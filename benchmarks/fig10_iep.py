"""Fig. 10 — counting with vs without the Inclusion–Exclusion Principle.

Paper methodology: fix the configuration (schedule + restriction set)
selected by the performance model; toggle ONLY the IEP folding of the
independent tail.  The win grows with candidate-set size, so the
star-family patterns (tail candidate set = a whole neighborhood) show
the paper's 100-1000× regime even on small graphs.
"""
from __future__ import annotations

from repro.core.config_search import search_configuration
from repro.core.plan import best_iep_k, build_plan

from ._util import Row, emit, get_pattern, graph_of, stats_of, timed_count

QUICK = {"patterns": ["P1", "P4", "star4", "fig6"], "datasets": ["tiny-er"]}
FULL = {"patterns": ["P1", "P2", "P4", "star4", "star5", "fig6", "P6"],
        "datasets": ["tiny-er", "small-rmat"]}


def run(full: bool = False, repeats: int = 2) -> list[Row]:
    spec = FULL if full else QUICK
    rows: list[Row] = []
    for ds in spec["datasets"]:
        graph, stats = graph_of(ds), stats_of(ds)
        for pname in spec["patterns"]:
            pattern = _pattern(pname)
            res = search_configuration(pattern, stats)
            best = res.best
            k = best_iep_k(pattern, best.order, best.res_set)
            if k < 2:
                continue                   # no foldable tail — IEP is a no-op
            c_enum, t_enum = timed_count(
                graph, build_plan(pattern, best.order, best.res_set, iep_k=0),
                repeats=repeats)
            plan_iep = build_plan(pattern, best.order, best.res_set, iep_k=k)
            c_iep, t_iep = timed_count(graph, plan_iep, repeats=repeats)
            assert c_enum == c_iep, (pname, ds, c_enum, c_iep)
            rows.append(Row("fig10", {"dataset": ds, "pattern": pname},
                            t_enum / t_iep, "speedup", {
                "iep_k": k, "divisor": plan_iep.iep_divisor,
                "t_enum_s": t_enum, "t_iep_s": t_iep, "count": c_iep,
            }))
    return rows


def _pattern(name: str):
    from repro.core.pattern import star

    if name == "star4":
        return star(4)
    if name == "star5":
        return star(5)
    return get_pattern(name)


def main(full: bool = False):
    emit(run(full), "fig10_iep")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
