"""Fig. 10 — counting with vs without the Inclusion–Exclusion Principle.

Paper methodology: fix the configuration (schedule + restriction set)
selected by the performance model; toggle ONLY the IEP folding of the
independent tail.  The win grows with candidate-set size, so the
star-family patterns (tail candidate set = a whole neighborhood) show
the paper's 100-1000× regime even on small graphs.

Two registered variants (benchmarks/run.py):

  fig10        enum vs IEP on the default execution path (portable on
               CPU, fused Pallas on TPU) — the paper's figure.
  fig10_fused  IEP separate-sweep vs fused-tail: the same IEP plan
               executed with the prefix corrections as per-position
               binary-search sweeps (portable path) vs folded into the
               level-expansion kernel's signed count (use_pallas=True —
               DESIGN.md §4).  On CPU the fused path runs in interpret
               mode, so only the trajectory of the curve is meaningful
               there; on TPU the timing is real.
"""
from __future__ import annotations

from repro.core.config_search import search_configuration
from repro.core.executor import ExecutorConfig, auto_buckets
from repro.core.plan import best_iep_k, build_plan

from ._util import Row, emit, get_pattern, graph_of, stats_of, timed_count

QUICK = {"patterns": ["P1", "P4", "star4", "fig6"], "datasets": ["tiny-er"]}
FULL = {"patterns": ["P1", "P2", "P4", "star4", "star5", "fig6", "P6"],
        "datasets": ["tiny-er", "small-rmat"]}

# interpret-mode Pallas is orders slower than compiled TPU code, so the
# fused-tail variant keeps a deliberately small quick tier on CPU
FUSED_QUICK = {"patterns": ["star4"], "datasets": ["tiny-er"]}
FUSED_FULL = {"patterns": ["star4", "star5", "P4"],
              "datasets": ["tiny-er", "small-rmat"]}


def run(full: bool = False, repeats: int = 2) -> list[Row]:
    spec = FULL if full else QUICK
    rows: list[Row] = []
    for ds in spec["datasets"]:
        graph, stats = graph_of(ds), stats_of(ds)
        for pname in spec["patterns"]:
            pattern = _pattern(pname)
            res = search_configuration(pattern, stats)
            best = res.best
            k = best_iep_k(pattern, best.order, best.res_set)
            if k < 2:
                continue                   # no foldable tail — IEP is a no-op
            c_enum, t_enum = timed_count(
                graph, build_plan(pattern, best.order, best.res_set, iep_k=0),
                repeats=repeats)
            plan_iep = build_plan(pattern, best.order, best.res_set, iep_k=k)
            c_iep, t_iep = timed_count(graph, plan_iep, repeats=repeats)
            assert c_enum == c_iep, (pname, ds, c_enum, c_iep)
            rows.append(Row("fig10", {"dataset": ds, "pattern": pname},
                            t_enum / t_iep, "speedup", {
                "iep_k": k, "divisor": plan_iep.iep_divisor,
                "t_enum_s": t_enum, "t_iep_s": t_iep, "count": c_iep,
            }))
    return rows


def run_fused(full: bool = False, repeats: int = 1,
              capacity: int = 1 << 12) -> list[Row]:
    """IEP tail: separate-sweep (portable binary searches per prefix
    position per union) vs fused (prefix corrections folded into the
    level-expansion kernel's signed count — one pass per union/bucket).
    Counts must stay bit-identical; the speedup column is the win the
    fusion buys on the SAME plan."""
    spec = FUSED_FULL if full else FUSED_QUICK
    rows: list[Row] = []
    for ds in spec["datasets"]:
        graph, stats = graph_of(ds), stats_of(ds)
        buckets = auto_buckets(graph)
        for pname in spec["patterns"]:
            pattern = _pattern(pname)
            res = search_configuration(pattern, stats)
            best = res.best
            k = best_iep_k(pattern, best.order, best.res_set)
            if k < 2:
                continue                   # no foldable tail
            plan = build_plan(pattern, best.order, best.res_set, iep_k=k)
            c_sep, t_sep = timed_count(
                graph, plan, repeats=repeats,
                cfg=ExecutorConfig(capacity=capacity, use_pallas=False,
                                   degree_buckets=buckets))
            c_fused, t_fused = timed_count(
                graph, plan, repeats=repeats,
                cfg=ExecutorConfig(capacity=capacity, use_pallas=True,
                                   degree_buckets=buckets))
            assert c_sep == c_fused, (pname, ds, c_sep, c_fused)
            rows.append(Row("fig10_fused", {"dataset": ds, "pattern": pname},
                            t_sep / t_fused, "speedup", {
                "iep_k": k, "t_separate_s": t_sep, "t_fused_s": t_fused,
                "count": c_fused,
            }))
    return rows


def _pattern(name: str):
    from repro.core.pattern import star

    if name == "star4":
        return star(4)
    if name == "star5":
        return star(5)
    return get_pattern(name)


def main(full: bool = False):
    emit(run(full), "fig10_iep")


def main_fused(full: bool = False):
    emit(run_fused(full), "fig10_fused")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
