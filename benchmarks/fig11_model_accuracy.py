"""Fig. 11 — accuracy of the performance prediction model.

For each pattern, measure every generated schedule (best restriction set
per the model) and compare the model's pick against the measured oracle.
Paper: picks average 32% slower than oracle.  Also reports the rank
correlation between predicted and measured cost — a stronger statement
than the paper's single-number comparison.
"""
from __future__ import annotations

from repro.core.perf_model import predict_cost
from repro.core.plan import build_plan
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules

from ._util import Row, emit, get_pattern, graph_of, stats_of, timed_count

QUICK = {"patterns": ["P1", "P2", "P4"], "datasets": ["tiny-er"]}
FULL = {"patterns": ["P1", "P2", "P3", "P4", "P5", "P6"],
        "datasets": ["tiny-er", "small-rmat"]}


def _spearman(xs, ys) -> float:
    import numpy as np

    rx = np.argsort(np.argsort(xs)).astype(float)
    ry = np.argsort(np.argsort(ys)).astype(float)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = float(np.sqrt((rx ** 2).sum() * (ry ** 2).sum()))
    return float((rx * ry).sum() / denom) if denom else 0.0


def run(full: bool = False, repeats: int = 2) -> list[Row]:
    spec = FULL if full else QUICK
    rows: list[Row] = []
    for ds in spec["datasets"]:
        graph, stats = graph_of(ds), stats_of(ds)
        for pname in spec["patterns"]:
            pattern = get_pattern(pname)
            res_sets = generate_restriction_sets(pattern)
            predicted, measured = [], []
            for order in generate_schedules(pattern):
                rs = min(res_sets,
                         key=lambda r: predict_cost(pattern, order, r, stats))
                cost = predict_cost(pattern, order, rs, stats)
                _, dt = timed_count(graph, build_plan(pattern, order, rs),
                                    repeats=repeats)
                predicted.append(cost)
                measured.append(dt)
            i_pick = min(range(len(predicted)), key=predicted.__getitem__)
            i_oracle = min(range(len(measured)), key=measured.__getitem__)
            rows.append(Row(
                "fig11", {"dataset": ds, "pattern": pname},
                measured[i_pick] / measured[i_oracle], "pick/oracle", {
                    "pick_s": measured[i_pick],
                    "oracle_s": measured[i_oracle],
                    "spearman": _spearman(predicted, measured),
                    "n_schedules": len(measured),
                }))
    return rows


def main(full: bool = False):
    emit(run(full), "fig11_model_accuracy")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
