"""Pallas intersection-kernel micro-benchmark (the paper's hot spot).

Compares three implementations of batched membership/intersection-count
over sorted padded neighbor lists:
  binary-search : the portable executor path (vectorized per-segment
                  binary search over flat CSR),
  pallas        : blocked broadcast-compare kernel (interpret mode on
                  CPU — correctness + lowering; the HLO it emits is the
                  TPU path),
  jnp-ref       : the pure-jnp oracle (ref.py).

On CPU only relative correctness + rough timing are meaningful; the
VMEM/roofline arguments for the kernel live in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from ._util import Row, emit

SHAPES_QUICK = [(256, 128, 128), (512, 128, 256)]
SHAPES_FULL = [(256, 128, 128), (512, 128, 256), (1024, 256, 512),
               (4096, 128, 128)]


def _data(B, D, L, seed=0):
    rng = np.random.default_rng(seed)
    # strictly increasing rows (CSR contract)
    nbr = np.stack(
        [np.sort(rng.choice(10 * L, size=L, replace=False)) for _ in range(B)]
    ).astype(np.int32)
    cand = rng.integers(0, 10 * L, size=(B, D)).astype(np.int32)
    return jnp.asarray(cand), jnp.asarray(nbr)


def _time(fn, *args, repeats=3):
    jax.block_until_ready(fn(*args))        # compile + warm
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    jref = jax.jit(ref.membership_ref)
    for (B, D, L) in (SHAPES_FULL if full else SHAPES_QUICK):
        cand, nbr = _data(B, D, L)
        out_ref = jref(cand, nbr)
        out_pl = ops.sorted_membership(cand, nbr)
        assert bool(jnp.all(out_ref == out_pl)), (B, D, L)

        t_pl = _time(lambda: ops.sorted_membership(cand, nbr))
        t_ref = _time(lambda: jref(cand, nbr))
        cnt_pl = ops.intersect_count(cand, nbr)
        assert bool(jnp.all(cnt_pl == out_ref.sum(axis=1)))
        t_cnt = _time(lambda: ops.intersect_count(cand, nbr))

        compares = B * D * L
        rows.append(Row("kernel", {"B": B, "D": D, "L": L,
                                   "impl": "pallas-membership"},
                        t_pl, "s", {"gcmp_per_s": compares / t_pl / 1e9}))
        rows.append(Row("kernel", {"B": B, "D": D, "L": L,
                                   "impl": "jnp-ref-membership"},
                        t_ref, "s", {"gcmp_per_s": compares / t_ref / 1e9}))
        rows.append(Row("kernel", {"B": B, "D": D, "L": L,
                                   "impl": "pallas-count"},
                        t_cnt, "s", {"gcmp_per_s": compares / t_cnt / 1e9}))
    return rows


LEVEL_SHAPES_QUICK = [(256, 128, 3, 128)]
LEVEL_SHAPES_FULL = [(256, 128, 3, 128), (512, 128, 4, 256),
                     (1024, 256, 2, 512)]


def _level_data(B, D, P, L, E=2, seed=0):
    """CSR-layout level data: one flat pool of strictly-increasing rows
    plus per-predecessor (starts, lens) — the layout the self-feeding
    kernel prefetches.  Nothing here (or anywhere) materializes the old
    [P, B, L] stacked window array."""
    rng = np.random.default_rng(seed)
    lens = np.full((P, B), L, np.int32)
    starts = (np.arange(P * B, dtype=np.int32) * L).reshape(P, B)
    flat = np.concatenate([
        np.sort(rng.choice(10 * L, size=L, replace=False)).astype(np.int32)
        for _ in range(P * B)
    ])
    cand = rng.integers(0, 10 * L, size=(B, D)).astype(np.int32)
    extra = rng.integers(0, 10 * L, size=(B, E)).astype(np.int32)
    dirs = tuple(1 if e % 2 == 0 else 0 for e in range(E))
    return (jnp.asarray(cand), jnp.asarray(flat), jnp.asarray(starts),
            jnp.asarray(lens), jnp.asarray(extra), dirs)


def _hbm_mb(*arrays) -> float:
    return sum(a.size * a.dtype.itemsize for a in arrays) / 2**20


def run_level(full: bool = False) -> list[Row]:
    """Self-feeding fused level expansion vs the per-predecessor
    composition, with the operand-HBM-peak accounting for DESIGN.md §4.

    per-pred      one `sorted_membership` pallas_call per predecessor
                  (window gathered host-side, ONE [B, L] array live at a
                  time) plus one XLA mask pass per restriction /
                  injectivity constraint — P + E separate sweeps.
    fused-gather  the whole level in ONE kernel pass; the predecessor
                  windows are DMA'd from the flat CSR array inside the
                  grid, so the only operands resident in HBM are the
                  graph itself + the candidate matrix.
    stacked (retired, PR 1..2): fused but fed a host-stacked [P, B, L]
                  window array — its ~P× operand peak is reported as
                  `hbm_peak_mb_stacked` for the before/after table; the
                  path itself no longer exists in the code base.
    """
    rows: list[Row] = []
    for (B, D, P, L) in (LEVEL_SHAPES_FULL if full else LEVEL_SHAPES_QUICK):
        cand, flat, starts, lens, extra, dirs = _level_data(B, D, P, L)
        E = len(dirs)

        @jax.jit
        def per_pred(cand, flat, starts, lens, extra):
            # the pre-fusion executor path: gather one predecessor's
            # window host-side, one membership kernel pass per
            # predecessor, then one XLA mask per comparison
            mask = jnp.ones(cand.shape, dtype=bool)
            for p in range(P):
                window = flat[starts[p][:, None]
                              + jnp.arange(L, dtype=jnp.int32)[None, :]]
                mask &= ops.sorted_membership(cand, window,
                                              nbr_len=lens[p])
            for e, d in enumerate(dirs):
                ev = extra[:, e][:, None]
                mask &= (cand > ev) if d > 0 else (cand != ev)
            return mask

        fused = lambda: ops.level_expand(cand, flat, starts, lens, extra,
                                         dirs=dirs, window=L)
        out_old = per_pred(cand, flat, starts, lens, extra)
        out_new = fused()
        assert bool(jnp.all(out_old == out_new)), (B, D, P, L)
        cnt = ops.level_expand(cand, flat, starts, lens, extra,
                               dirs=dirs, window=L, count=True)
        assert bool(jnp.all(cnt == out_old.sum(axis=1))), (B, D, P, L)

        t_old = _time(lambda: per_pred(cand, flat, starts, lens, extra))
        t_new = _time(fused)
        t_cnt = _time(lambda: ops.level_expand(cand, flat, starts, lens,
                                               extra, dirs=dirs, window=L,
                                               count=True))
        compares = B * D * L * P
        # operand HBM peaks (MB): what must be live at once to feed the
        # kernel, beyond the resident CSR itself
        peak_gather = _hbm_mb(cand, starts, lens, extra)
        peak_perpred = _hbm_mb(cand, extra) + B * L * 4 / 2**20
        peak_stacked = _hbm_mb(cand, extra) + P * B * L * 4 / 2**20
        keys = {"B": B, "D": D, "P": P, "L": L}
        rows.append(Row("level_expand", {**keys, "impl": "per-pred"},
                        t_old, "s", {"passes": P + E,
                                     "hbm_peak_mb": peak_perpred,
                                     "gcmp_per_s": compares / t_old / 1e9}))
        rows.append(Row("level_expand", {**keys, "impl": "fused-gather"},
                        t_new, "s", {"passes": 1,
                                     "hbm_peak_mb": peak_gather,
                                     "hbm_peak_mb_stacked": peak_stacked,
                                     "gcmp_per_s": compares / t_new / 1e9}))
        rows.append(Row("level_expand", {**keys,
                                         "impl": "fused-gather-count"},
                        t_cnt, "s", {"passes": 1,
                                     "hbm_peak_mb": peak_gather,
                                     "gcmp_per_s": compares / t_cnt / 1e9}))
    return rows


def main(full: bool = False):
    emit(run(full) + run_level(full), "kernel_intersect")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
