"""Fig. 8 — overall performance: GraphPi vs GraphZero-mode vs naive.

For each pattern × dataset this measures wall time of:
  graphpi   : best configuration from the performance model over ALL
              (schedule × restriction-set) candidates,
  graphzero : the baseline's single restriction set + degree-heuristic
              schedule (our faithful re-implementation of GraphZero's
              selection, as the paper also had to do),
  naive     : best schedule, NO restrictions (AutoMine-style), count
              divided by |Aut| afterwards.

The paper's headline (Fig. 8) is GraphPi ≥ GraphZero everywhere with up
to 105× on symmetry-heavy patterns; the naive column shows the |Aut|-fold
redundant computation restrictions eliminate.
"""
from __future__ import annotations

from repro.core.config_search import graphzero_configuration, search_configuration
from repro.core.plan import build_plan

from ._util import Row, emit, get_pattern, graph_of, stats_of, timed_count

QUICK = {"patterns": ["P1", "P2", "P3", "P4"], "datasets": ["tiny-er"]}
FULL = {"patterns": ["P1", "P2", "P3", "P4", "P5", "P6"],
        "datasets": ["tiny-er", "small-rmat"]}


def run(full: bool = False, repeats: int = 2) -> list[Row]:
    spec = FULL if full else QUICK
    rows: list[Row] = []
    for ds in spec["datasets"]:
        graph, stats = graph_of(ds), stats_of(ds)
        for pname in spec["patterns"]:
            pattern = get_pattern(pname)
            res = search_configuration(pattern, stats)
            plans = {
                "graphpi": build_plan(pattern, res.best.order,
                                      res.best.res_set),
            }
            gz = graphzero_configuration(pattern, stats)
            plans["graphzero"] = build_plan(pattern, gz.order, gz.res_set)
            plans["naive"] = build_plan(pattern, res.best.order, ())

            counts = {}
            for mode, plan in plans.items():
                c, dt = timed_count(graph, plan, repeats=repeats)
                if mode == "naive":
                    assert c % pattern.aut_count() == 0, (c, pattern)
                    c //= pattern.aut_count()
                counts[mode] = c
                rows.append(Row("fig8", {"dataset": ds, "pattern": pname,
                                         "mode": mode}, dt, "s",
                                {"count": c}))
            assert len(set(counts.values())) == 1, (pname, ds, counts)
    return rows


def main(full: bool = False):
    emit(run(full), "fig8_overall")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
