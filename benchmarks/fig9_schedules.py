"""Fig. 9 — the schedule landscape for one pattern.

Measures EVERY prefix-connected schedule of a pattern (the superset the
2-phase generator filters), marking for each whether the 2-phase
generator kept it, and where GraphPi's model pick / GraphZero's
heuristic pick / the oracle land.  The paper's claims:
  * most eliminated schedules are slow (the generator is safe),
  * the model pick is within ~22-32% of the oracle,
  * the oracle is up to 8× faster than the worst kept schedule.
"""
from __future__ import annotations

import itertools

from repro.core.config_search import graphzero_configuration, search_configuration
from repro.core.perf_model import predict_cost
from repro.core.plan import build_plan
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules, is_prefix_connected

from ._util import Row, emit, get_pattern, graph_of, stats_of, timed_count

# quick: the House — its 60-schedule pool shows the 2-phase filter
# eliminating 44 schedules; P3 (the paper's figure) runs under --full.
QUICK = {"pattern": "P1", "dataset": "tiny-er"}
FULL = {"pattern": "P3", "dataset": "tiny-er"}


def run(full: bool = False, repeats: int = 2) -> list[Row]:
    spec = FULL if full else QUICK
    pattern = get_pattern(spec["pattern"])
    graph, stats = graph_of(spec["dataset"]), stats_of(spec["dataset"])

    # one restriction algorithm for everything (paper methodology: isolate
    # the schedule choice) — GraphZero's canonical set
    rs = generate_restriction_sets(pattern)[0]
    kept = set(generate_schedules(pattern))
    # measure all prefix-connected schedules (the pool phase 2 prunes);
    # fully unconnected ones are catastrophically slow and excluded from
    # measurement in the paper's figure too
    pool = [
        o for o in itertools.permutations(range(pattern.n))
        if is_prefix_connected(pattern, o)
    ]

    res = search_configuration(pattern, stats)
    # model pick restricted to the same restriction set:
    model_pick = min(kept, key=lambda o: predict_cost(pattern, o, rs, stats))
    gz_pick = graphzero_configuration(pattern, stats).order

    rows: list[Row] = []
    times = {}
    for order in pool:
        c, dt = timed_count(graph, build_plan(pattern, order, rs),
                            repeats=repeats)
        times[order] = dt
        rows.append(Row(
            "fig9",
            {"pattern": spec["pattern"], "dataset": spec["dataset"],
             "schedule": "".join(map(str, order))},
            dt, "s",
            {"kept_by_2phase": order in kept,
             "is_model_pick": order == model_pick,
             "is_graphzero_pick": order == gz_pick,
             "count": c},
        ))
    oracle = min(times, key=times.get)
    kept_times = [times[o] for o in pool if o in kept]
    rows.append(Row("fig9", {"pattern": spec["pattern"],
                             "dataset": spec["dataset"],
                             "schedule": "SUMMARY"},
                    times[model_pick] / times[oracle], "pick/oracle", {
        "oracle": "".join(map(str, oracle)),
        "oracle_s": times[oracle],
        "model_pick_s": times[model_pick],
        "gz_pick_s": times.get(gz_pick),
        "worst_kept_over_oracle":
            (max(kept_times) / times[oracle]) if kept_times else None,
        "n_pool": len(pool), "n_kept": len(kept),
    }))
    return rows


def main(full: bool = False):
    emit(run(full), "fig9_schedules")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
