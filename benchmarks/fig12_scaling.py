"""Fig. 12 — scalability of the distributed runtime.

The container has one physical CPU socket, so 1..1024-node wall-clock
curves cannot be measured directly.  What CAN be measured exactly is the
quantity that determines them: per-task work and its balance under the
task-partitioning policy.  This benchmark:

  1. instruments the reference matcher to produce the exact search-tree
     work w[v] for every outer-loop root task v;
  2. simulates GraphPi's fine-grained striped assignment (device d owns
     tasks d, d+P, ...) and a naive contiguous-block assignment for
     P ∈ {1..1024} devices: projected speedup = Σw / max_device Σw;
  3. if the process has >1 JAX devices (XLA_FLAGS host platform count),
     additionally runs the real shard_map counting kernel and checks the
     count is invariant (the correctness half of scaling).

The paper observes near-linear scaling to 128 nodes and imbalance-limited
scaling beyond (P2/P3 on Twitter); the striped-vs-block curves reproduce
exactly that mechanism.
"""
from __future__ import annotations

import numpy as np

from repro.core.config_search import search_configuration
from repro.core.plan import build_plan

from ._util import Row, emit, get_pattern, graph_of, stats_of

QUICK = {"pattern": "P1", "dataset": "tiny-er"}
FULL = {"pattern": "P2", "dataset": "small-rmat"}


def per_root_work(graph, plan) -> np.ndarray:
    """Exact DFS-tree node count per root task (reference matcher walk)."""
    n_v = graph.n
    adj = [set(map(int, graph.neighbors(v))) for v in range(n_v)]
    n = plan.n
    preds = plan.preds
    restr = plan.restr
    depth = plan.depth
    work = np.zeros(n_v, dtype=np.int64)

    def rec(i, assigned, used):
        cnt = 1
        if i == depth:
            return cnt
        cand_sets = [adj[assigned[j]] for j in preds[i]]
        cand = set.intersection(*cand_sets) if cand_sets else set(range(n_v))
        for c in cand:
            if c in used:
                continue
            ok = True
            for (other, d) in restr[i]:
                if (d > 0) != (c > assigned[other]):
                    ok = False
                    break
            if not ok:
                continue
            cnt += rec(i + 1, assigned + [c], used | {c})
        return cnt

    for v in range(n_v):
        work[v] = rec(1, [v], {v})
    return work


def run(full: bool = False) -> list[Row]:
    spec = FULL if full else QUICK
    pattern = get_pattern(spec["pattern"])
    graph, stats = graph_of(spec["dataset"]), stats_of(spec["dataset"])
    res = search_configuration(pattern, stats)
    plan = build_plan(pattern, res.best.order, res.best.res_set)

    w = per_root_work(graph, plan)
    total = float(w.sum())
    rows: list[Row] = []
    for P in [1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]:
        if P > graph.n:
            break
        striped = np.zeros(P)
        for d in range(P):
            striped[d] = w[d::P].sum()
        blocks = np.array_split(w, P)
        blocked = np.array([b.sum() for b in blocks], dtype=float)
        rows.append(Row("fig12", {"pattern": spec["pattern"],
                                  "dataset": spec["dataset"],
                                  "devices": P, "policy": "striped"},
                        total / max(striped.max(), 1.0), "proj_speedup",
                        {"balance": float(striped.mean() / striped.max())}))
        rows.append(Row("fig12", {"pattern": spec["pattern"],
                                  "dataset": spec["dataset"],
                                  "devices": P, "policy": "blocked"},
                        total / max(blocked.max(), 1.0), "proj_speedup",
                        {"balance": float(blocked.mean() / blocked.max())}))

    # correctness + wall-clock half on whatever real devices exist; both
    # executor paths (portable binary search and the fused Pallas level
    # kernel) run across the host mesh so the two curves sit side by side
    # (ROADMAP: distributed striping benchmark).  Off-TPU the Pallas
    # curve is interpret-mode — bit-exact but slow, so it is a
    # correctness curve there, not a speed one.
    import time

    import jax

    if jax.device_count() > 1:
        from repro.core.executor import (
            ExecutorConfig, ShardedMatcher, count_embeddings,
        )
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(model=1)
        for policy, use_pallas in (("portable", False), ("pallas", True)):
            cfg = ExecutorConfig(capacity=1 << 14, use_pallas=use_pallas)
            single = count_embeddings(graph, plan, cfg)
            sm = ShardedMatcher(graph, plan, mesh, cfg=cfg)
            # warm with a full untimed count so even the capacities the
            # overflow-escalation path needs are compiled before timing
            sm.count()
            t0 = time.perf_counter()
            sharded = sm.count()
            dt = time.perf_counter() - t0
            assert single.count == sharded.count, (
                policy, single.count, sharded.count)
            rows.append(Row("fig12", {"pattern": spec["pattern"],
                                      "dataset": spec["dataset"],
                                      "devices": jax.device_count(),
                                      "policy": f"shard_map-{policy}"},
                            dt, "s", {"count": sharded.count,
                                      "count_invariant": True}))
    return rows


def main(full: bool = False):
    emit(run(full), "fig12_scaling")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
