"""Ground-truth question benchmark: labeled pattern matching as QA.

A property graph plus a typed pattern query is a *question* with one
objectively right answer ("how many manager→engineer→manager chains?"),
and the brute-force oracle can state that answer independently of every
plan-time and executor-path decision under test.  This module fixes a
generated labeled graph (`tiny-labeled`, 4 label classes) and a
~54-question inventory — typed multi-hop joins, labeled triangles and
cliques, star-with-role queries, wildcard mixes — answers each question
through the real pipeline (canonicalization → configuration search →
label-aware plan → executor) on BOTH executor paths, and scores the
answers against the oracle.

`tests/test_questions.py` gates tier-1 on 100% agreement over the full
inventory; here the same inventory doubles as a throughput benchmark
(questions/s per path) and the accuracy row makes any disagreement an
artifact-visible failure (`run()` raises, so `scripts/bench_smoke.sh`
fails loudly rather than persisting a wrong-answer artifact).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.config_search import search_configuration
from repro.core.executor import ExecutorConfig, Matcher, device_graph
from repro.core.oracle import count_embeddings_oracle
from repro.core.pattern import Pattern
from repro.query.canon import canonical_form

from ._util import Row, emit, graph_of, stats_of

DATASET = "tiny-labeled"        # 256 vertices, 4 label classes (0..3)
CAPACITY = 1 << 12

Label = int | None


def _edge(a: Label, b: Label) -> Pattern:
    return Pattern(2, ((0, 1),), labels=(a, b))


def _path(labs: tuple[Label, ...]) -> Pattern:
    n = len(labs)
    return Pattern(n, tuple((i, i + 1) for i in range(n - 1)), labels=labs)


def _tri(labs: tuple[Label, Label, Label]) -> Pattern:
    return Pattern(3, ((0, 1), (1, 2), (0, 2)), labels=labs)


def _star(center: Label, leaves: tuple[Label, ...]) -> Pattern:
    n = 1 + len(leaves)
    return Pattern(n, tuple((0, i) for i in range(1, n)),
                   labels=(center,) + leaves)


def _cycle4(labs: tuple[Label, ...]) -> Pattern:
    return Pattern(4, ((0, 1), (1, 2), (2, 3), (0, 3)), labels=labs)


def _clique4(labs: tuple[Label, ...]) -> Pattern:
    return Pattern(4, ((0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)),
                   labels=labs)


def _tailed_tri(labs: tuple[Label, ...]) -> Pattern:
    """Triangle 0-1-2 with pendant 3 hanging off vertex 0."""
    return Pattern(4, ((0, 1), (1, 2), (0, 2), (0, 3)), labels=labs)


@dataclass(frozen=True)
class Question:
    qid: str
    text: str                   # the human phrasing of the question
    category: str
    pattern: Pattern


def _lab(x: Label) -> str:
    return "*" if x is None else f"L{x}"


def inventory() -> list[Question]:
    """The full question inventory (deterministic order and qids)."""
    qs: list[Question] = []

    def add(category: str, text: str, pattern: Pattern) -> None:
        qs.append(Question(f"q{len(qs):02d}", text, category, pattern))

    # --- typed joins: how many (a)-(b) edges? -------------------------
    for a, b in [(0, 0), (0, 1), (0, 2), (0, 3), (1, 1),
                 (1, 2), (1, 3), (2, 2), (2, 3), (3, 3)]:
        add("typed-edge",
            f"how many {_lab(a)}—{_lab(b)} edges?", _edge(a, b))
    for a in (0, 2):
        add("typed-edge",
            f"how many edges touch a {_lab(a)} vertex?", _edge(a, None))

    # --- labeled triangles -------------------------------------------
    for labs in [(0, 0, 0), (1, 1, 1), (2, 2, 2), (3, 3, 3),
                 (0, 0, 1), (0, 1, 1), (0, 1, 2), (0, 1, 3),
                 (1, 2, 3), (2, 2, 3)]:
        add("labeled-triangle",
            "how many triangles typed "
            f"{_lab(labs[0])}-{_lab(labs[1])}-{_lab(labs[2])}?", _tri(labs))
    for labs in [(0, None, None), (None, None, 2), (1, None, 3)]:
        add("labeled-triangle",
            "how many triangles with role slots "
            f"{_lab(labs[0])}-{_lab(labs[1])}-{_lab(labs[2])}?", _tri(labs))

    # --- typed multi-hop joins (paths) -------------------------------
    for labs in [(0, 1, 0), (0, 1, 2), (1, 0, 1),
                 (2, 3, 2), (0, None, 1), (3, 1, 3)]:
        add("typed-path",
            "how many 2-hop chains "
            + "→".join(_lab(x) for x in labs) + "?", _path(labs))
    for labs in [(0, 1, 1, 0), (0, 1, 2, 3), (1, 2, 2, 1),
                 (0, None, None, 3), (2, 1, 1, 2)]:
        add("typed-path",
            "how many 3-hop chains "
            + "→".join(_lab(x) for x in labs) + "?", _path(labs))

    # --- stars with role constraints ---------------------------------
    for center, leaves in [(0, (1, 1, 2)), (2, (0, 1, 3)), (1, (3, 3, 3)),
                           (3, (0, 0, 0)), (None, (1, 2, 3)),
                           (0, (None, 1, 2))]:
        add("star-role",
            f"how many {_lab(center)} hubs with role set "
            "{" + ",".join(_lab(x) for x in leaves) + "}?",
            _star(center, leaves))

    # --- labeled rectangles (4-cycles) -------------------------------
    for labs in [(0, 1, 0, 1), (0, 1, 2, 3), (2, 2, 3, 3),
                 (0, None, 0, None), (1, 1, 1, 1)]:
        add("labeled-rectangle",
            "how many 4-cycles typed "
            + "-".join(_lab(x) for x in labs) + "?", _cycle4(labs))

    # --- labeled cliques ---------------------------------------------
    for labs in [(0, 1, 2, 3), (0, 0, 1, 1), (1, 1, 1, 1),
                 (None, 0, 1, 2)]:
        add("labeled-clique",
            "how many K4 cliques typed "
            + "-".join(_lab(x) for x in labs) + "?", _clique4(labs))

    # --- tailed triangles (triangle + pendant role) ------------------
    for labs in [(0, 1, 2, 3), (1, 1, 1, 0), (2, None, 2, 0)]:
        add("tailed-triangle",
            "how many triangles "
            f"{_lab(labs[0])}-{_lab(labs[1])}-{_lab(labs[2])} with a "
            f"{_lab(labs[3])} pendant on the first vertex?",
            _tailed_tri(labs))

    return qs


def oracle_answers(graph, questions: list[Question]) -> dict[str, int]:
    """Ground truth per qid, brute-forced independently of the pipeline."""
    edges = graph.edge_array()
    return {
        q.qid: count_embeddings_oracle(graph.n, edges, q.pattern,
                                       labels=graph.labels)
        for q in questions
    }


def machine_answers(
    graph, questions: list[Question], *, use_pallas: bool,
    capacity: int = CAPACITY, stats=None, arrays=None,
) -> tuple[dict[str, int], float]:
    """(answers, seconds) through the real pipeline on one executor path.

    Every question pays canonicalization, the configuration search, a
    label-aware plan build, and a fresh executor trace — the full cold
    path — so an agreement failure localizes to the pipeline, not to a
    shared shortcut.  Device arrays are shared across questions (the
    graph does not change between questions)."""
    if stats is None:
        stats = stats_of(DATASET)
    if arrays is None:
        arrays = device_graph(graph)
    cfg = ExecutorConfig(capacity=capacity, use_pallas=use_pallas)
    answers: dict[str, int] = {}
    t0 = time.perf_counter()
    for q in questions:
        canon = canonical_form(q.pattern)
        best = search_configuration(canon, stats).best
        from repro.core.plan import build_plan

        plan = build_plan(canon, best.order, best.res_set, iep_k=best.iep_k)
        m = Matcher(graph, plan, cfg, arrays=arrays)
        out = m.count()
        assert not out.overflowed, f"{q.qid}: overflow at capacity {capacity}"
        answers[q.qid] = int(out.count)
        m.release()
    return answers, time.perf_counter() - t0


def run(full: bool = False) -> list[Row]:
    graph = graph_of(DATASET)
    questions = inventory()
    t0 = time.perf_counter()
    truth = oracle_answers(graph, questions)
    oracle_s = time.perf_counter() - t0
    # an inventory that mostly asks about empty classes would "pass"
    # while validating nothing — demand real mass behind the questions
    nonzero = sum(1 for v in truth.values() if v > 0)
    assert nonzero >= len(questions) * 3 // 5, (
        f"only {nonzero}/{len(questions)} questions have nonzero answers")

    arrays = device_graph(graph)
    stats = stats_of(DATASET)
    rows: list[Row] = []
    keys = {"dataset": DATASET, "questions": len(questions)}
    for path, use_pallas in (("portable", False), ("fused", True)):
        answers, dt = machine_answers(
            graph, questions, use_pallas=use_pallas, stats=stats,
            arrays=arrays)
        wrong = {q.qid: (answers[q.qid], truth[q.qid])
                 for q in questions if answers[q.qid] != truth[q.qid]}
        by_cat: dict[str, list[bool]] = {}
        for q in questions:
            by_cat.setdefault(q.category, []).append(
                answers[q.qid] == truth[q.qid])
        for cat, oks in sorted(by_cat.items()):
            rows.append(Row("questions",
                            {**keys, "path": path, "category": cat},
                            sum(oks) / len(oks), "accuracy",
                            {"n": len(oks)}))
        rows.append(Row("questions", {**keys, "path": path},
                        (len(questions) - len(wrong)) / len(questions),
                        "accuracy",
                        {"wrong": {k: {"got": g, "want": w}
                                   for k, (g, w) in wrong.items()},
                         "nonzero_truth": nonzero}))
        rows.append(Row("questions",
                        {**keys, "path": path, "phase": "throughput"},
                        len(questions) / dt, "questions/s",
                        {"oracle_s": oracle_s}))
        if wrong:
            # never persist a pretty artifact over wrong answers
            raise AssertionError(
                f"{path} path disagrees with the oracle on "
                f"{len(wrong)} question(s): {wrong}")
    return rows


def main(full: bool = False):
    emit(run(full), "questions")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
