"""Live-graph churn — serve-while-mutating vs drop-and-reload, and
incremental vs full recount (ISSUE 10 acceptance).

Phase A replays ROUNDS batches of edge churn with queries between each.
The live path keeps ONE `QueryEngine` (delta overlay, epoch keys):
mutations land at round boundaries, plans and compiled matchers are
reused across epochs, only counts re-execute.  The reload path does
what a frozen engine forces today — rebuild the CSR and a fresh engine
every batch, paying stats + search + JIT again.  Counts are asserted
identical between the two paths at every round; the headline ratio is
queries/s.

Phase B measures incremental count maintenance on a locality-friendly
ring-lattice: after a full (memoized) count, a single edge insert
dirties well under 1% of vertices, so the maintainer re-expands only
the spans owning the dirty neighborhood and carries every other span's
total forward.  Reported as dispatch and wall-time ratios vs the full
recount the same engine would otherwise run, asserted oracle-exact.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.executor import ExecutorConfig
from repro.core.oracle import count_embeddings_oracle
from repro.graph.csr import GraphCSR
from repro.query import QueryEngine, QueryRequest

from ._util import Row, emit, fresh_registry, get_pattern, graph_of

QUICK = {"dataset": "tiny-er", "patterns": ["triangle", "P1"],
         "rounds": 4, "ins": 8, "dels": 4, "capacity": 1 << 14}
FULL = {"dataset": "small-rmat", "patterns": ["triangle", "P1", "P2"],
        "rounds": 6, "ins": 32, "dels": 16, "capacity": 1 << 15}


def _churn_schedule(graph, seed, rounds, n_ins, n_del):
    """Deterministic per-round (inserts, deletes) with deletes drawn
    from the evolving edge set."""
    rng = np.random.default_rng(seed)
    edges = set(map(tuple, graph.edge_array().tolist()))
    sched = []
    for _ in range(rounds):
        ins = []
        while len(ins) < n_ins:
            u, v = sorted(int(x) for x in rng.integers(0, graph.n, 2))
            if u != v and (u, v) not in edges and (u, v) not in ins:
                ins.append((u, v))
        edges |= set(ins)
        pool = sorted(edges)
        dels = [pool[i] for i in
                rng.choice(len(pool), size=n_del, replace=False)]
        edges -= set(dels)
        sched.append((ins, dels, sorted(edges)))
    return sched


def _serve(engine, patterns):
    tickets = [engine.enqueue(QueryRequest(p)) for p in patterns]
    while engine.pending() or engine.inflight():
        engine.run_pending()
    return [t.result.count for t in tickets]


def run(full: bool = False) -> list[Row]:
    spec = FULL if full else QUICK
    graph = graph_of(spec["dataset"])
    patterns = [get_pattern(n) for n in spec["patterns"]]
    cfg = ExecutorConfig(capacity=spec["capacity"])
    sched = _churn_schedule(graph, seed=17, rounds=spec["rounds"],
                            n_ins=spec["ins"], n_del=spec["dels"])
    keys = {"dataset": spec["dataset"], "patterns": len(patterns),
            "rounds": spec["rounds"]}

    # ---- phase A: one live engine across every churn round
    live_engine = QueryEngine(graph, cfg=cfg, live=True,
                              metrics=fresh_registry())
    _serve(live_engine, patterns)            # steady state: warm plans
    t0 = time.perf_counter()
    live_counts = []
    for ins, dels, _ in sched:
        live_engine.request_mutation("insert_edges", ins)
        live_engine.request_mutation("delete_edges", dels)
        live_counts.append(_serve(live_engine, patterns))
    live_s = time.perf_counter() - t0
    lsum = live_engine.summary()["live"]

    # ---- phase A reference: drop the engine, rebuild per round
    t0 = time.perf_counter()
    reload_counts = []
    for ins, dels, edges in sched:
        g = GraphCSR.from_edges(graph.n, edges,
                                name=f"{graph.name}-reload")
        reload_counts.append(_serve(QueryEngine(g, cfg=cfg), patterns))
    reload_s = time.perf_counter() - t0
    assert live_counts == reload_counts, "live ⊕ delta drifted from rebuilt"

    n_queries = spec["rounds"] * len(patterns)
    live_qps = n_queries / live_s
    reload_qps = n_queries / reload_s

    # ---- phase B: incremental vs full recount, ≤1% dirty
    n = 2048
    ring = sorted({(min(u, v), max(u, v))
                   for i in range(n)
                   for u, v in ((i, (i + 1) % n), (i, (i + 2) % n))})
    rg = GraphCSR.from_edges(n, ring, name="ring2048")
    tri = get_pattern("triangle")
    inc_engine = QueryEngine(rg, cfg=cfg, live=True, chunk=256)
    t0 = time.perf_counter()
    _serve(inc_engine, [tri])                # full count, memoized
    full_s = time.perf_counter() - t0
    full_disp = inc_engine.last_round_dispatches
    inc_engine.request_mutation("insert_edges", [(100, 103)])
    t0 = time.perf_counter()
    inc_count = _serve(inc_engine, [tri])[0]
    inc_s = time.perf_counter() - t0
    inc_disp = inc_engine.last_round_dispatches
    isum = inc_engine.summary()["live"]
    assert isum["incremental_hits"] == 1, isum
    assert inc_disp < full_disp, (inc_disp, full_disp)
    want = count_embeddings_oracle(
        n, inc_engine.live.materialize_edges(), tri)
    assert inc_count == want, (inc_count, want)
    dirty_frac = len(inc_engine.live.dirty_vertices()) / n

    return [
        Row("live_churn", {**keys, "phase": "live"}, live_qps,
            "queries/s",
            {"mutations": lsum["mutations_applied"],
             "rebinds": lsum["matcher_rebinds"],
             "rebuilds": lsum["matcher_rebuilds"],
             "compactions": lsum["compactions"]}),
        Row("live_churn", {**keys, "phase": "reload"}, reload_qps,
            "queries/s", {}),
        Row("live_churn", {**keys, "phase": "speedup"},
            live_qps / reload_qps, "x",
            {"live_s": round(live_s, 4), "reload_s": round(reload_s, 4)}),
        Row("live_churn", {"graph": "ring2048", "phase": "incremental"},
            full_disp / max(inc_disp, 1), "x_dispatches",
            {"full_dispatches": full_disp, "inc_dispatches": inc_disp,
             "full_s": round(full_s, 4), "inc_s": round(inc_s, 4),
             "spans_reused": isum["spans_reused"],
             "dirty_frac": round(dirty_frac, 5)}),
    ]


def main(full: bool = False) -> None:
    emit(run(full), "live_churn")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
