"""Table II — speedup from GraphPi's restriction-set selection.

For each schedule of a pattern, GraphPi ranks ALL restriction sets with
the performance model and picks the best; GraphZero has exactly one set.
Where the two choices differ we measure both and report the speedup
distribution (paper: avg 1.6-2.5×, max 7.8×).
"""
from __future__ import annotations

from repro.core.perf_model import predict_cost
from repro.core.plan import build_plan
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules

from ._util import Row, emit, get_pattern, graph_of, stats_of, timed_count

QUICK = {"patterns": ["P1", "P2", "P4"], "datasets": ["tiny-er"],
         "max_schedules": 6}
FULL = {"patterns": ["P1", "P2", "P4"], "datasets": ["tiny-er", "small-rmat"],
        "max_schedules": None}


def run(full: bool = False, repeats: int = 2) -> list[Row]:
    spec = FULL if full else QUICK
    rows: list[Row] = []
    for ds in spec["datasets"]:
        graph, stats = graph_of(ds), stats_of(ds)
        for pname in spec["patterns"]:
            pattern = get_pattern(pname)
            res_sets = generate_restriction_sets(pattern)
            gz_set = res_sets[0]            # GraphZero's single canonical set
            schedules = generate_schedules(pattern)
            if spec["max_schedules"]:
                schedules = schedules[: spec["max_schedules"]]
            speedups = []
            for order in schedules:
                best_rs = min(
                    res_sets,
                    key=lambda rs: predict_cost(pattern, order, rs, stats),
                )
                if best_rs == gz_set:
                    continue               # identical choice — no comparison
                c1, t_pi = timed_count(
                    graph, build_plan(pattern, order, best_rs),
                    repeats=repeats)
                c2, t_gz = timed_count(
                    graph, build_plan(pattern, order, gz_set),
                    repeats=repeats)
                assert c1 == c2, (pname, order, c1, c2)
                speedups.append(t_gz / t_pi)
                rows.append(Row(
                    "tab2", {"dataset": ds, "pattern": pname,
                             "schedule": "".join(map(str, order))},
                    t_gz / t_pi, "speedup",
                    {"t_graphpi_s": t_pi, "t_graphzero_s": t_gz},
                ))
            if speedups:
                rows.append(Row("tab2", {"dataset": ds, "pattern": pname,
                                         "schedule": "AVG"},
                                sum(speedups) / len(speedups), "speedup",
                                {"max": max(speedups), "n": len(speedups)}))
    return rows


def main(full: bool = False):
    emit(run(full), "tab2_restrictions")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
