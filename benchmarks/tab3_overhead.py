"""Table III — preprocessing + plan-generation overhead per pattern.

The paper reports 8 ms – 2.53 s for P1..P6 (pattern-only, independent of
the data graph).  We time the three plan-time stages separately:
restriction generation (Alg. 1 incl. K_n validation), 2-phase schedule
generation, and full configuration search (cost model over every
schedule × restriction set with IEP variants).
"""
from __future__ import annotations

import time

from repro.core.config_search import search_configuration
from repro.core.perf_model import GraphStats
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules

from ._util import Row, emit, get_pattern, stats_of

PATTERNS = ["P1", "P2", "P3", "P4", "P5", "P6"]


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    # Stats are graph-dependent but cheap; use a fixed small graph's stats
    # (the paper's Table III is also a single number per pattern).
    stats = stats_of("tiny-er")
    for pname in PATTERNS:
        pattern = get_pattern(pname)
        t0 = time.perf_counter()
        res_sets = generate_restriction_sets(pattern)
        t_res = time.perf_counter() - t0
        t0 = time.perf_counter()
        schedules = generate_schedules(pattern)
        t_sched = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = search_configuration(pattern, stats, use_iep=True)
        t_total = time.perf_counter() - t0
        rows.append(Row("tab3", {"pattern": pname}, t_total, "s", {
            "restriction_gen_s": t_res,
            "schedule_gen_s": t_sched,
            "n_restriction_sets": len(res_sets),
            "n_schedules": len(schedules),
            "n_configs": len(res.all_configs),
        }))
    return rows


def main(full: bool = False):
    emit(run(full), "tab3_overhead")


if __name__ == "__main__":
    main()
