"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run              # quick tier
    PYTHONPATH=src python -m benchmarks.run --full       # paper-scale tier
    PYTHONPATH=src python -m benchmarks.run --only fig10,tab3

Each module prints `bench,key=value...,value,unit` CSV rows and writes a
JSON artifact under artifacts/bench/.  The quick tier finishes on a CPU
container in minutes; --full uses the larger synthetic datasets.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    fig8_overall,
    fig9_schedules,
    fig10_iep,
    fig11_model_accuracy,
    fig12_scaling,
    gateway_mix,
    kernel_intersect,
    live_churn,
    query_throughput,
    questions,
    tab2_restrictions,
    tab3_overhead,
)

BENCHES = {
    "fig8": fig8_overall.main,       # overall perf vs GraphZero/naive
    "tab2": tab2_restrictions.main,  # restriction-set selection speedup
    "fig9": fig9_schedules.main,     # schedule landscape + 2-phase filter
    "fig10": fig10_iep.main,         # IEP on/off
    "fig10_fused": fig10_iep.main_fused,  # IEP tail: separate vs fused
    "fig11": fig11_model_accuracy.main,  # model pick vs oracle
    "fig12": fig12_scaling.main,     # scaling / load balance
    "tab3": tab3_overhead.main,      # preprocessing overhead
    "kernel": kernel_intersect.main, # Pallas intersection kernel
    "query": query_throughput.main,  # serve path: cold vs warm queries/s
    "gateway": gateway_mix.main,     # mixed graph+LM: coalescing/interference
    "questions": questions.main,     # labeled QA: oracle accuracy + q/s
    "live_churn": live_churn.main,   # serve-while-mutating vs reload
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args(argv)

    names = [n.strip() for n in args.only.split(",") if n.strip()] or \
        list(BENCHES)
    failures = []
    for name in names:
        print(f"\n=== {name} {'(full)' if args.full else '(quick)'} ===")
        t0 = time.time()
        try:
            BENCHES[name](args.full)
            print(f"=== {name} done in {time.time() - t0:.1f}s ===")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED: {failures}", file=sys.stderr)
        return 1
    print("\nall benchmarks OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
