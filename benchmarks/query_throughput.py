"""Query-serving throughput — cold vs warm queries/sec through the
plan cache.

Cold phase: a fresh `QueryEngine` serves each distinct pattern once, so
every query pays configuration search + plan build + JIT (the price the
old one-shot CLI paid per invocation).  Warm phase: the same patterns —
plus isomorphic relabelings, which must also hit — are re-served
`WARM_ROUNDS` times through the populated cache.  The cold/warm ratio
is the serving subsystem's reason to exist; warm p50/p99 is the
steady-state request latency.
"""
from __future__ import annotations

import time

from repro.core.executor import ExecutorConfig
from repro.query import PlanCache, QueryEngine, QueryRequest, relabeled_variant

from ._util import Row, emit, fresh_registry, get_pattern, graph_of, stats_of

QUICK = {"dataset": "tiny-er", "patterns": ["P1", "P2", "P4"],
         "capacity": 1 << 14}
FULL = {"dataset": "small-rmat", "patterns": ["P1", "P2", "P4", "P5"],
        "capacity": 1 << 15}
WARM_ROUNDS = 3


def _serve_sequential(engine, requests):
    """One request per round (no coalescing): preserves the benchmark's
    per-query latency semantics on the ticketed request surface."""
    tickets = [engine.enqueue(r) for r in requests]
    while engine.pending():
        engine.run_pending(limit=1)
    return [t.result for t in tickets]


def run(full: bool = False) -> list[Row]:
    spec = FULL if full else QUICK
    graph = graph_of(spec["dataset"])
    patterns = [get_pattern(n) for n in spec["patterns"]]
    engine = QueryEngine(
        graph,
        cfg=ExecutorConfig(capacity=spec["capacity"]),
        cache=PlanCache(),
        stats=stats_of(spec["dataset"]),
        metrics=fresh_registry(),
    )

    t0 = time.perf_counter()
    cold = _serve_sequential(engine, [QueryRequest(p) for p in patterns])
    cold_s = time.perf_counter() - t0
    assert all(not r.cache_hit for r in cold)
    over = [r.pattern_name for r in cold if r.overflowed]
    assert not over, f"overflowed (truncated) counts for {over}"
    cold_lat = engine.latency_percentiles()

    engine.reset_latencies()
    warm_reqs = []
    for rnd in range(WARM_ROUNDS):
        for i, p in enumerate(patterns):
            warm_reqs.append(QueryRequest(p))
            warm_reqs.append(QueryRequest(relabeled_variant(p, seed=rnd * 17 + i)))
    t0 = time.perf_counter()
    warm = _serve_sequential(engine, warm_reqs)
    warm_s = time.perf_counter() - t0
    assert all(r.cache_hit for r in warm), "warm phase must be all hits"
    for r in warm:
        assert r.count == next(c.count for c in cold
                               if c.canon_key == r.canon_key)
    warm_lat = engine.latency_percentiles()

    cache = engine.cache.stats
    keys = {"dataset": spec["dataset"], "patterns": len(patterns)}
    return [
        Row("query_throughput", {**keys, "phase": "cold"},
            len(cold) / cold_s, "queries/s",
            {"p50_ms": cold_lat["p50_ms"], "p99_ms": cold_lat["p99_ms"],
             "search_s": cache.search_seconds,
             "compile_s": cache.compile_seconds}),
        Row("query_throughput", {**keys, "phase": "warm"},
            len(warm) / warm_s, "queries/s",
            {"p50_ms": warm_lat["p50_ms"], "p99_ms": warm_lat["p99_ms"],
             "hits": cache.hits, "misses": cache.misses}),
        Row("query_throughput", {**keys, "phase": "speedup"},
            (len(warm) / warm_s) / max(len(cold) / cold_s, 1e-12), "x",
            {}),
    ]


def main(full: bool = False):
    emit(run(full), "query_throughput")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
