"""Gateway mixed-traffic benchmark: coalescing + interference evidence.

Two claims the unified front door makes, both counter-asserted here:

 (a) COALESCING — bursty duplicate patterns are cheap: a round holding
     N tickets of one isomorphism class dispatches ONE plan execution
     (engine.executions < requests, asserted), because the scheduler
     groups same-class tickets before counting.

 (b) BOUNDED INTERFERENCE — graph-query latency under concurrent LM
     decode stays within a recorded factor of solo latency: the same
     warm burst workload is served once with the graph tenant alone and
     once co-scheduled with a hot `LMDecodeWorkload`; the artifact
     records solo p50, mixed p50, and their ratio, and the run fails if
     the ratio exceeds INTERFERENCE_BOUND (generous — CPU CI timing is
     noisy; the point is a recorded bound, not a tight one).

 (c) TENANT FAIRNESS UNDER PREEMPTION — a small tenant's p99 survives
     an adversarial co-resident: the same cheap-query burst is timed
     solo and again while a whale tenant's huge count (P3, two orders
     of magnitude more kernel dispatches) is mid-flight.  Preemptive quanta (`preempt_dispatches`) checkpoint
     the whale between rounds, so the mouse completes while the whale
     is still suspended — the artifact records per-tenant p99 for both
     phases plus the preemption count that made it possible.

All phases run against a prewarmed plan cache (search/JIT excluded,
same methodology as the paper's timing) on the CPU smoke config.
"""
from __future__ import annotations

from repro.core.executor import ExecutorConfig
from repro.obs import MetricsRegistry
from repro.query import QueryEngine, QueryRequest, relabeled_variant
from repro.serve.gateway import (
    Gateway, GraphQueryWorkload, LMDecodeWorkload, Share,
)
from repro.serve.session import LMSession

from ._util import Row, emit, fresh_registry, get_pattern, graph_of, stats_of

QUICK = {"dataset": "tiny-er", "patterns": ["P1", "triangle"],
         "capacity": 1 << 13, "bursts": 2, "dups": 2,
         "arch": "qwen3-1.7b", "batch": 2, "prompt_len": 16}
FULL = {"dataset": "small-rmat", "patterns": ["P1", "P2", "P4"],
        "capacity": 1 << 15, "bursts": 3, "dups": 3,
        "arch": "qwen3-1.7b", "batch": 4, "prompt_len": 32}
INTERFERENCE_BOUND = 100.0   # mixed p50 must stay within this × solo p50


def _burst_requests(patterns, bursts: int, dups: int):
    """`bursts` rounds, each: every pattern once plus `dups` isomorphic
    relabelings — the duplicate-heavy shape coalescing exists for."""
    reqs = []
    for b in range(bursts):
        for i, p in enumerate(patterns):
            reqs.append(QueryRequest(p))
            for d in range(dups):
                reqs.append(QueryRequest(
                    relabeled_variant(p, seed=101 * b + 13 * i + d)))
    return reqs


def _serve_phase(engine, requests, quantum: int, lm_spec=None):
    """Drain `requests` through a Gateway; returns (gateway, results)."""
    gw = Gateway(metrics=engine.metrics)
    wl = gw.add(GraphQueryWorkload(engine, requests),
                Share(quantum=quantum))
    if lm_spec is not None:
        gw.add(LMDecodeWorkload(lm_spec), Share(quantum=2))
    gw.run(warmup=False)     # engine prewarmed; LM session started below
    return gw, wl.results()


def run(full: bool = False) -> list[Row]:
    spec = FULL if full else QUICK
    graph = graph_of(spec["dataset"])
    patterns = [get_pattern(n) for n in spec["patterns"]]
    engine = QueryEngine(
        graph,
        cfg=ExecutorConfig(capacity=spec["capacity"]),
        stats=stats_of(spec["dataset"]),
        metrics=fresh_registry(),
    )
    # prewarm every class: both phases measure steady-state execution
    for p in patterns:
        engine.plan(QueryRequest(p))

    burst = len(patterns) * (1 + spec["dups"])
    keys = {"dataset": spec["dataset"], "patterns": len(patterns),
            "burst": burst, "bursts": spec["bursts"]}

    # ---- phase 1: solo graph ------------------------------------------
    engine.reset_latencies()
    reqs = _burst_requests(patterns, spec["bursts"], spec["dups"])
    _, solo_results = _serve_phase(engine, reqs, quantum=burst)
    solo = engine.latency_percentiles()
    n_requests = len(reqs)
    n_exec = engine.executions
    n_coal = engine.coalesced
    assert n_exec < n_requests, (
        f"coalescing must dispatch fewer executions ({n_exec}) than "
        f"requests ({n_requests})")
    assert n_coal == n_requests - n_exec
    by_class: dict[str, int] = {}
    for r in solo_results:
        assert not r.overflowed, f"overflowed count for {r.pattern_name}"
        assert by_class.setdefault(r.canon_key, r.count) == r.count

    # ---- phase 2: graph + hot LM decode -------------------------------
    session = LMSession(
        spec["arch"], smoke=True, batch=spec["batch"],
        prompt_len=spec["prompt_len"],
        gen=4 * spec["bursts"] * len(patterns) + 8,
    )
    session.start()
    engine.reset_latencies()
    exec_before = engine.executions
    reqs = _burst_requests(patterns, spec["bursts"], spec["dups"])
    gw, mixed_results = _serve_phase(engine, reqs, quantum=burst,
                                     lm_spec=session)
    mixed = engine.latency_percentiles()
    for r in mixed_results:
        # scheduling must never change a count
        assert by_class[r.canon_key] == r.count, r.pattern_name
    factor = (mixed["p50_ms"] / solo["p50_ms"]
              if solo["p50_ms"] > 0 else float("inf"))
    assert factor <= INTERFERENCE_BOUND, (
        f"graph p50 under decode is {factor:.1f}x solo "
        f"(bound {INTERFERENCE_BOUND}x)")
    lm = session.metrics()

    # ---- phase 3: small tenant vs preempted whale tenant --------------
    tenant_rows = _tenant_fairness_phase(spec, graph, keys)

    return [
        Row("gateway_mix", {**keys, "phase": "coalesce"},
            n_exec, "executions",
            {"requests": n_requests, "coalesced": n_coal,
             "cache_hits": engine.cache.stats.hits}),
        Row("gateway_mix", {**keys, "phase": "solo"},
            solo["p50_ms"], "ms",
            {"p99_ms": solo["p99_ms"], "n": solo["n"]}),
        Row("gateway_mix", {**keys, "phase": "mixed"},
            mixed["p50_ms"], "ms",
            {"p99_ms": mixed["p99_ms"], "n": mixed["n"],
             "executions": engine.executions - exec_before,
             "lm_steps": lm["steps_done"],
             "lm_tok_s": lm["decode_tok_s"],
             "rounds": gw.report()["rounds"]}),
        Row("gateway_mix", {**keys, "phase": "interference"},
            factor, "x", {"bound": INTERFERENCE_BOUND}),
        *tenant_rows,
    ]


def _tenant_fairness_phase(spec, graph, keys) -> list[Row]:
    """Claim (c): drive one engine with a preemption budget; time the
    mouse tenant's burst solo, then again with a whale tenant's huge
    count suspended mid-flight.  The mouse must resolve while the whale
    is still in flight, and every count stays exact."""
    engine = QueryEngine(
        graph,
        cfg=ExecutorConfig(capacity=spec["capacity"]),
        stats=stats_of(spec["dataset"]),
        metrics=MetricsRegistry(),   # private: keep emit()'s snapshot
        chunk=8,                     # scoped to the main engine above
        preempt_dispatches=8,
    )
    mouse_pat = get_pattern("triangle")
    whale_req = QueryRequest(get_pattern("P3"), tenant="whale")
    engine.plan(QueryRequest(mouse_pat))          # prewarm both classes
    engine.plan(whale_req)

    def mouse_burst(tenant: str):
        tickets = [engine.enqueue(QueryRequest(mouse_pat, tenant=tenant))
                   for _ in range(spec["bursts"] * 2)]
        for _ in range(1000):
            if all(t.done for t in tickets):
                break
            engine.run_pending()
        assert all(t.done for t in tickets)
        return tickets

    solo_tickets = mouse_burst("mouse_solo")
    solo = engine.latency_percentiles(tenant="mouse_solo")
    ref_count = solo_tickets[0].result.count

    whale = engine.enqueue(whale_req)
    engine.run_pending()                          # whale suspended mid-class
    assert engine.inflight() == 1 and not whale.done, (
        "whale must still be in flight when the mouse burst lands")
    adv_tickets = mouse_burst("mouse")
    assert not whale.done, (
        "fairness evidence requires the mouse to finish first")
    adv = engine.latency_percentiles(tenant="mouse")
    preemptions = engine.preemptions
    for t in solo_tickets + adv_tickets:
        assert t.result.count == ref_count        # preemption never skews
    for _ in range(1000):                         # drain the whale
        if whale.done:
            break
        engine.run_pending()
    assert whale.done and not whale.result.overflowed

    ratio = (adv["p99_ms"] / solo["p99_ms"]
             if solo["p99_ms"] > 0 else float("inf"))
    return [
        Row("gateway_mix", {**keys, "phase": "tenant_solo"},
            solo["p99_ms"], "ms",
            {"p50_ms": solo["p50_ms"], "n": solo["n"]}),
        Row("gateway_mix", {**keys, "phase": "tenant_adversarial"},
            adv["p99_ms"], "ms",
            {"p50_ms": adv["p50_ms"], "n": adv["n"],
             "p99_ratio": ratio, "preemptions": preemptions,
             "whale_count": whale.result.count}),
    ]


def main(full: bool = False):
    emit(run(full), "gateway_mix")


if __name__ == "__main__":
    import sys

    main("--full" in sys.argv)
