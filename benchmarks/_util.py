"""Shared benchmark plumbing: timing, stats caching, result records."""
from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, dataclass, field

from repro.configs.graphpi import get_dataset, get_pattern
from repro.core.executor import (
    ExecutorConfig, Matcher, auto_buckets, compute_stats,
)
from repro.core.perf_model import GraphStats
from repro.core.plan import build_plan

ART_DIR = os.environ.get("REPRO_BENCH_OUT", "artifacts/bench")

_STATS_CACHE: dict[str, GraphStats] = {}
_GRAPH_CACHE: dict[str, object] = {}


def graph_of(name: str):
    if name not in _GRAPH_CACHE:
        _GRAPH_CACHE[name] = get_dataset(name)
    return _GRAPH_CACHE[name]


def stats_of(name: str) -> GraphStats:
    if name not in _STATS_CACHE:
        _STATS_CACHE[name] = compute_stats(graph_of(name))
    return _STATS_CACHE[name]


def timed_count(graph, plan, *, capacity: int = 1 << 15,
                repeats: int = 1, budget_s: float = 120.0,
                cfg: ExecutorConfig | None = None):
    """(count, best_seconds).  Compile excluded (paper methodology).

    The default configuration is the hot path: fused Pallas level
    expansion (use_pallas=None resolves to True on TPU backends) with
    auto degree buckets.  On CPU the portable binary-search path runs
    instead — interpret-mode Pallas is correctness-only; set
    REPRO_BENCH_PALLAS=1/0 to force either path.

    budget_s bounds total measurement wall time: if the first timed run
    exceeds it, we keep that single measurement."""
    if cfg is None:
        force = {"1": True, "0": False}.get(
            os.environ.get("REPRO_BENCH_PALLAS", ""))
        cfg = ExecutorConfig(capacity=capacity, use_pallas=force,
                             degree_buckets=auto_buckets(graph))
    m = Matcher(graph, plan, cfg)
    m.warmup()
    best = None
    count = None
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        out = m.count()
        dt = time.perf_counter() - t0
        assert not out.overflowed, "frontier overflow at MAX_CAPACITY"
        count = out.count
        best = dt if best is None else min(best, dt)
        if dt > budget_s:
            break
    return count, best


@dataclass
class Row:
    bench: str
    keys: dict
    value: float
    unit: str
    extra: dict = field(default_factory=dict)


def emit(rows: list[Row], name: str) -> None:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump([asdict(r) for r in rows], f, indent=1)
    for r in rows:
        keys = ",".join(f"{k}={v}" for k, v in r.keys.items())
        print(f"{r.bench},{keys},{r.value:.6g},{r.unit}")
    print(f"[bench] wrote {path}")
