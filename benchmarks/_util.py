"""Shared benchmark plumbing: timing, stats caching, result records.

Observability rides along for free: with the tracer enabled
(REPRO_TRACE=1, as `scripts/bench_smoke.sh` sets) `timed_count` emits
`bench.warmup` / `bench.count` spans around its measurements and
`emit()` writes `<name>.trace.json` + `<name>.metrics.json` next to
each benchmark's result artifact.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, dataclass, field

from repro.configs.graphpi import get_dataset, get_pattern
from repro.core.executor import (
    ExecutorConfig, Matcher, auto_buckets, compute_stats,
)
from repro.core.perf_model import GraphStats
from repro.core.plan import build_plan
from repro.obs import MetricsRegistry, get_tracer

ART_DIR = os.environ.get("REPRO_BENCH_OUT", "artifacts/bench")

# Registry snapshotted by emit(): benchmark mains pass it to their
# engine/gateway so the metrics artifact carries the run's counters.
REGISTRY = MetricsRegistry()


def fresh_registry() -> MetricsRegistry:
    """New registry for one benchmark main.  benchmarks/run.py executes
    several mains in one process; swapping the module registry keeps
    each emitted snapshot scoped to its own benchmark (no collectors
    left over from the previous engine)."""
    global REGISTRY
    REGISTRY = MetricsRegistry()
    return REGISTRY

_STATS_CACHE: dict[str, GraphStats] = {}
_GRAPH_CACHE: dict[str, object] = {}


def graph_of(name: str):
    if name not in _GRAPH_CACHE:
        _GRAPH_CACHE[name] = get_dataset(name)
    return _GRAPH_CACHE[name]


def stats_of(name: str) -> GraphStats:
    if name not in _STATS_CACHE:
        _STATS_CACHE[name] = compute_stats(graph_of(name))
    return _STATS_CACHE[name]


def timed_count(graph, plan, *, capacity: int = 1 << 15,
                repeats: int = 1, budget_s: float = 120.0,
                cfg: ExecutorConfig | None = None):
    """(count, best_seconds).  Compile excluded (paper methodology).

    The default configuration is the hot path: fused Pallas level
    expansion (use_pallas=None resolves to True on TPU backends) with
    auto degree buckets.  On CPU the portable binary-search path runs
    instead — interpret-mode Pallas is correctness-only; set
    REPRO_BENCH_PALLAS=1/0 to force either path.

    budget_s bounds total measurement wall time: if the first timed run
    exceeds it, we keep that single measurement."""
    if cfg is None:
        force = {"1": True, "0": False}.get(
            os.environ.get("REPRO_BENCH_PALLAS", ""))
        cfg = ExecutorConfig(capacity=capacity, use_pallas=force,
                             degree_buckets=auto_buckets(graph))
    m = Matcher(graph, plan, cfg)
    with get_tracer().span("bench.warmup", graph=graph.name):
        m.warmup()
    best = None
    count = None
    for rep in range(max(repeats, 1)):
        with get_tracer().span("bench.count", graph=graph.name,
                               repeat=rep):
            t0 = time.perf_counter()
            out = m.count()
            dt = time.perf_counter() - t0
        assert not out.overflowed, "frontier overflow at MAX_CAPACITY"
        count = out.count
        best = dt if best is None else min(best, dt)
        if dt > budget_s:
            break
    return count, best


@dataclass
class Row:
    bench: str
    keys: dict
    value: float
    unit: str
    extra: dict = field(default_factory=dict)


def emit(rows: list[Row], name: str) -> None:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump([asdict(r) for r in rows], f, indent=1)
    for r in rows:
        keys = ",".join(f"{k}={v}" for k, v in r.keys.items())
        print(f"{r.bench},{keys},{r.value:.6g},{r.unit}")
    print(f"[bench] wrote {path}")
    # tracer on (REPRO_TRACE=1): every benchmark gains trace + metrics
    # artifacts for free next to its result JSON
    tr = get_tracer()
    if tr.enabled and len(tr):
        tpath = os.path.join(ART_DIR, f"{name}.trace.json")
        n = tr.export_chrome(tpath)
        print(f"[bench] wrote {tpath} ({n} spans)")
        tr.clear()               # one trace per benchmark, not cumulative
        mpath = os.path.join(ART_DIR, f"{name}.metrics.json")
        with open(mpath, "w") as f:
            json.dump(REGISTRY.snapshot(), f, indent=1, default=str,
                      sort_keys=True)
        print(f"[bench] wrote {mpath}")
