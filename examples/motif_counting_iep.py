"""Counting with the Inclusion–Exclusion Principle (paper §IV-D).

    PYTHONPATH=src python examples/motif_counting_iep.py

When an application only needs the NUMBER of embeddings, GraphPi replaces
the innermost k loops (whose pattern vertices are pairwise non-adjacent)
by a closed-form IEP evaluation over candidate-set cardinalities.  This
example counts the paper's Fig. 6 motif (k = 3 independent tail) both
ways and reports the speedup — the paper's Fig. 10 shows up to 1110×.
"""
import time

from repro.configs.graphpi import EXTRA_PATTERNS, get_dataset
from repro.core.config_search import search_configuration
from repro.core.executor import ExecutorConfig, compute_stats, count_embeddings
from repro.core.oracle import count_embeddings_oracle
from repro.core.plan import best_iep_k, build_plan


def main():
    pattern = EXTRA_PATTERNS["fig6"]
    graph = get_dataset("tiny-er")
    stats = compute_stats(graph)
    print(f"pattern {pattern.name} (n={pattern.n}), graph {graph.name}")

    # Same configuration both ways (paper Fig. 10 methodology: fix the
    # schedule and restriction set; toggle only the IEP folding).
    res = search_configuration(pattern, stats)
    best = res.best
    k = best_iep_k(pattern, best.order, best.res_set)
    print(f"schedule={best.order} restrictions={best.res_set} "
          f"IEP-foldable tail k={k}")

    ecfg = ExecutorConfig(capacity=1 << 15)
    plan_enum = build_plan(pattern, best.order, best.res_set, iep_k=0)
    t0 = time.perf_counter()
    c_enum = count_embeddings(graph, plan_enum, ecfg).count
    t_enum = time.perf_counter() - t0

    plan_iep = build_plan(pattern, best.order, best.res_set, iep_k=k)
    t0 = time.perf_counter()
    c_iep = count_embeddings(graph, plan_iep, ecfg).count
    t_iep = time.perf_counter() - t0

    print(f"enumeration: count={c_enum}  {t_enum:.3f}s")
    print(f"IEP (k={k}):  count={c_iep}  {t_iep:.3f}s  "
          f"(overcount divisor x={plan_iep.iep_divisor})")
    assert c_enum == c_iep, (c_enum, c_iep)
    if t_iep > 0:
        print(f"speedup {t_enum / t_iep:.1f}×")

    expect = count_embeddings_oracle(graph.n, graph.edge_array(), pattern)
    assert expect == c_iep, (expect, c_iep)
    print(f"oracle = {expect}  ✓")


if __name__ == "__main__":
    main()
