"""Train a small LM end to end (fault-tolerant loop, real optimizer).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the production train path (make_train_step: sharded params/opt
state, remat, donation) on a reduced qwen3-family config sized for CPU.
Interrupt it (Ctrl-C) and rerun — it resumes from the atomic checkpoint
and the step-indexed data pipeline continues the exact token stream.
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()
    return train_main([
        "--arch", "qwen3-1.7b", "--smoke",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
        "--log-every", "10",
    ])


if __name__ == "__main__":
    sys.exit(main())
