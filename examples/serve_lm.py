"""Serve a small model with batched requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_lm.py

Uses the production serving path (make_prefill/make_decode — the same
functions the 256-chip dry-run lowers) on a reduced MoE config, so the
expert-parallel decode path is exercised on CPU.
"""
import sys

from repro.launch.serve import main as serve_main


def main():
    return serve_main([
        "--arch", "granite-moe-1b-a400m", "--smoke",
        "--batch", "4", "--prompt-len", "32", "--gen", "16",
    ])


if __name__ == "__main__":
    sys.exit(main())
