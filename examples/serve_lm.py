"""Serve a small model with the resumable LMSession API.

    PYTHONPATH=src python examples/serve_lm.py

Uses the production serving path (LMSession over make_prefill /
make_decode — the same functions the 256-chip dry-run lowers) on a
reduced MoE config, so the expert-parallel decode path is exercised on
CPU.  The session is the unit the serving Gateway schedules: decode
runs in explicit step batches, so graph-query rounds can interleave
(see `python -m repro.launch.gateway`), and `start(resume=True)` picks
a preempted generation back up from its last checkpoint.
"""
import sys

from repro.serve.session import LMSession


def main():
    session = LMSession(
        "granite-moe-1b-a400m", smoke=True,
        batch=4, prompt_len=32, gen=16,
    )
    session.start()
    m = session.metrics()
    print(f"prefill: {session.B}x{session.S} tokens "
          f"in {m['prefill_seconds']:.3f}s")
    while session.remaining:
        session.decode_steps(4)        # the Gateway's step granularity
        print(f"decoded {session.step_i}/{session.gen} steps")
    m = session.metrics()
    print(f"decode: {m['decode_tok_s']:.1f} tok/s "
          f"({m['ms_per_step']:.1f} ms/step)")
    print(f"sample tokens[0,:8] = {session.tokens_out()[0, :8].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
