"""Quickstart: count a pattern in a graph with the full GraphPi pipeline.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end to end on CPU in a few seconds:
  1. define a pattern,
  2. generate restriction sets (Algorithm 1) and efficient schedules
     (2-phase generator),
  3. let the performance model pick the optimal configuration,
  4. count embeddings with the JAX executor,
  5. verify against the pure-python oracle.
"""
import math

from repro.configs.graphpi import get_dataset
from repro.core.config_search import search_configuration
from repro.core.executor import ExecutorConfig, compute_stats, count_embeddings
from repro.core.oracle import count_embeddings_oracle
from repro.core.pattern import Pattern, house
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules


def main():
    # 1. the House pattern (paper Fig. 5a): a rectangle with a roof apex
    pattern = house()
    print(f"pattern: {pattern}")
    print(f"|Aut| = {pattern.aut_count()} (mirror symmetry)")

    # 2. Algorithm 1 — multiple restriction sets, each kills all symmetry
    res_sets = generate_restriction_sets(pattern)
    print(f"\nAlgorithm 1 found {len(res_sets)} restriction sets:")
    for rs in res_sets[:4]:
        print("   ", " & ".join(f"id({a}) > id({b})" for a, b in rs))

    schedules = generate_schedules(pattern)
    print(f"2-phase generator kept {len(schedules)} of "
          f"{math.factorial(pattern.n)} schedules")

    # 3. data graph + performance-model configuration selection
    graph = get_dataset("tiny-er")
    stats = compute_stats(graph)
    print(f"\ngraph: {graph.name} |V|={graph.n} |E|={graph.m} "
          f"triangles={stats.tri_cnt}")
    res = search_configuration(pattern, stats, use_iep=True)
    best = res.best
    print(f"searched {len(res.all_configs)} configurations in "
          f"{res.preprocess_seconds * 1e3:.1f} ms")
    print(f"best: schedule={best.order} restrictions={best.res_set} "
          f"iep_k={best.iep_k}")

    # 4. count with the JAX executor
    plan = res.plan(pattern)
    out = count_embeddings(graph, plan, ExecutorConfig(capacity=1 << 14))
    print(f"\ncount = {out.count}")

    # 5. verify
    expect = count_embeddings_oracle(graph.n, graph.edge_array(), pattern)
    assert out.count == expect, (out.count, expect)
    print(f"oracle = {expect}  ✓")


if __name__ == "__main__":
    main()
