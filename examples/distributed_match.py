"""End-to-end distributed pattern matching (the paper's workload).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_match.py

Runs the paper's distributed algorithm over an 8-device host mesh:
the outer-loop vertex tasks are striped over the `data` axis exactly like
GraphPi's master-thread task partitioning (fine-grained striping instead
of MPI work stealing — DESIGN.md §3), and the per-device counts are
psum-reduced.  The same code lowers on the 256-chip production mesh
(launch/dryrun.py proves it compiles there).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.configs.graphpi import PATTERNS, get_dataset
from repro.core.config_search import search_configuration
from repro.core.executor import (
    ExecutorConfig, compute_stats, count_embeddings, count_embeddings_sharded,
)
from repro.core.oracle import count_embeddings_oracle
from repro.launch.mesh import make_host_mesh


def main():
    # tiny-er keeps this demo CPU-quick; swap in "small-rmat" (power-law)
    # to see the striped load balancing actually matter
    graph = get_dataset("tiny-er")
    pattern = PATTERNS["P2"]                 # pentagon
    print(f"devices: {jax.device_count()}  graph: {graph.name} "
          f"|V|={graph.n} |E|={graph.m} max_deg={graph.max_degree}")

    stats = compute_stats(graph)
    res = search_configuration(pattern, stats, use_iep=True)
    plan = res.plan(pattern)
    print(f"config: schedule={res.best.order} restr={res.best.res_set} "
          f"iep_k={res.best.iep_k}")

    cfg = ExecutorConfig(capacity=1 << 14)

    # single device
    t0 = time.perf_counter()
    single = count_embeddings(graph, plan, cfg)
    t1 = time.perf_counter() - t0

    # sharded over the host mesh's data axis (fine-grained task striping)
    mesh = make_host_mesh(model=1)
    t0 = time.perf_counter()
    sharded = count_embeddings_sharded(graph, plan, mesh, cfg=cfg)
    t2 = time.perf_counter() - t0

    print(f"single-device count = {single.count}   ({t1:.3f}s)")
    print(f"sharded      count  = {sharded.count}   ({t2:.3f}s over "
          f"{jax.device_count()} devices)")
    assert single.count == sharded.count

    expect = count_embeddings_oracle(graph.n, graph.edge_array(), pattern)
    assert expect == single.count, (expect, single.count)
    print(f"oracle = {expect}  ✓")


if __name__ == "__main__":
    main()
