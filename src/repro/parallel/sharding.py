"""Sharding rules: map parameter/batch/cache pytrees → PartitionSpecs.

Mesh axes (launch/mesh.py):
    single pod:  ("data", "model")            = (16, 16)
    multi-pod:   ("pod", "data", "model")     = (2, 16, 16)

`DP` below = all data-parallel axes (pod+data); `MP` = "model".

Parameter policy (2-D: TP over model, FSDP over data — ZeRO-3-like):
    embed [V, d]           (MP, DP)     vocab over model, FSDP over d
    wq/wk/wv [d, Hhd]      (DP, MP)
    wo [Hhd, d]            (MP, DP)
    mlp gate/up [d, ff]    (DP, MP)
    mlp down [ff, d]       (MP, DP)
    moe gate/up [E, d, f]  (MP, DP, ∅)  expert-parallel over model
    moe down [E, f, d]     (MP, ∅, DP)
    moe router [d, E]      (DP, ∅)
    mamba in_proj [d, P]   (DP, MP)
    mamba out_proj [di,d]  (MP, DP)
    1-D params             replicated
Leading scan-stack dims get ∅ prepended automatically.

The rules are chosen by a small analytic cost model (`choose_kv_spec`)
where a choice exists (decode KV cache: shard heads vs sequence).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    names = mesh.axis_names
    dp = tuple(a for a in names if a in ("pod", "data"))
    return dp if len(dp) > 1 else (dp[0] if dp else None)


MP = "model"

# (path-suffix match, spec for the trailing (non-stacked) dims)
_RULES: list[tuple[tuple[str, ...], tuple[Any, ...]]] = [
    (("embed", "w"), (MP, "DP")),
    (("lm_head", "w"), ("DP", MP)),
    (("wq", "w"), ("DP", MP)),
    (("wk", "w"), ("DP", MP)),
    (("wv", "w"), ("DP", MP)),
    (("wo", "w"), (MP, "DP")),
    (("gate", "w"), ("DP", MP)),
    (("up", "w"), ("DP", MP)),
    (("down", "w"), (MP, "DP")),
    (("router", "w"), ("DP", None)),
    # moe expert tensors (no trailing 'w' — raw [E, ..] arrays)
    (("mlp", "gate"), (MP, "DP", None)),
    (("mlp", "up"), (MP, "DP", None)),
    (("mlp", "down"), (MP, None, "DP")),
    (("in_proj", "w"), ("DP", MP)),
    (("out_proj", "w"), (MP, "DP")),
    (("conv_w",), (None, MP)),
]


def _match(path: tuple[str, ...], suffix: tuple[str, ...]) -> bool:
    return len(path) >= len(suffix) and tuple(path[-len(suffix):]) == suffix


def pick_layout(cfg, mesh: Mesh) -> str:
    """Analytic layout choice (the GraphPi idea applied to sharding: rank
    candidate plans with a cost model instead of a fixed heuristic).

    'tp2d'          params 2-D sharded (TP×FSDP)  — default
    'dp_replicated' params replicated, batch over every axis — small
                    models whose head count can't fill the model axis;
                    TP would force GSPMD to all-reduce full attention
                    score tensors (measured 1.6 TB/step on whisper-base).
    """
    m = mesh.shape[MP]
    # replicated params+opt (16 B/param fp32 master + m + v + bf16) must fit
    # comfortably under the 16 GB HBM budget
    fits = cfg.param_count() * 16 < 6e9
    heads_ok = cfg.n_heads == 0 or cfg.n_heads % m == 0
    if fits and not heads_ok:
        return "dp_replicated"
    return "tp2d"


def param_spec(path: tuple[str, ...], shape: tuple[int, ...], mesh: Mesh,
               layout: str = "tp2d") -> P:
    if layout == "dp_replicated":
        return P()
    dp = dp_axes(mesh)

    def sub(s):
        return dp if s == "DP" else s

    for suffix, spec in _RULES:
        if _match(path, suffix):
            spec = tuple(sub(s) for s in spec)
            ndim = len(shape)
            if len(spec) > ndim:      # smoke configs may drop dims — bail
                return P()
            pad = (None,) * (ndim - len(spec))   # scan-stack leading dims
            full = pad + spec
            # never shard a dim that isn't divisible by its axis size
            sized = []
            for dim, ax in zip(shape, full):
                if ax is None:
                    sized.append(None)
                    continue
                n = (
                    int(np.prod([mesh.shape[a] for a in ax]))
                    if isinstance(ax, tuple)
                    else mesh.shape[ax]
                )
                sized.append(ax if dim % n == 0 else None)
            return P(*sized)
    return P()  # replicate 1-D / unmatched params


def param_shardings(params_shape, mesh: Mesh, layout: str = "tp2d"):
    """Tree of NamedShardings matching an eval_shape'd param tree."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(params_shape)

    def key_names(kp):
        out = []
        for k in kp:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "name"):
                out.append(str(k.name))
            else:
                out.append(str(k))
        return tuple(out)

    specs = [
        NamedSharding(mesh, param_spec(key_names(kp), v.shape, mesh, layout))
        for kp, v in flat
    ]
    return jax.tree_util.tree_unflatten(tdef, specs)


def opt_state_shardings(opt_shape, params_shardings, mesh: Mesh):
    """m/v mirror the params; step is replicated."""
    return {
        "m": params_shardings,
        "v": params_shardings,
        "step": NamedSharding(mesh, P()),
    }


# ----------------------------------------------------------------- batch ---
def _largest_dividing_axes(axes: tuple, dim: int, mesh: Mesh):
    """Longest prefix-shrunk axis tuple whose size product divides `dim`.

    §Perf iteration 1 (whisper-base prefill): the old rule demanded the
    FULL axis product divide the batch and otherwise replicated it — a
    global_batch=32 cell on 256 chips then did 16× redundant work per
    device.  Dropping trailing axes until the product divides keeps the
    batch sharded as widely as the shape allows."""
    axes = tuple(axes)
    while axes:
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % n == 0 and n > 1:
            return axes, n
        axes = axes[:-1]
    return None, 1


def batch_specs(batch_shape, mesh: Mesh, layout: str = "tp2d"):
    """Shard every batch leaf over the widest dividing data-axis tuple
    (dim 0); with dp_replicated layout the model axis carries batch too."""
    dp = dp_axes(mesh)
    if layout == "dp_replicated":
        dp = tuple(mesh.axis_names)
    dp = dp if isinstance(dp, tuple) else (dp,)

    def spec(v):
        if not v.shape or v.shape[0] <= 1:
            return NamedSharding(mesh, P())
        axes, n = _largest_dividing_axes(dp, v.shape[0], mesh)
        if axes is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(axes, *([None] * (len(v.shape) - 1))))

    return jax.tree.map(spec, batch_shape)


# -------------------------------------------------------------- KV cache ---
def choose_kv_spec(cfg, batch: int, seq: int, mesh: Mesh) -> P:
    """Cache [..., B, S, K, hd]: shard B over DP when divisible; shard K
    over model when K ≥ |model| (cheap, no softmax collectives), else
    shard S over model (flash-decoding style — the partial-softmax
    reductions cost one small all-reduce per layer but the cache fits).

    Analytic rule: prefer the head shard iff K % |model| == 0."""
    dp = dp_axes(mesh)
    m = mesh.shape[MP]
    ndp = int(np.prod([mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,))]))
    bspec = dp if batch % ndp == 0 and batch > 1 else None
    K = max(cfg.n_kv_heads, 1)
    if K % m == 0:
        return P(bspec, None, MP, None)
    if seq % m == 0:
        return P(bspec, MP, None, None)
    return P(bspec, None, None, None)


def cache_shardings(cfg, cache_shape, batch: int, seq: int, mesh: Mesh):
    kv = choose_kv_spec(cfg, batch, seq, mesh)

    def spec(v):
        ndim = len(v.shape)
        if ndim >= 5 and v.shape[-1] == cfg.head_dim and v.shape[-3] == seq:
            # [stack, B, S, K, hd]
            return NamedSharding(mesh, P(*((None,) * (ndim - 4)), *kv))
        if ndim >= 5 and v.shape[-2] == seq:
            # encdec cross_kv [L, 2, B, S, K, hd] handled below
            return NamedSharding(mesh, P())
        # ssm states [stack, B, nh, hd, ds] / conv [stack, B, cw-1, cd]:
        # shard batch over DP; heads/channels over model when divisible
        dp = dp_axes(mesh)
        ndpn = int(np.prod([mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,))]))
        bdim = 1 if ndim >= 2 else None
        parts = [None] * ndim
        if bdim is not None and v.shape[bdim] % ndpn == 0 and v.shape[bdim] > 1:
            parts[bdim] = dp
        if ndim >= 3 and v.shape[2] % mesh.shape[MP] == 0:
            parts[2] = MP
        return NamedSharding(mesh, P(*parts))

    def spec_cross(v):  # [L, 2, B, S, K, hd]
        dp = dp_axes(mesh)
        ndpn = int(np.prod([mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,))]))
        parts = [None] * len(v.shape)
        if v.shape[2] % ndpn == 0 and v.shape[2] > 1:
            parts[2] = dp
        if v.shape[3] % mesh.shape[MP] == 0:
            parts[3] = MP
        return NamedSharding(mesh, P(*parts))

    out = {}
    for k, v in cache_shape.items():
        if k == "cross_kv":
            out[k] = spec_cross(v)
        else:
            out[k] = jax.tree.map(spec, v)
    return out
