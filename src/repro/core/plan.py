"""MatchingPlan — a fully static compilation of one *configuration*
(schedule × restriction set [× IEP]) that the JAX executor consumes.

All pattern vertices are relabeled to schedule order: loop position i
assigns pattern vertex i.  Everything here is plain Python data; the
executor closes over it so every jitted shape/branch is static.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import itertools

import numpy as np

from .iep import IEPPlan, build_iep_plan
from .pattern import Pattern
from .restrictions import Restriction, RestrictionSet
from .schedule import Schedule, predecessors


class IEPInvalidError(ValueError):
    """IEP folding is unsound for this (schedule, restriction set, k)."""


def iep_multiplicity(
    pattern: Pattern, surviving: Sequence[Restriction]
) -> int | None:
    """Per-subgraph overcount x under partial restrictions R'.

    Every subgraph instance with generic id ranking σ is found
    m(σ) = #{p ∈ Aut : σ∘p ⊨ R'} times.  The paper (§IV-D) derives x via
    `no_conflict`, but that counts *consistent* perms, which overestimates
    (e.g. triangle with R'={id0>id1}: no_conflict gives 5, the true
    multiplicity is 3).  We compute m(σ) exactly for all σ ∈ S_n and
    return it when constant; a non-constant m means no single divisor is
    correct and IEP must be rejected for this configuration — a soundness
    condition the paper does not state.
    """
    from .restrictions import perm_matrix

    n = pattern.n
    auts = pattern.automorphisms()
    sigmas = perm_matrix(n)
    m = np.zeros(len(sigmas), dtype=np.int64)
    for p in auts:
        ok = np.ones(len(sigmas), dtype=bool)
        for (a, b) in surviving:
            ok &= sigmas[:, p[a]] > sigmas[:, p[b]]
        m += ok
    if not (m == m[0]).all():
        return None
    return int(m[0])


@dataclass(frozen=True)
class MatchingPlan:
    pattern: Pattern            # original labeling
    order: Schedule             # schedule (original vertex ids)
    n: int
    # per loop position i (schedule-relabeled):
    preds: tuple[tuple[int, ...], ...]       # adjacent earlier positions
    neqs: tuple[tuple[int, ...], ...]        # earlier positions needing !=
    # restrictions at position i: (other_pos, dir); dir=+1 → v_i > v_other
    restr: tuple[tuple[tuple[int, int], ...], ...]
    iep: IEPPlan | None         # folded tail, or None (enumeration to depth n)
    iep_divisor: int            # x in ans = ans_IEP / x  (1 when iep is None)
    res_set: RestrictionSet     # original labeling (for reporting)
    # vertex label required at loop position i (None = wildcard);
    # None altogether for unlabeled patterns.
    vlabels: tuple[int | None, ...] | None = None

    @property
    def depth(self) -> int:
        """Number of explicit loops (prefix length)."""
        return self.n - (self.iep.k if self.iep else 0)


def build_plan(
    pattern: Pattern,
    order: Schedule,
    res_set: Sequence[Restriction],
    *,
    iep_k: int = 0,
) -> MatchingPlan:
    n = pattern.n
    if sorted(order) != list(range(n)):
        raise ValueError(f"order {order} is not a permutation of 0..{n-1}")
    if iep_k > 0 and pattern.labels is not None:
        # IEP folds the tail into closed-form cardinalities over unlabeled
        # candidate sets; per-label tail sets are future work, so labeled
        # plans always enumerate to depth n (best_iep_k returns 0 for them).
        raise ValueError("IEP folding is not supported for labeled patterns")
    pos = {v: i for i, v in enumerate(order)}
    rel = pattern.relabel(order)          # position-major pattern
    preds = tuple(tuple(p) for p in predecessors(rel, tuple(range(n))))
    if any(len(preds[i]) == 0 for i in range(1, n)):
        raise ValueError("schedule is not prefix-connected")

    # Restrictions (a, b): id(a) > id(b); enforce at max position.
    restr: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for (a, b) in res_set:
        pa, pb = pos[a], pos[b]
        if pa > pb:
            restr[pa].append((pb, +1))    # v_pa > v_pb
        else:
            restr[pb].append((pa, -1))    # v_pb < v_pa
    # != constraints for earlier non-neighbors (neighbors are != for free —
    # no self loops in the data graph).
    neqs = tuple(
        tuple(j for j in range(i) if j not in preds[i]) for i in range(n)
    )

    iep_plan = None
    divisor = 1
    if iep_k > 0:
        tail = list(range(n - iep_k, n))
        rel_adj = rel.adjacency()
        for a in tail:
            for b in tail:
                if a < b and rel_adj[a, b]:
                    raise ValueError(
                        f"IEP tail {tail} is not an independent set in the "
                        f"relabeled pattern"
                    )
        surviving = tuple(
            (a, b) for (a, b) in res_set if max(pos[a], pos[b]) < n - iep_k
        )
        divisor = iep_multiplicity(pattern, surviving)
        if divisor is None:
            raise IEPInvalidError(
                f"surviving restrictions {surviving} give a non-constant "
                f"per-subgraph multiplicity; IEP with k={iep_k} is unsound "
                f"for schedule {order}"
            )
        iep_plan = build_iep_plan([preds[t] for t in tail])
        restr = [r if i < n - iep_k else [] for i, r in enumerate(restr)]

    return MatchingPlan(
        pattern=pattern,
        order=tuple(order),
        n=n,
        preds=preds,
        neqs=neqs,
        restr=tuple(tuple(r) for r in restr),
        iep=iep_plan,
        iep_divisor=divisor,
        res_set=tuple(res_set),
        vlabels=rel.labels,
    )


# ----------------------------------------------------------- serialization
def plan_to_dict(plan: MatchingPlan) -> dict:
    """JSON-serializable record of a compiled plan.

    The full derived structure is persisted (not just the build_plan
    inputs) so `plan_from_dict` reconstructs the exact MatchingPlan
    without re-running the IEP soundness validation — the on-disk plan
    store's load path must stay O(read), and dataclass equality with
    the original plan is what the round-trip tests pin down.
    """
    out = {
        "pattern": plan.pattern.to_dict(),
        "order": list(plan.order),
        "n": int(plan.n),
        "preds": [list(p) for p in plan.preds],
        "neqs": [list(q) for q in plan.neqs],
        "restr": [[list(r) for r in level] for level in plan.restr],
        "iep": None if plan.iep is None else {
            "k": int(plan.iep.k),
            "unions": [list(u) for u in plan.iep.unions],
            "terms": [[int(c), list(idxs)] for c, idxs in plan.iep.terms],
        },
        "iep_divisor": int(plan.iep_divisor),
        "res_set": [list(r) for r in plan.res_set],
    }
    if plan.vlabels is not None:
        # v2 field; omitted for unlabeled plans so v1 records stay
        # byte-identical and keep loading.
        out["vlabels"] = list(plan.vlabels)
    return out


def plan_from_dict(d: dict) -> MatchingPlan:
    iep = None
    if d["iep"] is not None:
        iep = IEPPlan(
            k=int(d["iep"]["k"]),
            unions=tuple(tuple(int(q) for q in u)
                         for u in d["iep"]["unions"]),
            terms=tuple((int(c), tuple(int(i) for i in idxs))
                        for c, idxs in d["iep"]["terms"]),
        )
    return MatchingPlan(
        pattern=Pattern.from_dict(d["pattern"]),
        order=tuple(int(v) for v in d["order"]),
        n=int(d["n"]),
        preds=tuple(tuple(int(p) for p in ps) for ps in d["preds"]),
        neqs=tuple(tuple(int(q) for q in qs) for qs in d["neqs"]),
        restr=tuple(tuple((int(c), int(s)) for c, s in level)
                    for level in d["restr"]),
        iep=iep,
        iep_divisor=int(d["iep_divisor"]),
        res_set=tuple((int(a), int(b)) for a, b in d["res_set"]),
        vlabels=None if d.get("vlabels") is None else tuple(
            None if lab is None else int(lab) for lab in d["vlabels"]),
    )


def best_iep_k(
    pattern: Pattern, order: Schedule, res_set: Sequence[Restriction]
) -> int:
    """Largest SOUND k: tail independent AND constant multiplicity.

    Labeled patterns always get k=0 (see build_plan)."""
    if pattern.labels is not None:
        return 0
    pos = {v: i for i, v in enumerate(order)}
    n = pattern.n
    k = max_iep_k(pattern, order)
    while k >= 1:
        surviving = tuple(
            (a, b) for (a, b) in res_set if max(pos[a], pos[b]) < n - k
        )
        if iep_multiplicity(pattern, surviving) is not None:
            return k
        k -= 1
    return 0


def max_iep_k(pattern: Pattern, order: Schedule) -> int:
    """Largest k such that the last k scheduled vertices are pairwise
    non-adjacent (candidates for IEP folding).  0 for labeled patterns:
    IEP folding is unlabeled-only (see build_plan)."""
    if pattern.labels is not None:
        return 0
    rel = pattern.relabel(order).adjacency()
    n = pattern.n
    k = 1
    while k < n:
        tail = range(n - k - 1, n)
        ok = all(
            not rel[a, b]
            for a in tail
            for b in tail
            if a < b
        )
        if not ok:
            break
        k += 1
    return min(k, n - 1)  # keep at least one explicit loop
