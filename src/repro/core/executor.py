"""JAX vectorized pattern-matching executor.

TPU-native adaptation of GraphPi's nested-loop DFS (DESIGN.md §3):

 * level-synchronous frontier expansion — a dense [capacity, depth] matrix
   of partial embeddings is expanded one schedule position at a time;
 * candidate generation gathers a fixed-width window from the flat CSR
   `indices` array at the (dynamically chosen) minimum-degree predecessor;
 * ONE shared per-level admissibility core (`expand_core`) serves every
   path — bucketed and single-window expansion, the last-level popcount
   and the IEP-tail cardinalities.  On the Pallas path the whole level
   (membership against all predecessors + restriction + injectivity
   masks, reduced to a mask or an in-kernel popcount) is a single fused
   kernel pass over the candidate matrix; the portable path is a
   vectorized binary search over flat CSR segments plus XLA masks;
 * compaction is a cumsum scatter (stream compaction);
 * labeled plans prune candidates BEFORE membership: the window is
   gathered from the base predecessor's per-label CSR segment
   (graph.label_view), so only same-label candidates ever reach the
   membership intersection — identically on the portable and fused
   paths, which share the gather and differ only in membership;
 * the IEP tail is evaluated in closed form per surviving prefix;
 * distribution = `shard_map` over the mesh `data` axis with the paper's
   fine-grained outer-loop task striping (device d owns tasks d, d+P, ...).

Counts are exact int64 (x64 enabled locally inside the public entry
points; everything else in the framework pins its own dtypes).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import enable_x64, shard_map
from ..graph.csr import GraphCSR
from ..obs import get_tracer
from .pattern import Pattern, clique
from .perf_model import GraphStats
from .plan import MatchingPlan, build_plan
from .restrictions import generate_restriction_sets


# --------------------------------------------------------------------------
# low-level primitives
# --------------------------------------------------------------------------
def _segment_member(flat, lo, hi, target, iters: int):
    """Vectorized binary search: is `target` in sorted flat[lo:hi)?

    All of lo/hi/target may be arbitrary (broadcast-compatible) shapes.
    `iters` must be >= ceil(log2(max segment length)) + 1 (static).
    """
    shape = jnp.broadcast_shapes(lo.shape, hi.shape, target.shape)
    lo = jnp.broadcast_to(lo, shape)
    hi = jnp.broadcast_to(hi, shape)
    hi0 = hi

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        val = flat[mid]
        active = lo < hi
        go_right = active & (val < target)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    found = (lo < hi0) & (flat[jnp.minimum(lo, flat.shape[0] - 1)] == target)
    return found


def _bs_iters(max_degree: int) -> int:
    return max(1, math.ceil(math.log2(max(max_degree, 2))) + 1)


@dataclass(frozen=True)
class ExecutorConfig:
    capacity: int = 1 << 15          # frontier rows per level
    dynamic_base: bool = True        # per-row min-degree base predecessor
    # Fused Pallas level-expansion kernel (the TPU hot path).  None =
    # auto: True on TPU backends, False elsewhere — interpret-mode
    # Pallas is bit-exact but slow, so CPU/CI defaults to the portable
    # binary-search path; parity tests force True explicitly.
    use_pallas: bool | None = None
    # Degree-bucketed expansion (§Perf, graphpi cell): ((width, frac), ...)
    # ascending widths; rows whose base degree fits a narrower window are
    # compacted into a frac·capacity sub-frontier and gathered at that
    # width, so power-law max-degree padding is paid only by the rows
    # that need it.  None = single max-degree window (paper-faithful
    # baseline behaviour); internally that is the degenerate one-bucket
    # layout ((W, 1.0),) — both run the same expansion core.
    degree_buckets: tuple | None = None

    def resolve_use_pallas(self) -> bool:
        if self.use_pallas is None:
            return jax.default_backend() == "tpu"
        return self.use_pallas

    def fingerprint(self) -> str:
        """Stable string of the facets baked into a jitted count program
        (capacity, base selection, RESOLVED pallas path, bucket layout).
        Safe to persist: equal strings ⟺ the same compiled program
        modulo graph/plan, across processes and serving replicas."""
        buckets = "none" if self.degree_buckets is None else ";".join(
            f"{int(w)}:{float(f):.6g}" for w, f in self.degree_buckets)
        return (f"cap={self.capacity},dyn={int(self.dynamic_base)},"
                f"pallas={int(self.resolve_use_pallas())},"
                f"buckets={buckets}")


def auto_buckets(graph, *, small: int = 128, mid: int = 1024,
                 stats: GraphStats | None = None):
    """Degree buckets from the graph's degree distribution.

    Legacy layout (`stats=None`): fractions are sized ~4× above the
    empirical vertex-count shares so bucket overflow (→ capacity
    escalation) is rare — a blanket margin that over-allocates the tail
    buckets on most graphs.

    Model layout (`stats=GraphStats`): fractions come from the perf
    model's *predicted frontier occupancy*
    (`perf_model.predicted_frontier_occupancy`) — the edge-weighted
    share of rows whose base lands above each width threshold, times
    the model's clustering amplification (p2/p1, clamped).  Both
    layouts share the 1/64 floor and run the identical expansion core;
    the flag only moves capacity between buckets, never correctness
    (any layout counts exactly — tests/test_executor_buckets.py)."""
    W = max(graph.max_degree, 1)
    if W <= small:
        return None
    deg = graph.degrees

    if stats is not None:
        from .perf_model import predicted_frontier_occupancy

        def frac(lo: int) -> float:
            return min(1.0, max(
                predicted_frontier_occupancy(stats, deg, lo), 1 / 64))
    else:
        n = max(len(deg), 1)

        def frac(lo: int) -> float:
            return min(1.0, max(4.0 * float((deg > lo).sum()) / n, 1 / 64))

    out = [(small, 1.0)]
    if W > mid:
        out.append((mid, frac(small)))
        out.append((W, frac(mid)))
    else:
        out.append((W, frac(small)))
    return tuple(out)


@dataclass
class CountResult:
    count: int
    overflowed: bool
    max_needed: int                  # max frontier rows needed at any level


@dataclass
class CountState:
    """Resumable progress of one chunked count (`Matcher.count_partial`).

    The outer vertex loop is a work stack of ``(start, end, capacity)``
    spans; a preempted count is exactly this stack plus the raw running
    totals.  `total` is the RAW embedding sum — the IEP divisor (and the
    naive-mode |Aut| division, `CacheEntry.count_partial`) apply once at
    completion, so partial segments never lose remainder bits."""

    spans: list                      # [(start, end, capacity)], LIFO
    chunk: int                       # resolved chunk width (span rebuilds)
    total: int = 0                   # raw sum, pre iep_divisor
    overflowed: bool = False
    max_needed: int = 0
    dispatches: int = 0              # kernel dispatches so far (all segments)


# --------------------------------------------------------------------------
# single-shard counting kernel (pure function of device arrays; jit-safe)
# --------------------------------------------------------------------------
def _make_count_fn(plan: MatchingPlan, W: int, iters: int,
                   cfg: ExecutorConfig, *, level_cb=None):
    """Returns count(indptr, degrees, flat, v0) -> (count i64, needed i32)
    — or, for labeled plans, count(indptr, degrees, flat, labs, v0) where
    `labs` = (vlabels [n+1], lab_starts [n+1, L], lab_lens [n+1, L],
    lab_flat) are the device per-label CSR views (see `device_graph`).

    `W` = candidate-window width (graph max degree), static.
    `degrees` must be padded to [n+1] with 0 at index n (sentinel).

    `level_cb` (keyword-only) hooks per-level observability: when given,
    every schedule level runs as ``level_cb(i, thunk)`` where `thunk`
    computes that level's `expand_level` (or the IEP tail, `i="iep"`) —
    the callback wraps it in a span and may fence on the results.  Only
    meaningful on an EAGER (un-jitted) count fn: under jit the callback
    would fire once at trace time with abstract values, so the Matcher
    only routes here on the `--trace-sync` path.
    """
    n = plan.n
    depth = plan.depth
    C = cfg.capacity
    use_pallas = cfg.resolve_use_pallas()
    # Static per-position label requirements (None = wildcard / unlabeled).
    vlabels = plan.vlabels or (None,) * n

    # Normalized bucket layout; None collapses to the degenerate single
    # max-degree window so there is exactly ONE expansion path.
    buckets = cfg.degree_buckets
    if buckets is not None:
        buckets = tuple((min(int(w), W), float(f)) for (w, f) in buckets)
        if buckets[-1][0] < W:
            buckets = buckets + ((W, buckets[-1][1]),)
    else:
        buckets = ((W, 1.0),)

    def gather_window(flat, indptr, degrees, base, width, *, labs=None,
                      label=None):
        """Candidate window at `base`.  With a `label` requirement the
        window comes from the base row's per-label segment (lab_flat is
        grouped by destination label, each segment sorted by id), so the
        label mask is applied by construction BEFORE any membership test
        — the rows that reach the intersection kernels are already
        label-pruned."""
        if label is not None:
            _, lab_starts, lab_lens, lab_flat = labs
            start = lab_starts[base, label]
            cand = lab_flat[start[:, None]
                            + jnp.arange(width, dtype=start.dtype)[None, :]]
            ok = jnp.arange(width)[None, :] < lab_lens[base, label][:, None]
            return cand, ok
        start = indptr[base]
        cand = flat[start[:, None]
                    + jnp.arange(width, dtype=start.dtype)[None, :]]
        ok = jnp.arange(width)[None, :] < degrees[base][:, None]
        return cand, ok

    def base_degrees(degrees, pv, *, labs=None, label=None):
        """Candidate-set size per predecessor: the full degree, or the
        per-label segment length when the target position is labeled."""
        if label is not None:
            _, _, lab_lens, _ = labs
            return lab_lens[pv, label]
        return degrees[pv]

    def pick_base(emb, degrees, preds, *, labs=None, label=None):
        pv = emb[:, jnp.asarray(preds)]            # [C, P]
        if not cfg.dynamic_base or len(preds) == 1:
            return pv[:, -1]
        dg = base_degrees(degrees, pv, labs=labs, label=label)
        sel = jnp.argmin(dg, axis=1)
        return jnp.take_along_axis(pv, sel[:, None], axis=1)[:, 0]

    def level_extras(i):
        """Restriction + injectivity comparisons at loop position i as a
        uniform ((emb column, dir), ...) spec; dir ∈ {+1: >, -1: <, 0: !=}."""
        return tuple(plan.restr[i]) + tuple((j, 0) for j in plan.neqs[i])

    def expand_core(emb, base, valid, preds, extras,
                    indptr, degrees, flat, width, *, want_counts=False,
                    labs=None, label=None):
        """THE per-level admissibility core (shared by every path).

        Gathers the candidate window at `base`, tests membership in
        every predecessor neighborhood, and applies the restriction /
        injectivity comparisons.  Returns (cand, mask) — or per-row
        int32 counts when `want_counts` (last enumeration level and
        IEP-tail cardinalities).

        Pallas path: everything above is ONE fused kernel pass over the
        candidate matrix (kernels.intersect.level_expand_pallas), with
        the predecessor loop on the innermost grid dimension — one HBM
        round trip per level where the portable path does one compare /
        mask pass per predecessor, restriction, and != constraint.  The
        base's own membership test is redundant but keeps the kernel
        branch-free under the dynamic-base selection.

        Labeled positions change ONLY the gather (per-label segment of
        the base row); membership keeps walking the plain sorted rows on
        both paths, so portable and fused stay bit-identical.
        """
        cand, ok = gather_window(flat, indptr, degrees, base, width,
                                 labs=labs, label=label)
        mask = ok & valid[:, None]
        if use_pallas and len(preds) > 1:
            from ..kernels.ops import level_expand

            # the kernel gathers each predecessor's neighbor window from
            # the flat CSR array itself (scalar-prefetched offsets +
            # in-grid DMA) — nothing here materializes a [P, B, W] stack
            us = emb[:, jnp.asarray(preds)].T                      # [P, B]
            res = level_expand(
                cand, flat, indptr[us], degrees[us],
                emb[:, jnp.asarray([c for c, _ in extras])] if extras
                else None,
                mask,
                dirs=tuple(d for _, d in extras), count=want_counts,
                window=W, flat_padded=True,
            )
            return res if want_counts else (cand, res)
        if len(preds) > 1:
            # membership in every predecessor's neighborhood (the base's
            # own test is redundant but keeps the mask branch-free under
            # the dynamic-base selection)
            for p in preds:
                u = emb[:, p]
                lo = indptr[u][:, None]
                hi = lo + degrees[u][:, None]
                mask &= _segment_member(flat, lo, hi, cand, iters)
        for (col, d) in extras:
            ev = emb[:, col][:, None]
            if d > 0:
                mask &= cand > ev
            elif d < 0:
                mask &= cand < ev
            else:
                mask &= cand != ev
        if want_counts:
            return mask.sum(axis=1).astype(jnp.int32)
        return cand, mask

    def select_rows(rowmask, cap):
        """Compact indices of rows where rowmask → (sel_idx [cap] with C as
        the drop sentinel, sub_valid [cap], sub_total)."""
        pos = jnp.cumsum(rowmask) - 1
        total = (pos[-1] + 1).astype(jnp.int32)
        out_idx = jnp.where(rowmask, jnp.minimum(pos, cap), cap)
        sel = jnp.full((cap + 1,), C, dtype=jnp.int32)
        sel = sel.at[out_idx].set(jnp.arange(C, dtype=jnp.int32),
                                  mode="drop")
        sub_valid = jnp.arange(cap) < total
        return sel[:cap], sub_valid, total

    def scaled_need(sub_total, cap):
        """Escalation units: sub_total scaled to full-capacity terms so the
        driver's capacity doubling also doubles every bucket."""
        st = sub_total.astype(jnp.int64)
        return ((st * C + cap - 1) // cap).astype(jnp.int32)

    def bucket_ranges():
        lo = 0
        for bi, (w, f) in enumerate(buckets):
            cap = max(int(C * f), 8)
            yield bi, w, cap, lo, bi == len(buckets) - 1
            lo = w

    def expand_level(i, emb, valid, needed, indptr, degrees, flat,
                     labs=None):
        """One level of frontier expansion over the bucket layout.

        Returns (new_emb, new_valid, needed) — or, at the last
        enumeration level, (count_contribution, None, needed)."""
        preds = plan.preds[i]
        extras = level_extras(i)
        label = vlabels[i]
        base_all = pick_base(emb, degrees, preds, labs=labs, label=label)
        db = base_degrees(degrees, base_all, labs=labs, label=label)
        last_enum = (plan.iep is None) and (i == n - 1)
        parent = jnp.zeros((C + 1,), dtype=jnp.int32)
        newcol = jnp.zeros((C + 1,), dtype=jnp.int32)
        offset = jnp.asarray(0, jnp.int32)
        total_cnt = jnp.asarray(0, jnp.int64)
        for bi, width, cap, lo, is_last in bucket_ranges():
            rowmask = valid & (db > lo)
            if not is_last:
                rowmask &= db <= width
            sel_idx, sub_valid, sub_total = select_rows(rowmask, cap)
            needed = jnp.maximum(needed, scaled_need(sub_total, cap))
            sub_emb = jnp.take(emb, sel_idx, axis=0, mode="clip")[:, :i]
            sub_base = jnp.take(base_all, sel_idx, mode="clip")
            if last_enum:
                cnts = expand_core(
                    sub_emb, sub_base, sub_valid, preds, extras,
                    indptr, degrees, flat, width, want_counts=True,
                    labs=labs, label=label,
                )
                total_cnt += jnp.sum(cnts, dtype=jnp.int64)
                continue
            cand, mask = expand_core(
                sub_emb, sub_base, sub_valid, preds, extras,
                indptr, degrees, flat, width,
                labs=labs, label=label,
            )
            # stream-compact surviving (row, cand) pairs behind `offset`
            flat_mask = mask.reshape(-1)
            pos = jnp.cumsum(flat_mask) - 1
            bucket_total = (pos[-1] + 1).astype(jnp.int32)
            out_idx = jnp.where(flat_mask, jnp.minimum(offset + pos, C), C)
            rows_local = jnp.arange(cap * width, dtype=jnp.int32) // width
            parent = parent.at[out_idx].set(
                jnp.take(sel_idx, rows_local), mode="drop")
            newcol = newcol.at[out_idx].set(cand.reshape(-1), mode="drop")
            offset = offset + bucket_total
        if last_enum:
            return total_cnt, None, needed
        new_emb = jnp.concatenate(
            [jnp.take(emb, parent[:C], axis=0, mode="clip")[:, :i],
             newcol[:C, None]], axis=1,
        )
        new_valid = jnp.arange(C) < offset
        needed = jnp.maximum(needed, offset)
        return new_emb, new_valid, needed

    def iep_card_fused(sub_emb, sub_base, sub_valid, U,
                       indptr, degrees, flat, width):
        """One IEP-term cardinality — |window ∩ (∩_q N(v_q))| minus the
        prefix-vertex corrections — in a SINGLE fused kernel pass: the
        already-assigned prefix vertices ride along as negatively-
        weighted candidate columns (`neg_from`), so the kernel's signed
        popcount returns raw − corr directly (DESIGN.md §4) instead of
        one binary-search sweep per prefix position."""
        from ..kernels.ops import level_expand

        cand, ok = gather_window(flat, indptr, degrees, sub_base, width)
        comb = jnp.concatenate([cand, sub_emb], axis=1)
        cvalid = jnp.concatenate(
            [ok & sub_valid[:, None],
             jnp.broadcast_to(sub_valid[:, None], sub_emb.shape)], axis=1)
        us = sub_emb[:, jnp.asarray(U)].T                          # [P, B]
        signed = level_expand(
            comb, flat, indptr[us], degrees[us], None, cvalid,
            dirs=(), count=True, neg_from=width,
            window=W, flat_padded=True,
        )
        return signed.astype(jnp.int64)

    def iep_value(emb, valid, indptr, degrees, flat):
        """Per-row IEP count over the folded tail (int64), with bucketed
        union-window gathers through the shared expansion core.  On the
        Pallas path each (union, bucket) cardinality — including the
        prefix corrections — is one fused kernel pass."""
        iep = plan.iep
        cards = []
        needed_extra = jnp.asarray(0, jnp.int32)
        for U in iep.unions:
            base = pick_base(emb, degrees, U)
            db = degrees[base]
            card = jnp.zeros((C,), jnp.int64)
            for bi, width, cap, lo, is_last in bucket_ranges():
                rowmask = valid & (db > lo)
                if not is_last:
                    rowmask &= db <= width
                sel_idx, sub_valid, sub_total = select_rows(rowmask, cap)
                needed_extra = jnp.maximum(needed_extra,
                                           scaled_need(sub_total, cap))
                sub_emb = jnp.take(emb, sel_idx, axis=0, mode="clip")
                sub_base = jnp.take(base, sel_idx, mode="clip")
                if use_pallas:
                    val = iep_card_fused(
                        sub_emb, sub_base, sub_valid, U,
                        indptr, degrees, flat, width)
                else:
                    raw = expand_core(
                        sub_emb, sub_base, sub_valid, U, (),
                        indptr, degrees, flat, width, want_counts=True,
                    ).astype(jnp.int64)
                    # subtract already-assigned prefix vertices inside
                    # the intersection (injectivity w.r.t. outer loops)
                    corr = jnp.zeros_like(raw)
                    for j in range(depth):
                        vj = sub_emb[:, j]
                        inside = sub_valid
                        for q in U:
                            u = sub_emb[:, q]
                            inside &= _segment_member(
                                flat, indptr[u], indptr[u] + degrees[u],
                                vj, iters
                            )
                        corr += inside.astype(jnp.int64)
                    val = raw - corr
                card = card.at[sel_idx].add(
                    jnp.where(sub_valid, val, 0), mode="drop")
            cards.append(card)
        val = jnp.zeros((C,), dtype=jnp.int64)
        for coeff, idxs in iep.terms:
            term = jnp.full((C,), coeff, dtype=jnp.int64)
            for u in idxs:
                term = term * cards[u]
            val = val + term
        return jnp.where(valid, val, 0), needed_extra

    def count_impl(indptr, degrees, flat, labs, v0):
        emb = v0[:, None].astype(jnp.int32)                    # [T, 1]
        valid = v0 < (indptr.shape[0] - 1)
        if vlabels[0] is not None:
            # root label mask: v0 is padded with the sentinel n, and the
            # device vlabels array carries -1 there, so sentinels never
            # match a real label
            valid &= labs[0][v0] == vlabels[0]
        # pad/crop the initial frontier to capacity C
        T = emb.shape[0]
        if T < C:
            emb = jnp.pad(emb, ((0, C - T), (0, 0)))
            valid = jnp.pad(valid, (0, C - T))
        needed = jnp.asarray(T, dtype=jnp.int32)
        for i in range(1, depth):
            thunk = partial(expand_level, i, emb, valid, needed,
                            indptr, degrees, flat, labs)
            out, new_valid, needed = (
                thunk() if level_cb is None else level_cb(i, thunk))
            if new_valid is None:          # last enumeration level
                return out, needed
            emb, valid = out, new_valid
        if plan.iep is None:
            # depth-1 == 0: single-vertex pattern — count valid v0 rows
            return jnp.sum(valid, dtype=jnp.int64), needed
        iep_thunk = partial(iep_value, emb, valid, indptr, degrees, flat)
        vals, need2 = (iep_thunk() if level_cb is None
                       else level_cb("iep", iep_thunk))
        return jnp.sum(vals), jnp.maximum(needed, need2)

    if plan.vlabels is None:
        # unlabeled plans keep the historical 4-arg signature (AOT blobs,
        # shard_map specs, dryrun all depend on it)
        def count(indptr, degrees, flat, v0):
            return count_impl(indptr, degrees, flat, None, v0)
        return count

    def count_labeled(indptr, degrees, flat, labs, v0):
        return count_impl(indptr, degrees, flat, labs, v0)
    return count_labeled


# --------------------------------------------------------------------------
# public host-side drivers
# --------------------------------------------------------------------------
class DeviceGraph(NamedTuple):
    """Resident device arrays for one graph.  The first three fields are
    the historical (indptr, padded degrees, flat) triple; `labs` is the
    per-label view pytree (vlabels, lab_starts, lab_lens, lab_flat) for
    labeled graphs, or None."""

    indptr: object
    degrees: object
    flat: object
    labs: object = None


def device_graph(graph: GraphCSR) -> DeviceGraph:
    """Upload one graph to device memory (indptr, padded degrees, flat).

    Matchers accept the returned tuple via ``arrays=`` so long-lived
    callers (the query engine) keep ONE resident copy of the CSR shared
    by every cached matcher instead of re-uploading per pattern.

    The flat indices array is padded ONCE here with never-matching
    sentinels so the fused kernel's in-grid window DMAs (bounded by the
    row-extent + DMA-skip invariant — DESIGN.md §4) stay in bounds;
    every kernel call then passes ``flat_padded=True`` instead of
    re-padding the resident graph per call.

    Labeled graphs additionally upload the per-label CSR view: vlabels
    padded to [n+1] with -1 (the frontier's sentinel root n never
    matches a label), lab_starts/lab_lens padded with an all-empty row n
    for the same reason.  lab_flat only ever feeds host-side gathers —
    never the kernel's DMAs — so it needs no extra sentinel pad beyond
    the max-degree pad the CSR build already applies."""
    from ..kernels.ops import flat_gather_pad

    degrees = np.concatenate([graph.degrees, np.zeros(1, dtype=np.int32)])
    flat = np.concatenate([
        graph.indices,
        np.full(flat_gather_pad(), np.iinfo(np.int32).max, dtype=np.int32),
    ])
    labs = None
    if graph.labels is not None:
        lv = graph.label_view
        L = graph.n_labels
        vlabels = np.concatenate(
            [graph.labels, np.full(1, -1, dtype=np.int32)])
        lab_starts = np.concatenate(
            [lv.starts, np.zeros((1, L), dtype=np.int32)])
        lab_lens = np.concatenate(
            [lv.lens, np.zeros((1, L), dtype=np.int32)])
        labs = (
            jnp.asarray(vlabels),
            jnp.asarray(lab_starts),
            jnp.asarray(lab_lens),
            jnp.asarray(lv.flat),
        )
    return DeviceGraph(
        jnp.asarray(graph.indptr),
        jnp.asarray(degrees),
        jnp.asarray(flat),
        labs,
    )


def _labs_of(arrays):
    """Label-view pytree of a DeviceGraph (None for legacy 3-tuples)."""
    return arrays[3] if len(arrays) > 3 else None


class Matcher:
    """Reusable single-device matcher: compile once, count many times.

    Benchmarks construct one Matcher per configuration and call
    ``warmup()`` before timing so compile time never pollutes the
    measurement (the paper excludes compilation time too)."""

    MAX_CAPACITY = 1 << 22   # escalation ceiling (frontier RAM bound)

    def __init__(self, graph: GraphCSR, plan: MatchingPlan,
                 cfg: ExecutorConfig | None = None, *, arrays=None):
        self.graph = graph
        self.plan = plan
        self.cfg = cfg or ExecutorConfig()
        self._W = max(graph.max_degree, 1)
        self._fns: dict[int, object] = {}     # capacity -> jitted count_fn
        self._traced_fns: dict[int, object] = {}  # eager --trace-sync twins
        self._arrays = arrays if arrays is not None else device_graph(graph)
        self._labeled = plan.vlabels is not None
        if self._labeled and _labs_of(self._arrays) is None:
            raise ValueError(
                f"labeled pattern {plan.pattern.name!r} cannot run against "
                f"unlabeled graph {graph.name!r}")
        self._capacity = self.cfg.capacity    # sticky escalated capacity

    def _call_args(self):
        """Positional args ahead of v0 — labeled plans append the label
        views so the jitted signature matches _make_count_fn."""
        indptr, degrees, flat = self._arrays[:3]
        if self._labeled:
            return (indptr, degrees, flat, _labs_of(self._arrays))
        return (indptr, degrees, flat)

    def _fn(self, capacity: int):
        if capacity not in self._fns:
            self._fns[capacity] = jax.jit(_make_count_fn(
                self.plan, self._W, _bs_iters(self._W),
                replace(self.cfg, capacity=capacity),
            ))
        return self._fns[capacity]

    def _level_cb(self, i, thunk):
        """`--trace-sync` per-level hook: one `executor.level` span per
        schedule position, fenced with block_until_ready so the span
        duration is real device time, annotated with the surviving
        frontier size and the level's capacity demand."""
        with get_tracer().span("executor.level", level=i) as sp:
            out = thunk()
            jax.block_until_ready(out)
            if isinstance(out, tuple) and len(out) == 3:
                _, new_valid, needed = out
                sp.set(needed=int(needed))
                if new_valid is not None:
                    sp.set(frontier=int(new_valid.sum()))
        return out

    def _traced_fn(self, capacity: int):
        """Eager (un-jitted) twin of :meth:`_fn` with the per-level span
        hook — dispatched only when the tracer asks for device-fenced
        levels (`--trace-sync`): per-level spans are impossible inside
        one jitted program, and the fencing serializes the pipeline, so
        this path must never be the default."""
        if capacity not in self._traced_fns:
            self._traced_fns[capacity] = _make_count_fn(
                self.plan, self._W, _bs_iters(self._W),
                replace(self.cfg, capacity=capacity),
                level_cb=self._level_cb,
            )
        return self._traced_fns[capacity]

    def warmup(self, *, chunk: int | None = None) -> None:
        """Compile against a sentinel frontier.  Pass the same `chunk`
        later given to :meth:`count`, or the trace compiled here (v0
        shape = chunk width) is not the one counting will use."""
        width = min(chunk or self.cfg.capacity, self.cfg.capacity)
        v0 = jnp.full((width,), self.graph.n, dtype=jnp.int32)
        with enable_x64(True):
            jax.block_until_ready(
                self._fn(self.cfg.capacity)(*self._call_args(), v0))

    # --------------------------------------------------- AOT persistence
    def export_bytes(self, *, chunk: int | None = None) -> bytes:
        """Serialize the base-capacity count program ahead-of-time
        (`jax.export` over the same (capacity, chunk-width) trace that
        :meth:`warmup` compiles).  A fresh process feeds the bytes to
        :meth:`install_exported` and skips Python re-tracing entirely;
        escalated capacities still JIT live (they are rare retry paths).
        """
        from ..compat import jax_export

        if jax_export is None:
            raise RuntimeError("jax.export unavailable on this JAX version")
        width = min(chunk or self.cfg.capacity, self.cfg.capacity)
        v0 = jnp.full((width,), self.graph.n, dtype=jnp.int32)
        with enable_x64(True):
            exported = jax_export.export(self._fn(self.cfg.capacity))(
                *self._call_args(), v0)
        return exported.serialize()

    def install_exported(self, data: bytes, *,
                         chunk: int | None = None) -> None:
        """Install a serialized AOT program as the base-capacity count
        fn.  Raises ValueError when the blob targets another platform or
        was traced against different array shapes — callers catch it and
        fall back to a fresh :meth:`warmup` JIT."""
        from ..compat import jax_export

        if jax_export is None:
            raise ValueError("jax.export unavailable on this JAX version")
        exported = jax_export.deserialize(data)
        backend = jax.default_backend()
        if backend not in exported.platforms:
            raise ValueError(
                f"AOT program exported for {exported.platforms}, running "
                f"on {backend!r}")
        width = min(chunk or self.cfg.capacity, self.cfg.capacity)
        v0 = jax.ShapeDtypeStruct((width,), jnp.int32)
        want = tuple(
            tuple(a.shape)
            for a in jax.tree_util.tree_leaves((*self._call_args(), v0))
        )
        got = tuple(tuple(a.shape) for a in exported.in_avals)
        if got != want:
            raise ValueError(f"AOT input shapes {got} != expected {want}")
        self._fns[self.cfg.capacity] = jax.jit(exported.call)

    def release(self) -> None:
        """Drop every compiled executable and device-array reference so
        LRU eviction actually frees HBM in long-lived serving processes
        (the resident graph shared via ``arrays=`` stays alive at its
        owner).  The matcher is unusable afterwards."""
        self._fns.clear()
        self._traced_fns.clear()
        self._arrays = None

    def rebind(self, arrays, *, graph=None) -> None:
        """Swap the resident device arrays for same-shaped replacements
        (a live-overlay epoch or compaction swap, src/repro/live/).

        Compiled count programs take the graph arrays as ARGUMENTS, so a
        same-shape swap replays every cached jit/AOT trace untouched —
        zero recompiles.  Any shape or dtype difference raises
        ValueError (the overlay genuinely grew); the caller must rebuild
        the matcher instead.  `graph` additionally swaps the host-side
        view, and must preserve the compiled gather window and vertex
        count (both are baked into the traces via `_W` and v0 padding).
        """
        if self._arrays is None:
            raise RuntimeError("matcher was released (evicted from cache)")
        old = jax.tree_util.tree_leaves(tuple(self._arrays))
        new = jax.tree_util.tree_leaves(tuple(arrays))
        if (len(old) != len(new)
                or any(tuple(a.shape) != tuple(b.shape) or a.dtype != b.dtype
                       for a, b in zip(old, new))):
            raise ValueError(
                "rebind needs identical array shapes/dtypes; the graph "
                "outgrew its fixed layout — rebuild the matcher")
        if graph is not None:
            if max(graph.max_degree, 1) != self._W:
                raise ValueError(
                    f"rebind window {max(graph.max_degree, 1)} != compiled "
                    f"window {self._W}")
            if graph.n != self.graph.n:
                raise ValueError(
                    f"rebind vertex count {graph.n} != {self.graph.n}")
            self.graph = graph
        self._arrays = arrays

    def count(self, *, chunk: int | None = None) -> CountResult:
        """Chunked outer loop; a chunk that overflows capacity is bisected
        and retried (host-side adaptivity — the SPMD analogue of the
        paper's work splitting).  A single root that still overflows
        escalates to a doubled-capacity kernel so the count stays exact."""
        _, out = self.count_partial(chunk=chunk)
        return out

    def count_partial(self, state: CountState | None = None, *,
                      chunk: int | None = None,
                      max_dispatches: int | None = None,
                      ) -> tuple[CountState, CountResult | None]:
        """Run the chunked outer loop for up to `max_dispatches` kernel
        dispatches, then yield.  Returns ``(state, result)`` where
        `result` is None while spans remain — pass `state` back in to
        resume exactly where the loop stopped (same span stack, same
        raw totals; the final count is bit-identical to an
        uninterrupted :meth:`count`).  `max_dispatches=None` runs to
        completion (the exact :meth:`count` loop)."""
        if self._arrays is None:
            raise RuntimeError("matcher was released (evicted from cache)")
        graph, cfg = self.graph, self.cfg
        call_args = self._call_args()
        tr = get_tracer()
        # per-level device fencing is strictly opt-in (tracer.sync =
        # --trace-sync): the eager twin serializes the dispatch pipeline
        trace_sync = tr.enabled and tr.sync
        if state is None:
            chunk = min(chunk or cfg.capacity, cfg.capacity)
            # spans: (start, end, capacity).  Start at the last count's
            # escalated capacity so warm repeats (the serve path) skip
            # the doomed undersized passes.
            cap0 = self._capacity
            state = CountState(
                spans=[(s, min(s + chunk, graph.n), cap0)
                       for s in range(0, graph.n, chunk)],
                chunk=chunk,
            )
        chunk = state.chunk
        budget = None if max_dispatches is None else max(int(max_dispatches),
                                                         1)
        with enable_x64(True), tr.span(
                "executor.count", depth=self.plan.depth,
                buckets=cfg.fingerprint(), sync=trace_sync,
                resumed=state.dispatches > 0) as csp:
            spans = state.spans
            segment = 0
            while spans and (budget is None or segment < budget):
                s, e, cap = spans.pop()
                self._capacity = max(self._capacity, cap)
                width = min(chunk, cap)
                with tr.span("executor.dispatch", v0_start=s, v0_end=e,
                             capacity=cap, frontier=e - s) as dsp:
                    v0 = jnp.arange(s, e, dtype=jnp.int32)
                    if e - s < width:
                        v0 = jnp.pad(v0, (0, width - (e - s)),
                                     constant_values=graph.n)
                    # fn resolution inside the span: a cold capacity
                    # (escalation) compiles here, attributed to this
                    # dispatch
                    fn = (self._traced_fn(cap) if trace_sync
                          else self._fn(cap))
                    cnt, needed = fn(*call_args, v0)
                    # int() blocks until the device result is ready, so
                    # the dispatch span always covers real compute time
                    needed = int(needed)
                    dsp.set(needed=needed)
                segment += 1
                state.dispatches += 1
                state.max_needed = max(state.max_needed, needed)
                if needed > cap:
                    if e - s > 1:
                        mid = (s + e) // 2
                        spans += [(s, mid, cap), (mid, e, cap)]
                    elif cap < self.MAX_CAPACITY:
                        spans.append((s, e, cap * 2))   # escalate
                    else:
                        state.overflowed = True  # cannot split/grow further
                        state.total += int(cnt)
                    continue
                state.total += int(cnt)
            csp.set(dispatches=segment, max_needed=state.max_needed,
                    preempted=bool(spans))
        if spans:
            return state, None
        return state, CountResult(count=state.total // self.plan.iep_divisor,
                                  overflowed=state.overflowed,
                                  max_needed=state.max_needed)


def count_embeddings(
    graph: GraphCSR,
    plan: MatchingPlan,
    cfg: ExecutorConfig | None = None,
    *,
    chunk: int | None = None,
) -> CountResult:
    """One-shot convenience wrapper around :class:`Matcher`."""
    return Matcher(graph, plan, cfg).count(chunk=chunk)


class ShardedMatcher:
    """Reusable multi-device matcher: compile once per capacity, count many.

    Distributed counting with outer-loop tasks striped over `axis`:
    device d takes v0 ∈ {d, d+P, ...} (fine-grained striping — DESIGN §3);
    with degree-descending relabeling this balances the power-law head.
    Each device scans its stripe in fixed-size chunks; if any chunk's
    frontier exceeds capacity, the whole pass is retried at doubled
    capacity (straggler-free SPMD analogue of the single-device
    bisection — every retry is a fresh collective-complete program).

    The jitted shard_map program is cached per capacity, so repeat
    counts (the serve path) pay zero compilation."""

    def __init__(self, graph: GraphCSR, plan: MatchingPlan, mesh,
                 *, axis: str = "data", cfg: ExecutorConfig | None = None,
                 chunk: int | None = None, arrays=None):
        self.graph = graph
        self.plan = plan
        self.mesh = mesh
        self.axis = axis
        self.cfg = cfg or ExecutorConfig()
        self._W = max(graph.max_degree, 1)
        self._iters = _bs_iters(self._W)
        self._arrays = arrays if arrays is not None else device_graph(graph)
        self._labeled = plan.vlabels is not None
        if self._labeled and _labs_of(self._arrays) is None:
            raise ValueError(
                f"labeled pattern {plan.pattern.name!r} cannot run against "
                f"unlabeled graph {graph.name!r}")
        self.chunk = chunk or max(64, self.cfg.capacity // 16)
        nshards = 1
        for ax in (axis,) if isinstance(axis, str) else axis:
            nshards *= mesh.shape[ax]
        per = math.ceil(graph.n / nshards)
        per = math.ceil(per / self.chunk) * self.chunk  # pad to chunk multiple
        self._per = per
        # striped: column-major so device d gets d, d+P, 2P+d, ...
        v0 = np.full(nshards * per, graph.n, dtype=np.int32)
        v0[: graph.n] = np.arange(graph.n, dtype=np.int32)
        self._v0 = jnp.asarray(v0.reshape(per, nshards).T.reshape(-1))
        self._fns: dict[int, object] = {}     # capacity -> jitted shard fn
        self._capacity = self.cfg.capacity    # sticky escalated capacity

    def _call_args(self):
        indptr, degrees, flat = self._arrays[:3]
        return (indptr, degrees, flat, _labs_of(self._arrays))

    def _fn(self, capacity: int):
        if capacity not in self._fns:
            from jax.sharding import PartitionSpec as P

            count_fn = _make_count_fn(
                self.plan, self._W, self._iters,
                replace(self.cfg, capacity=capacity),
            )
            per, chunk, axis = self._per, self.chunk, self.axis
            labeled = self._labeled

            def shard_fn(indptr, degrees, flat, labs, v0_local):
                chunks = v0_local.reshape(per // chunk, chunk)

                def body(carry, v0c):
                    tot, mx = carry
                    if labeled:
                        cnt, needed = count_fn(indptr, degrees, flat,
                                               labs, v0c)
                    else:
                        cnt, needed = count_fn(indptr, degrees, flat, v0c)
                    return (tot + cnt, jnp.maximum(mx, needed)), ()

                init = (jnp.zeros((), jnp.int64), jnp.zeros((), jnp.int32))
                (tot, mx), _ = jax.lax.scan(body, init, chunks)
                return jax.lax.psum(tot, axis), jax.lax.pmax(mx, axis)

            self._fns[capacity] = jax.jit(
                shard_map(
                    shard_fn,
                    # P() is a pytree PREFIX for the labs tuple: the label
                    # views are replicated like the CSR arrays
                    mesh=self.mesh,
                    in_specs=(P(), P(), P(), P(), P(axis)),
                    out_specs=(P(), P()),
                    check_vma=False,
                )
            )
        return self._fns[capacity]

    def warmup(self) -> None:
        # all-sentinel frontier: compiles the program without doing the
        # real count (mirrors Matcher.warmup)
        v0 = jnp.full_like(self._v0, self.graph.n)
        with enable_x64(True):
            jax.block_until_ready(
                self._fn(self.cfg.capacity)(*self._call_args(), v0))

    def release(self) -> None:
        """Mirror of :meth:`Matcher.release` — also drops the striped-v0
        device array this matcher privately owns."""
        self._fns.clear()
        self._arrays = None
        self._v0 = None

    def rebind(self, arrays, *, graph=None) -> None:
        """Mirror of :meth:`Matcher.rebind` — the striped v0 layout
        depends only on `n` (unchanged by overlay epochs), so swapping
        the CSR arrays replays the cached shard_map programs as-is."""
        if self._arrays is None:
            raise RuntimeError("matcher was released (evicted from cache)")
        old = jax.tree_util.tree_leaves(tuple(self._arrays))
        new = jax.tree_util.tree_leaves(tuple(arrays))
        if (len(old) != len(new)
                or any(tuple(a.shape) != tuple(b.shape) or a.dtype != b.dtype
                       for a, b in zip(old, new))):
            raise ValueError(
                "rebind needs identical array shapes/dtypes; the graph "
                "outgrew its fixed layout — rebuild the matcher")
        if graph is not None:
            if max(graph.max_degree, 1) != self._W:
                raise ValueError(
                    f"rebind window {max(graph.max_degree, 1)} != compiled "
                    f"window {self._W}")
            if graph.n != self.graph.n:
                raise ValueError(
                    f"rebind vertex count {graph.n} != {self.graph.n}")
            self.graph = graph
        self._arrays = arrays

    def count(self) -> CountResult:
        if self._arrays is None:
            raise RuntimeError("matcher was released (evicted from cache)")
        call_args = self._call_args()
        tr = get_tracer()
        # start from the last successful capacity so warm repeats skip
        # the doomed undersized passes, not just their compilation
        capacity = self._capacity
        with tr.span("executor.count", depth=self.plan.depth,
                     sharded=True, chunk=self.chunk) as csp:
            while True:
                with enable_x64(True), tr.span(
                        "executor.dispatch", capacity=capacity,
                        frontier=int(self._v0.shape[0])) as dsp:
                    cnt, needed = self._fn(capacity)(*call_args, self._v0)
                    needed = int(needed)
                    dsp.set(needed=needed)
                if needed <= capacity or capacity >= Matcher.MAX_CAPACITY:
                    break
                while capacity < min(needed, Matcher.MAX_CAPACITY):
                    capacity *= 2
            csp.set(max_needed=needed, capacity=capacity)
        self._capacity = capacity
        return CountResult(
            count=int(cnt) // self.plan.iep_divisor,
            overflowed=needed > capacity,
            max_needed=needed,
        )


def count_embeddings_sharded(
    graph: GraphCSR,
    plan: MatchingPlan,
    mesh,
    *,
    axis: str = "data",
    cfg: ExecutorConfig | None = None,
    chunk: int | None = None,
) -> CountResult:
    """One-shot convenience wrapper around :class:`ShardedMatcher`."""
    return ShardedMatcher(
        graph, plan, mesh, axis=axis, cfg=cfg, chunk=chunk
    ).count()


# --------------------------------------------------------------------------
# graph statistics (bootstraps the performance model with the executor)
# --------------------------------------------------------------------------
def triangle_plan() -> MatchingPlan:
    tri = clique(3)
    rs = generate_restriction_sets(tri, max_sets=1)[0]
    return build_plan(tri, (0, 1, 2), rs)


def compute_stats(
    graph: GraphCSR, cfg: ExecutorConfig | None = None
) -> GraphStats:
    """|V|, |E| and exact triangle count (counted by the system itself)."""
    tri = count_embeddings(graph, triangle_plan(), cfg)
    return GraphStats(
        n_vertices=graph.n, n_edges=graph.m, tri_cnt=tri.count
    )
