"""Pattern representation and automorphism (permutation) group.

A pattern is a small undirected graph (n <= 8 in practice), optionally
vertex-labeled: ``labels[v]`` is the label id vertex v must match in the
data graph, or None for a wildcard position.  Labels shrink the
automorphism group to the label-preserving subgroup, so labeled patterns
need fewer (or equal) symmetry-breaking restrictions than their
unlabeled skeletons.  All plan-time machinery here is pure Python/numpy
— the paper does the same (Table III: preprocessing is milliseconds).
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

Edge = tuple[int, int]
Perm = tuple[int, ...]


@dataclass(frozen=True)
class Pattern:
    """An undirected pattern graph on vertices 0..n-1."""

    n: int
    edges: tuple[Edge, ...]
    name: str = ""
    labels: tuple[int | None, ...] | None = None

    def __post_init__(self) -> None:
        seen = set()
        for (u, v) in self.edges:
            if not (0 <= u < self.n and 0 <= v < self.n):
                raise ValueError(f"edge {(u, v)} out of range for n={self.n}")
            if u == v:
                raise ValueError(f"self-loop {(u, v)} not allowed")
            key = (min(u, v), max(u, v))
            if key in seen:
                raise ValueError(f"duplicate edge {(u, v)}")
            seen.add(key)
        # Canonicalize edge ordering.
        object.__setattr__(
            self, "edges", tuple(sorted((min(u, v), max(u, v)) for u, v in self.edges))
        )
        if self.labels is not None:
            if len(self.labels) != self.n:
                raise ValueError(
                    f"labels has {len(self.labels)} entries for n={self.n}"
                )
            norm = []
            for lab in self.labels:
                if lab is None:
                    norm.append(None)
                    continue
                lab = int(lab)
                if lab < 0:
                    raise ValueError(f"label {lab} must be >= 0")
                norm.append(lab)
            # All-wildcard is the unlabeled pattern: normalize so the two
            # spellings share one canonical key / cache entry / store digest.
            if all(lab is None for lab in norm):
                object.__setattr__(self, "labels", None)
            else:
                object.__setattr__(self, "labels", tuple(norm))

    # ---------------------------------------------------------------- helpers
    @property
    def m(self) -> int:
        return len(self.edges)

    def adjacency(self) -> np.ndarray:
        adj = np.zeros((self.n, self.n), dtype=bool)
        for u, v in self.edges:
            adj[u, v] = adj[v, u] = True
        return adj

    def neighbors(self, v: int) -> tuple[int, ...]:
        adj = self.adjacency()
        return tuple(int(u) for u in np.nonzero(adj[v])[0])

    def degree(self, v: int) -> int:
        return len(self.neighbors(v))

    def is_connected(self) -> bool:
        if self.n == 0:
            return True
        adj = self.adjacency()
        seen = {0}
        stack = [0]
        while stack:
            u = stack.pop()
            for w in np.nonzero(adj[u])[0]:
                w = int(w)
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        return len(seen) == self.n

    def is_labeled(self) -> bool:
        return self.labels is not None

    def skeleton(self) -> "Pattern":
        """The unlabeled pattern with the same edges (identity if unlabeled)."""
        if self.labels is None:
            return self
        return Pattern(self.n, self.edges, name=self.name)

    def with_labels(self, labels: Sequence[int | None] | None) -> "Pattern":
        return Pattern(self.n, self.edges, name=self.name,
                       labels=None if labels is None else tuple(labels))

    # ----------------------------------------------------------- group theory
    def automorphisms(self) -> list[Perm]:
        """All permutations p with (u,v) in E  <=>  (p[u],p[v]) in E,
        restricted to the label-preserving subgroup when labeled
        (labels[p[v]] == labels[v] for every v, wildcards included).

        Brute force over n! — fine for pattern sizes (n<=8 → 40320).
        Cached per pattern: Algorithm 1's K_n validation calls this at
        every leaf of its search tree.
        """
        return list(_automorphisms_cached(self))

    def aut_count(self) -> int:
        return len(self.automorphisms())

    def max_independent_set_size(self) -> int:
        """k = size of the largest set of pairwise non-adjacent vertices."""
        adj = self.adjacency()
        best = 0
        for mask in range(1 << self.n):
            verts = [i for i in range(self.n) if mask >> i & 1]
            if len(verts) <= best:
                continue
            if all(not adj[a, b] for a, b in itertools.combinations(verts, 2)):
                best = len(verts)
        return best

    def relabel(self, order: Sequence[int]) -> "Pattern":
        """Relabel so that order[i] becomes vertex i (i.e. schedule-major)."""
        pos = {v: i for i, v in enumerate(order)}
        edges = tuple((pos[u], pos[v]) for u, v in self.edges)
        labels = None
        if self.labels is not None:
            out: list[int | None] = [None] * self.n
            for v, lab in enumerate(self.labels):
                out[pos[v]] = lab
            labels = tuple(out)
        return Pattern(self.n, edges, name=self.name, labels=labels)

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        """JSON-serializable record; `from_dict` round-trips exactly.

        The "labels" key is emitted only for labeled patterns so unlabeled
        records are byte-identical to the pre-label (store v1) encoding.
        """
        d = {"n": self.n, "edges": [list(e) for e in self.edges],
             "name": self.name}
        if self.labels is not None:
            d["labels"] = list(self.labels)
        return d

    @staticmethod
    def from_dict(d: dict) -> "Pattern":
        labels = d.get("labels")
        return Pattern(int(d["n"]),
                       tuple((int(u), int(v)) for u, v in d["edges"]),
                       name=str(d.get("name", "")),
                       labels=None if labels is None else tuple(
                           None if lab is None else int(lab) for lab in labels))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        lab = f", labels={list(self.labels)}" if self.labels is not None else ""
        return (f"Pattern({self.name or 'anon'}, n={self.n}, "
                f"edges={list(self.edges)}{lab})")


@functools.lru_cache(maxsize=1024)
def _automorphisms_cached(pattern: "Pattern") -> tuple[Perm, ...]:
    adj = pattern.adjacency()
    labels = pattern.labels
    auts: list[Perm] = []
    for p in itertools.permutations(range(pattern.n)):
        ok = True
        if labels is not None:
            for v in range(pattern.n):
                if labels[p[v]] != labels[v]:
                    ok = False
                    break
        if ok:
            for u, v in pattern.edges:
                if not adj[p[u], p[v]]:
                    ok = False
                    break
        if ok:
            auts.append(tuple(p))
    return tuple(auts)


# --------------------------------------------------------------- cycle algebra
def perm_to_cycles(p: Perm) -> list[tuple[int, ...]]:
    """Disjoint-cycle decomposition of a permutation."""
    seen = [False] * len(p)
    cycles = []
    for start in range(len(p)):
        if seen[start]:
            continue
        cyc = [start]
        seen[start] = True
        nxt = p[start]
        while nxt != start:
            cyc.append(nxt)
            seen[nxt] = True
            nxt = p[nxt]
        cycles.append(tuple(cyc))
    return cycles


@functools.lru_cache(maxsize=65536)
def two_cycles_of(p: Perm) -> list[tuple[int, int]]:
    """All 2-cycles (u, p[u]) with p[p[u]] == u and p[u] != u.

    This is the paper's line-11 test `vertex == perm[perm[vertex]]`.
    """
    out = []
    for u in range(len(p)):
        v = p[u]
        if v != u and p[v] == u and u < v:
            out.append((u, v))
    return out


def identity_perm(n: int) -> Perm:
    return tuple(range(n))


# ------------------------------------------------------------ pattern library
def clique(n: int, name: str | None = None) -> Pattern:
    return Pattern(n, tuple(itertools.combinations(range(n), 2)), name or f"clique{n}")


def cycle(n: int, name: str | None = None) -> Pattern:
    return Pattern(n, tuple((i, (i + 1) % n) for i in range(n)), name or f"cycle{n}")


def path(n: int, name: str | None = None) -> Pattern:
    return Pattern(n, tuple((i, i + 1) for i in range(n - 1)), name or f"path{n}")


def star(n: int, name: str | None = None) -> Pattern:
    return Pattern(n, tuple((0, i) for i in range(1, n)), name or f"star{n}")


def house() -> Pattern:
    """House (Fig. 5a): square 0-1-2-3 plus roof apex 4 on edge (0,1).

    |Aut| = 2 (mirror symmetry).
    """
    return Pattern(5, ((0, 1), (1, 2), (2, 3), (0, 3), (0, 4), (1, 4)), "house")


def rectangle() -> Pattern:
    """4-cycle (Fig. 4a)."""
    return cycle(4, "rectangle")


def triangle() -> Pattern:
    return clique(3, "triangle")
