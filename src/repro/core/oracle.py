"""Pure-Python reference matcher (the `ref` oracle for every JAX path).

Recursive DFS exactly like the paper's generated nested loops — slow, but
obviously correct.  Used by unit/property tests and validation only.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .pattern import Pattern
from .plan import MatchingPlan


def _adj_sets(n: int, edges: np.ndarray) -> list[set[int]]:
    adj: list[set[int]] = [set() for _ in range(n)]
    for u, v in edges:
        u, v = int(u), int(v)
        if u == v:
            continue
        adj[u].add(v)
        adj[v].add(u)
    return adj


def count_injective_maps(
    n_vertices: int, edges: np.ndarray, pattern: Pattern
) -> int:
    """#injective maps pattern→graph preserving pattern edges.

    Equals (#embeddings) × |Aut(pattern)|.
    """
    adj = _adj_sets(n_vertices, edges)
    padj = pattern.adjacency()
    n = pattern.n
    assigned = [-1] * n
    used: set[int] = set()
    count = 0

    def rec(i: int) -> None:
        nonlocal count
        if i == n:
            count += 1
            return
        # candidates: any vertex adjacent to all already-assigned neighbors
        earlier = [j for j in range(i) if padj[i, j]]
        if earlier:
            cand = set(adj[assigned[earlier[0]]])
            for j in earlier[1:]:
                cand &= adj[assigned[j]]
        else:
            cand = set(range(n_vertices))
        for c in sorted(cand):
            if c in used:
                continue
            assigned[i] = c
            used.add(c)
            rec(i + 1)
            used.remove(c)
        assigned[i] = -1

    rec(0)
    return count


def count_with_plan(
    n_vertices: int, edges: np.ndarray, plan: MatchingPlan
) -> int:
    """Reference execution of a MatchingPlan (restrictions honored,
    enumeration only — IEP tail, if any, is enumerated explicitly and must
    produce plan.iep_divisor × the IEP count)."""
    adj = _adj_sets(n_vertices, edges)
    n = plan.n
    assigned = [-1] * n
    used: set[int] = set()
    count = 0
    # For reference purposes we always enumerate all n levels with the
    # PREFIX restrictions only (restrictions the IEP path keeps).
    restr = plan.restr

    def rec(i: int) -> None:
        nonlocal count
        if i == n:
            count += 1
            return
        preds = plan.preds[i]
        if preds:
            cand = set(adj[assigned[preds[0]]])
            for j in preds[1:]:
                cand &= adj[assigned[j]]
        else:
            cand = set(range(n_vertices))
        for c in sorted(cand):
            if c in used:
                continue
            ok = True
            for (other, d) in restr[i]:
                if d > 0 and not (c > assigned[other]):
                    ok = False
                    break
                if d < 0 and not (c < assigned[other]):
                    ok = False
                    break
            if not ok:
                continue
            assigned[i] = c
            used.add(c)
            rec(i + 1)
            used.remove(c)
        assigned[i] = -1

    rec(0)
    return count


def count_embeddings_oracle(
    n_vertices: int, edges: np.ndarray, pattern: Pattern
) -> int:
    """#distinct embeddings (subgraphs) = injective maps / |Aut|."""
    maps = count_injective_maps(n_vertices, edges, pattern)
    aut = pattern.aut_count()
    assert maps % aut == 0, (maps, aut)
    return maps // aut
