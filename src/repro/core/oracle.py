"""Pure-Python reference matcher (the `ref` oracle for every JAX path).

Recursive DFS exactly like the paper's generated nested loops — slow, but
obviously correct.  Used by unit/property tests and validation only.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .pattern import Pattern
from .plan import MatchingPlan


def _adj_sets(n: int, edges: np.ndarray) -> list[set[int]]:
    adj: list[set[int]] = [set() for _ in range(n)]
    for u, v in edges:
        u, v = int(u), int(v)
        if u == v:
            continue
        adj[u].add(v)
        adj[v].add(u)
    return adj


def count_injective_maps(
    n_vertices: int,
    edges: np.ndarray,
    pattern: Pattern,
    labels: Sequence[int] | np.ndarray | None = None,
) -> int:
    """#injective maps pattern→graph preserving pattern edges — and, for
    labeled patterns, mapping each labeled pattern vertex onto a data
    vertex of the same label (`labels` is the data graph's per-vertex
    label array; wildcard pattern positions match anything).

    Equals (#embeddings) × |Aut(pattern)| — label-preserving
    automorphisms when the pattern is labeled.
    """
    if pattern.labels is not None and labels is None:
        raise ValueError(
            f"labeled pattern {pattern.name!r} needs data-graph labels")
    adj = _adj_sets(n_vertices, edges)
    padj = pattern.adjacency()
    plabels = pattern.labels
    n = pattern.n
    assigned = [-1] * n
    used: set[int] = set()
    count = 0

    def label_ok(i: int, c: int) -> bool:
        if plabels is None or plabels[i] is None:
            return True
        return int(labels[c]) == plabels[i]

    def rec(i: int) -> None:
        nonlocal count
        if i == n:
            count += 1
            return
        # candidates: any vertex adjacent to all already-assigned neighbors
        earlier = [j for j in range(i) if padj[i, j]]
        if earlier:
            cand = set(adj[assigned[earlier[0]]])
            for j in earlier[1:]:
                cand &= adj[assigned[j]]
        else:
            cand = set(range(n_vertices))
        for c in sorted(cand):
            if c in used or not label_ok(i, c):
                continue
            assigned[i] = c
            used.add(c)
            rec(i + 1)
            used.remove(c)
        assigned[i] = -1

    rec(0)
    return count


def count_with_plan(
    n_vertices: int,
    edges: np.ndarray,
    plan: MatchingPlan,
    labels: Sequence[int] | np.ndarray | None = None,
) -> int:
    """Reference execution of a MatchingPlan (restrictions honored,
    enumeration only — IEP tail, if any, is enumerated explicitly and must
    produce plan.iep_divisor × the IEP count).  Labeled plans also honor
    plan.vlabels against the data graph's `labels` array."""
    if plan.vlabels is not None and labels is None:
        raise ValueError("labeled plan needs data-graph labels")
    adj = _adj_sets(n_vertices, edges)
    vlabels = plan.vlabels
    n = plan.n
    assigned = [-1] * n
    used: set[int] = set()
    count = 0
    # For reference purposes we always enumerate all n levels with the
    # PREFIX restrictions only (restrictions the IEP path keeps).
    restr = plan.restr

    def rec(i: int) -> None:
        nonlocal count
        if i == n:
            count += 1
            return
        preds = plan.preds[i]
        if preds:
            cand = set(adj[assigned[preds[0]]])
            for j in preds[1:]:
                cand &= adj[assigned[j]]
        else:
            cand = set(range(n_vertices))
        for c in sorted(cand):
            if c in used:
                continue
            if (vlabels is not None and vlabels[i] is not None
                    and int(labels[c]) != vlabels[i]):
                continue
            ok = True
            for (other, d) in restr[i]:
                if d > 0 and not (c > assigned[other]):
                    ok = False
                    break
                if d < 0 and not (c < assigned[other]):
                    ok = False
                    break
            if not ok:
                continue
            assigned[i] = c
            used.add(c)
            rec(i + 1)
            used.remove(c)
        assigned[i] = -1

    rec(0)
    return count


def count_embeddings_oracle(
    n_vertices: int,
    edges: np.ndarray,
    pattern: Pattern,
    labels: Sequence[int] | np.ndarray | None = None,
) -> int:
    """#distinct embeddings (subgraphs) = injective maps / |Aut|.

    For labeled patterns |Aut| is the label-preserving subgroup and the
    injective maps are label-constrained, so the quotient is the number
    of distinct LABELED subgraph instances."""
    maps = count_injective_maps(n_vertices, edges, pattern, labels=labels)
    aut = pattern.aut_count()
    assert maps % aut == 0, (maps, aut)
    return maps // aut
