"""Counting with the Inclusion–Exclusion Principle (paper §IV-D, Alg. 2).

The innermost k loops traverse candidate sets S_1..S_k of pairwise
non-adjacent pattern vertices.  The number of ways to pick pairwise
DISTINCT (e_1..e_k), e_i ∈ S_i, is by inclusion–exclusion over the pair
collisions A_{i,j}.  Algorithm 2 factors every term over connected
components; aggregating all 2^(k(k-1)/2) pair-subsets that induce the same
component structure collapses the sum onto the partition lattice with
Möbius coefficients:

    |S_IEP| = Σ_{partitions P of {1..k}}  Π_{block B ∈ P} (-1)^{|B|-1} (|B|-1)!  ·  |∩_{i∈B} S_i|

(For k=2 this is |S1||S2| - |S1∩S2|; for k=3 the Π-coefficients give the
paper's +2 |S1∩S2∩S3| term.)  This is mathematically identical to the
paper's expansion but with Bell(k) terms instead of 2^(k(k-1)/2).

Each S_i is itself an intersection of data-graph neighborhoods (one per
pattern-predecessor of tail vertex i), so a block's intersection is the
intersection of the UNION of the predecessor sets — we deduplicate those
unions so the executor computes each distinct multi-way intersection once.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence


def set_partitions(items: Sequence[int]):
    """Yield all partitions of `items` as lists of tuples."""
    items = list(items)
    if not items:
        yield []
        return
    first, rest = items[0], items[1:]
    for part in set_partitions(rest):
        # put `first` in its own block
        yield [(first,)] + part
        # or into each existing block
        for i in range(len(part)):
            yield part[:i] + [tuple((first,) + part[i])] + part[i + 1 :]


def bell_number(k: int) -> int:
    return sum(1 for _ in set_partitions(range(k)))


@dataclass(frozen=True)
class IEPPlan:
    """Static expansion used by the executor at the deepest surviving loop.

    unions:       distinct tuples of PREFIX loop positions; the executor
                  computes card_u = |∩_{q ∈ unions[u]} N(v_q)| (minus
                  already-used vertices lying in that intersection).
    terms:        (coeff, block_union_indices) — one per set partition;
                  value = coeff * Π_u card_{u}.
    k:            number of tail (IEP-folded) vertices.
    """

    k: int
    unions: tuple[tuple[int, ...], ...]
    terms: tuple[tuple[int, tuple[int, ...]], ...]


def build_iep_plan(tail_preds: Sequence[Sequence[int]]) -> IEPPlan:
    """tail_preds[i] = prefix loop positions feeding tail vertex i's
    candidate set S_i (i in 0..k-1)."""
    k = len(tail_preds)
    unions: list[tuple[int, ...]] = []
    union_index: dict[tuple[int, ...], int] = {}

    def intern(u: tuple[int, ...]) -> int:
        if u not in union_index:
            union_index[u] = len(unions)
            unions.append(u)
        return union_index[u]

    terms: list[tuple[int, tuple[int, ...]]] = []
    for part in set_partitions(range(k)):
        coeff = 1
        idxs = []
        for block in part:
            b = len(block)
            coeff *= (-1) ** (b - 1) * math.factorial(b - 1)
            merged = sorted(set(q for t in block for q in tail_preds[t]))
            idxs.append(intern(tuple(merged)))
        terms.append((coeff, tuple(sorted(idxs))))
    return IEPPlan(k=k, unions=tuple(unions), terms=tuple(terms))
