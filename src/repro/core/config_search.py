"""Configuration selection: enumerate (schedule × restriction-set)
candidates, rank them with the performance model, return the best plan.

This is the paper's `configuration generation + performance prediction`
stage (Fig. 3) — all plan-time.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Sequence

from .pattern import Pattern
from .perf_model import GraphStats, predict_cost
from .plan import MatchingPlan, best_iep_k, build_plan, max_iep_k
from .restrictions import RestrictionSet, generate_restriction_sets
from .schedule import Schedule, generate_schedules


@dataclass(frozen=True)
class Configuration:
    order: Schedule
    res_set: RestrictionSet
    iep_k: int
    predicted_cost: float


def config_to_dict(config: Configuration) -> dict:
    """JSON-serializable record of a searched configuration.

    `config_from_dict(config_to_dict(c)) == c` exactly (dataclass
    equality), including after a JSON round trip — the on-disk plan
    store (query/store.py) persists these so a replica restart replays
    the search result instead of re-ranking the configuration space.
    """
    return {
        "order": list(config.order),
        "res_set": [list(r) for r in config.res_set],
        "iep_k": int(config.iep_k),
        "predicted_cost": float(config.predicted_cost),
    }


def config_from_dict(d: dict) -> Configuration:
    return Configuration(
        order=tuple(int(v) for v in d["order"]),
        res_set=tuple((int(a), int(b)) for a, b in d["res_set"]),
        iep_k=int(d["iep_k"]),
        predicted_cost=float(d["predicted_cost"]),
    )


@dataclass
class SearchResult:
    best: Configuration
    all_configs: list[Configuration]
    n_schedules: int
    n_restriction_sets: int
    preprocess_seconds: float

    def plan(self, pattern: Pattern) -> MatchingPlan:
        return build_plan(
            pattern, self.best.order, self.best.res_set, iep_k=self.best.iep_k
        )


def search_configuration(
    pattern: Pattern,
    stats: GraphStats,
    *,
    use_iep: bool = False,
    max_restriction_sets: int | None = 64,
    max_schedules: int | None = None,
) -> SearchResult:
    """Rank every configuration with the cost model; pick the cheapest."""
    t0 = time.perf_counter()
    schedules = generate_schedules(pattern)
    if max_schedules is not None:
        schedules = schedules[:max_schedules]
    res_sets = generate_restriction_sets(pattern, max_sets=max_restriction_sets)
    if not res_sets:
        raise RuntimeError(f"no restriction sets for {pattern!r}")

    configs: list[Configuration] = []
    for order in schedules:
        for rs in res_sets:
            ks = {0}
            if use_iep:
                ks.add(best_iep_k(pattern, order, rs))
            for k in sorted(ks):
                cost = predict_cost(pattern, order, rs, stats, iep_k=k)
                configs.append(Configuration(order, rs, k, cost))
    configs.sort(key=lambda c: c.predicted_cost)
    return SearchResult(
        best=configs[0],
        all_configs=configs,
        n_schedules=len(schedules),
        n_restriction_sets=len(res_sets),
        preprocess_seconds=time.perf_counter() - t0,
    )


def graphzero_configuration(
    pattern: Pattern, stats: GraphStats, *, use_iep: bool = False
) -> Configuration:
    """Baseline emulating GraphZero: a single canonical restriction set and
    a degree-heuristic schedule (no data-aware cost model over sets).

    GraphZero orders vertices by (degree, connectivity) greedily and emits
    one symmetry-breaking set; we reproduce that flavour: schedule = the
    prefix-connected order that greedily maximizes (#connections to prefix,
    degree), restriction set = first set from Algorithm 1's DFS.
    """
    adj = pattern.adjacency()
    order: list[int] = []
    remaining = set(range(pattern.n))
    # seed: max-degree vertex
    order.append(max(remaining, key=lambda v: int(adj[v].sum())))
    remaining.remove(order[0])
    while remaining:
        nxt = max(
            remaining,
            key=lambda v: (
                sum(1 for u in order if adj[v, u]),
                int(adj[v].sum()),
            ),
        )
        # keep prefix-connectivity if at all possible
        connected = [v for v in remaining if any(adj[v, u] for u in order)]
        if connected:
            nxt = max(
                connected,
                key=lambda v: (
                    sum(1 for u in order if adj[v, u]),
                    int(adj[v].sum()),
                ),
            )
        order.append(nxt)
        remaining.remove(nxt)
    res_sets = generate_restriction_sets(pattern, max_sets=1)
    rs = res_sets[0]
    k = best_iep_k(pattern, tuple(order), rs) if use_iep else 0
    cost = predict_cost(pattern, tuple(order), rs, stats, iep_k=k)
    return Configuration(tuple(order), rs, k, cost)
