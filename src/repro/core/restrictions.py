"""Algorithm 1 — 2-cycle based automorphism elimination.

Generates MULTIPLE sets of partial-order restrictions, each of which
reduces the automorphism count of a pattern to exactly one.  A
restriction is a pair (a, b) meaning ``id(a) > id(b)`` (ids are data-graph
vertex ids of the embedding).

For labeled patterns `pattern.automorphisms()` is already the
label-preserving subgroup, so everything below transparently breaks the
SMALLER group: the completeness target becomes n!/|Aut_label| and the
generated sets carry fewer (or equal) restrictions than the unlabeled
skeleton's.  A pattern whose labels kill all symmetry yields the empty
restriction set.

This is plan-time code (pure Python); the paper reports 8ms..2.5s for
patterns up to size 7 (Table III) and ours is in the same ballpark.
"""
from __future__ import annotations

import functools
import itertools
import math
from typing import Sequence

import numpy as np

from .pattern import Pattern, Perm, identity_perm, two_cycles_of

Restriction = tuple[int, int]  # (a, b)  ==  id(a) > id(b)
RestrictionSet = tuple[Restriction, ...]


@functools.lru_cache(maxsize=16)
def perm_matrix(n: int) -> np.ndarray:
    """All n! permutations as an (n!, n) int8 matrix (cached; n <= 8)."""
    return np.array(list(itertools.permutations(range(n))), dtype=np.int8)


def _acyclic_masks(n: int, succ: list[int]) -> bool:
    """Is the digraph given by successor bitmasks a DAG? (bitmask Kahn —
    this is the innermost call of Algorithm 1's search, so it avoids all
    per-node allocations)."""
    indeg = [0] * n
    for v in range(n):
        m = succ[v]
        while m:
            w = (m & -m).bit_length() - 1
            indeg[w] += 1
            m &= m - 1
    stack = [v for v in range(n) if indeg[v] == 0]
    seen = 0
    while stack:
        v = stack.pop()
        seen += 1
        m = succ[v]
        while m:
            w = (m & -m).bit_length() - 1
            indeg[w] -= 1
            if indeg[w] == 0:
                stack.append(w)
            m &= m - 1
    return seen == n


def _acyclic(n: int, edges: set[tuple[int, int]]) -> bool:
    """Is the directed graph on n vertices with `edges` a DAG?"""
    succ = [0] * n
    for a, b in edges:
        succ[a] |= 1 << b
    return _acyclic_masks(n, succ)


def no_conflict(perm: Perm, res_set: Sequence[Restriction]) -> bool:
    """True iff `perm` is NOT eliminated by `res_set` (paper's no_conflict).

    For each restriction (a,b) [id(a) > id(b)] add directed edges a->b and
    perm[a]->perm[b]; perm survives iff the graph stays acyclic.
    """
    n = len(perm)
    succ = [0] * n
    for a, b in res_set:
        succ[a] |= 1 << b
        succ[perm[a]] |= 1 << perm[b]
    return _acyclic_masks(n, succ)


def surviving_perms(
    perms: Sequence[Perm], res_set: Sequence[Restriction]
) -> list[Perm]:
    return [p for p in perms if no_conflict(p, res_set)]


def count_orders_satisfying(n: int, res_set: Sequence[Restriction]) -> int:
    """#permutations of (0..n-1) id-assignments satisfying all id(a)>id(b).

    Used by `validate`: pattern matching on K_n finds exactly this many
    embeddings when the restrictions are applied.  (Vectorized — this is
    on the hot path of Algorithm 1's leaf checks.)
    """
    perms = perm_matrix(n)
    ok = np.ones(len(perms), dtype=bool)
    for a, b in res_set:
        ok &= perms[:, a] > perms[:, b]
    return int(ok.sum())


def validate(pattern: Pattern, res_set: Sequence[Restriction]) -> bool:
    """Paper's validate(): run on K_n with and without restrictions.

    On K_n every injective assignment is an embedding, so
    ans_without = n! and correctness requires
    ans_with == n! / |Aut(pattern)|.
    """
    n = pattern.n
    auts = pattern.automorphisms()
    n_fact = 1
    for i in range(2, n + 1):
        n_fact *= i
    if n_fact % len(auts) != 0:  # Lagrange guarantees this never trips.
        return False
    return count_orders_satisfying(n, res_set) == n_fact // len(auts)


@functools.lru_cache(maxsize=256)
def generate_restriction_sets(
    pattern: Pattern, *, validate_sets: bool = True, max_sets: int | None = None
) -> list[RestrictionSet]:
    """Algorithm 1: all distinct restriction sets that kill every non-identity
    automorphism.

    Branches over which 2-cycle to break at each step, deduplicates by the
    frozen set of restrictions, and (optionally) verifies each candidate via
    the K_n validation from the paper.  Memoized per (pattern, flags): the
    benchmarks re-enter this for the same pattern many times.
    """
    auts = pattern.automorphisms()
    ident = identity_perm(pattern.n)
    n_fact = math.factorial(pattern.n)
    target = n_fact // len(auts)            # orders a COMPLETE set must keep
    results: list[RestrictionSet] = []
    seen_sets: set[frozenset[Restriction]] = set()
    # Memoize on (surviving-group, restriction-set) to prune repeated states.
    visited_states: set[tuple[frozenset[Perm], frozenset[Restriction]]] = set()

    def generate(pg: list[Perm], res_set: tuple[Restriction, ...]) -> None:
        if max_sets is not None and len(results) >= max_sets:
            return
        if len(pg) <= 1:
            key = frozenset(res_set)
            if key in seen_sets:
                return
            # The monotone prune below guarantees count == target here, so
            # the paper's K_n validation can only confirm; keep it as the
            # safety net the paper prescribes (it is cheap, vectorized).
            if validate_sets and not validate(pattern, res_set):
                return
            seen_sets.add(key)
            results.append(tuple(sorted(res_set)))
            return
        state = (frozenset(pg), frozenset(res_set))
        if state in visited_states:
            return
        visited_states.add(state)
        tried: set[tuple[int, int]] = set()
        for perm in pg:
            if perm == ident:
                continue
            for (u, v) in two_cycles_of(perm):
                for pair in ((u, v), (v, u)):  # both orientations are valid
                    if pair in tried:
                        continue
                    tried.add(pair)
                    new_set = res_set + (pair,)
                    # Monotone prune: adding restrictions only shrinks the
                    # set of surviving id-orders, and a complete set keeps
                    # exactly n!/|Aut| of them — if we are already below
                    # the target no extension can be valid.
                    if count_orders_satisfying(pattern.n, new_set) < target:
                        continue
                    remaining = [p for p in pg if no_conflict(p, new_set)]
                    # new_set must at least kill `perm` itself; identity
                    # always survives.
                    if len(remaining) < len(pg):
                        generate(remaining, new_set)

    generate(list(auts), ())
    # Prefer smaller sets first, then lexicographic for determinism.
    results.sort(key=lambda rs: (len(rs), rs))
    return results


def first_restriction_set(pattern: Pattern) -> RestrictionSet:
    """A single canonical set — this is what a GraphZero-style system gets.

    GraphZero generates exactly one set; we emulate it by taking the first
    set found by a deterministic DFS over Algorithm 1's branch tree (no
    performance-model selection among sets).
    """
    sets = generate_restriction_sets(pattern, max_sets=1)
    if not sets:
        raise RuntimeError(f"no restriction set found for {pattern!r}")
    return sets[0]


def restrictions_checkable_positions(
    res_set: Sequence[Restriction], order: Sequence[int]
) -> dict[int, list[Restriction]]:
    """Map loop position -> restrictions checkable there under `order`.

    A restriction (a,b) can be enforced at the loop of whichever of a/b is
    searched LAST in the schedule.
    """
    pos = {v: i for i, v in enumerate(order)}
    out: dict[int, list[Restriction]] = {}
    for (a, b) in res_set:
        p = max(pos[a], pos[b])
        out.setdefault(p, []).append((a, b))
    return out
