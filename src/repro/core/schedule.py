"""2-phase computation-avoid schedule generation (paper §IV-B).

A schedule is an order (permutation of pattern vertices) in which the
matching loops assign vertices.  Of the n! candidates we keep only:

  Phase 1: prefix-connected orders — the i-th vertex must be adjacent (in
           the pattern) to at least one of the first i-1.
  Phase 2: orders whose last k vertices are pairwise non-adjacent, where
           k is the size of the pattern's maximum independent set.
"""
from __future__ import annotations

import itertools
from typing import Iterator, Sequence

import numpy as np

from .pattern import Pattern

Schedule = tuple[int, ...]


def is_prefix_connected(pattern: Pattern, order: Sequence[int]) -> bool:
    adj = pattern.adjacency()
    for i in range(1, len(order)):
        if not any(adj[order[i], order[j]] for j in range(i)):
            return False
    return True


def last_k_independent(pattern: Pattern, order: Sequence[int], k: int) -> bool:
    adj = pattern.adjacency()
    tail = order[len(order) - k :]
    return all(
        not adj[a, b] for a, b in itertools.combinations(tail, 2)
    )


def generate_schedules(
    pattern: Pattern, *, apply_phase2: bool = True
) -> list[Schedule]:
    """All efficient schedules after the 2-phase filter.

    Generation is a DFS that only extends prefix-connected orders (instead
    of filtering all n! post-hoc), then phase 2 prunes by the independent-
    set tail rule.
    """
    n = pattern.n
    adj = pattern.adjacency()
    k = pattern.max_independent_set_size() if apply_phase2 else 0
    out: list[Schedule] = []

    def extend(order: list[int], used: set[int]) -> None:
        if len(order) == n:
            out.append(tuple(order))
            return
        for v in range(n):
            if v in used:
                continue
            if order and not any(adj[v, u] for u in order):
                continue  # phase 1: must connect to the prefix
            order.append(v)
            used.add(v)
            extend(order, used)
            order.pop()
            used.remove(v)

    extend([], set())
    if apply_phase2:
        # Phase 2 can conflict with phase 1 (e.g. the 4-cycle: no prefix-
        # connected order ends in its only independent pair), so relax k
        # until schedules survive — k=1 imposes nothing.
        while k >= 2:
            kept = [o for o in out if last_k_independent(pattern, o, k)]
            if kept:
                return kept
            k -= 1
    return out


def all_schedules(pattern: Pattern) -> list[Schedule]:
    """Every permutation — used for evaluation figures (Fig. 9)."""
    return [tuple(p) for p in itertools.permutations(range(pattern.n))]


def predecessors(pattern: Pattern, order: Sequence[int]) -> list[list[int]]:
    """For each loop position i: positions j < i whose vertex is adjacent
    to order[i] in the pattern.  These define the candidate-set intersection
    for loop i."""
    adj = pattern.adjacency()
    preds: list[list[int]] = []
    for i, v in enumerate(order):
        preds.append([j for j in range(i) if adj[v, order[j]]])
    return preds
