"""Accurate performance prediction model (paper §IV-C).

cost_i = l_i * (1 - f_i) * (c_i + cost_{i+1})      for 1 <= i <= n-1
cost_n = l_n * (1 - f_n)

 - l_i : candidate-set cardinality of the vertex searched at loop i,
         estimated from graph statistics:
             l_1            = |V|
             one neighborhood  = |V| * p1          (= 2|E|/|V|, avg degree)
             m neighborhoods   = |V| * p1 * p2^(m-1)
         with p1 = 2|E|/|V|^2 and p2 = tri_cnt*|V| / (2|E|)^2.
 - f_i : probability a partial embedding is filtered by the restrictions
         enforced at loop i; computed EXACTLY by streaming the n! relative
         orders through the restrictions in loop order (vectorized numpy).
 - c_i : merge-intersection work attributed to loop i.  Matching the
         generated nested-loop code, the partial intersection for a vertex
         with predecessor positions q1<q2<...<qm is extended at each qj
         (j>=2) at cost  card(∩ of j-1 nbhds) + card(single nbhd).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .pattern import Pattern
from .restrictions import Restriction, restrictions_checkable_positions
from .schedule import Schedule, predecessors


@dataclass(frozen=True)
class GraphStats:
    """Data-graph statistics the model needs (paper: |V|, |E|, tri_cnt)."""

    n_vertices: int
    n_edges: int  # undirected edge count
    tri_cnt: int  # number of triangles

    @property
    def p1(self) -> float:
        return 2.0 * self.n_edges / max(self.n_vertices, 1) ** 2

    @property
    def p2(self) -> float:
        if self.n_edges == 0:
            return 0.0
        return self.tri_cnt * self.n_vertices / float(2.0 * self.n_edges) ** 2

    @property
    def avg_degree(self) -> float:
        return 2.0 * self.n_edges / max(self.n_vertices, 1)


def predicted_frontier_occupancy(
    stats: GraphStats, degrees, threshold: int
) -> float:
    """Predicted fraction of frontier rows whose base degree > threshold.

    Bucket sizing input for `executor.auto_buckets(stats=...)`.  At any
    loop depth ≥ 1 a frontier row binds its base vertex by traversing an
    edge into it, so under the model's uniform-traversal assumption
    P(base = v) ∝ deg(v): the occupancy of the degree range above
    `threshold` is its EDGE-weighted share, not the vertex-count share
    the 4×-margin heuristic padded.  Clustering concentrates frontiers
    on the head further — restriction-surviving rows preferentially sit
    inside closed wedges — which the model bounds with the p2/p1 ratio
    (how much likelier two neighbors of a common vertex are adjacent
    than a random pair), clamped to [1, 4] so pathological triangle
    counts cannot blow the layout up past the legacy margin."""
    deg = np.asarray(degrees, dtype=np.int64)
    total = float(deg.sum())
    if total <= 0:
        return 0.0
    share = float(deg[deg > threshold].sum()) / total
    amp = 1.0 if stats.p1 <= 0 else min(max(stats.p2 / stats.p1, 1.0), 4.0)
    return min(share * amp, 1.0)


def intersection_cardinality(stats: GraphStats, m: int) -> float:
    """Expected |N(v1) ∩ ... ∩ N(vm)|;  m=0 means the full vertex set."""
    if m == 0:
        return float(stats.n_vertices)
    return stats.n_vertices * stats.p1 * stats.p2 ** (m - 1)


def filter_probabilities(
    n: int, res_set: Sequence[Restriction], order: Schedule
) -> list[float]:
    """f_i per loop (0-indexed list of length n), computed exactly.

    Streams all n! relative-magnitude assignments through the restrictions
    in the order the generated code would check them.
    """
    from .restrictions import perm_matrix

    perms = perm_matrix(n)
    # column v of `perms` is id(v) for that assignment
    alive = np.ones(len(perms), dtype=bool)
    by_pos = restrictions_checkable_positions(res_set, order)
    f = [0.0] * n
    for i in range(n):
        if i not in by_pos:
            continue
        mask = np.ones(len(perms), dtype=bool)
        for (a, b) in by_pos[i]:
            mask &= perms[:, a] > perms[:, b]
        before = int(alive.sum())
        alive &= mask
        after = int(alive.sum())
        f[i] = 0.0 if before == 0 else (before - after) / before
    return f


def loop_cardinalities(
    pattern: Pattern, order: Schedule, stats: GraphStats
) -> list[float]:
    """l_i per loop position (0-indexed)."""
    preds = predecessors(pattern, order)
    return [intersection_cardinality(stats, len(p)) for p in preds]


def intersection_costs(
    pattern: Pattern, order: Schedule, stats: GraphStats
) -> list[float]:
    """c_i per loop position: merge work performed inside loop i.

    For a vertex at position p with predecessor positions q1<...<qm, the
    generated code extends its partial intersection at each qj (j >= 2);
    the extension at qj costs card(∩ j-1) + card(1) merge steps
    (sorted-merge is O(n+m)).  Loops with a single predecessor reuse N(v)
    directly (no merge cost) — same as the paper's example where
    c2 = |N(v_A)| + |N(v_B)| for the first real intersection.
    """
    n = pattern.n
    preds = predecessors(pattern, order)
    c = [0.0] * n
    for p in range(n):
        qs = preds[p]
        for j in range(1, len(qs)):
            at = qs[j]  # extension happens right after vertex at qs[j] binds
            c[at] += intersection_cardinality(stats, j) + intersection_cardinality(
                stats, 1
            )
    return c


def predict_cost(
    pattern: Pattern,
    order: Schedule,
    res_set: Sequence[Restriction],
    stats: GraphStats,
    *,
    iep_k: int = 0,
) -> float:
    """Total predicted cost of a configuration (schedule × restriction set).

    With iep_k > 0 the innermost iep_k loops are replaced by an IEP
    evaluation: their traversal cost collapses into a per-(n-k)-prefix
    term-evaluation cost (a fixed number of merge intersections).
    """
    n = pattern.n
    l = loop_cardinalities(pattern, order, stats)
    c = intersection_costs(pattern, order, stats)
    f = filter_probabilities(n, res_set, order)

    last = n - iep_k if iep_k > 0 else n
    if iep_k > 0:
        # Cost of evaluating all IEP terms for one prefix.  The executor
        # aggregates onto the partition lattice (Bell(k) terms) and computes
        # each distinct neighborhood-union intersection once; bound the
        # merge work by k single-neighborhood merges per term.
        from .iep import bell_number

        n_terms = float(bell_number(iep_k))
        iep_eval = n_terms * iep_k * intersection_cardinality(stats, 1)
    else:
        iep_eval = 0.0

    cost = 0.0
    for i in reversed(range(last)):
        if i == last - 1:
            # Innermost surviving loop: paper's base case l_n*(1-f_n); when
            # IEP replaces the tail, each surviving prefix additionally pays
            # the term-evaluation cost.
            cost = l[i] * (1.0 - f[i]) * (1.0 + c[i] + iep_eval)
        else:
            cost = l[i] * (1.0 - f[i]) * (c[i] + cost)
    return cost
