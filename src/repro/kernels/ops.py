"""Jitted public wrappers around the Pallas kernels.

Handle padding to block multiples and the padding-value contract
(cand -1 / nbr INT_MAX), so callers pass ragged-ish data freely.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .intersect import (
    CAND_PAD, NBR_PAD, intersect_count_pallas, level_expand_pallas,
    membership_pallas,
)


def _pad_to(x: jax.Array, mult0: int, mult1: int, value) -> jax.Array:
    b = (-x.shape[0]) % mult0
    d = (-x.shape[1]) % mult1
    if b or d:
        x = jnp.pad(x, ((0, b), (0, d)), constant_values=value)
    return x


@partial(jax.jit, static_argnames=("block_b", "block_d", "block_l", "interpret"))
def sorted_membership(
    cand: jax.Array,
    nbr: jax.Array,
    cand_valid: jax.Array | None = None,
    nbr_len: jax.Array | None = None,
    *,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """mask[b, d] = cand[b, d] ∈ nbr[b, :nbr_len[b]] (rows sorted asc).

    cand_valid / nbr_len mask out ragged tails; padding never matches.
    """
    B, D = cand.shape
    cand = cand.astype(jnp.int32)
    nbr = nbr.astype(jnp.int32)
    if cand_valid is not None:
        cand = jnp.where(cand_valid, cand, CAND_PAD)
    if nbr_len is not None:
        pos = jnp.arange(nbr.shape[1], dtype=jnp.int32)[None, :]
        nbr = jnp.where(pos < nbr_len[:, None], nbr, NBR_PAD)
    cand_p = _pad_to(cand, block_b, block_d, CAND_PAD)
    nbr_p = _pad_to(nbr, block_b, block_l, NBR_PAD)
    out = membership_pallas(
        cand_p, nbr_p,
        block_b=block_b, block_d=block_d, block_l=block_l,
        interpret=interpret,
    )
    return out[:B, :D]


@partial(jax.jit, static_argnames=("block_b", "block_d", "block_l", "interpret"))
def intersect_count(
    cand: jax.Array,
    nbr: jax.Array,
    cand_valid: jax.Array | None = None,
    nbr_len: jax.Array | None = None,
    *,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """cnt[b] = |{d : cand[b,d] ∈ nbr[b,:]}| — fused count kernel.

    Contract: nbr rows strictly increasing on the valid prefix."""
    B, D = cand.shape
    cand = cand.astype(jnp.int32)
    nbr = nbr.astype(jnp.int32)
    if cand_valid is not None:
        cand = jnp.where(cand_valid, cand, CAND_PAD)
    if nbr_len is not None:
        pos = jnp.arange(nbr.shape[1], dtype=jnp.int32)[None, :]
        nbr = jnp.where(pos < nbr_len[:, None], nbr, NBR_PAD)
    cand_p = _pad_to(cand, block_b, block_d, CAND_PAD)
    nbr_p = _pad_to(nbr, block_b, block_l, NBR_PAD)
    out = intersect_count_pallas(
        cand_p, nbr_p,
        block_b=block_b, block_d=block_d, block_l=block_l,
        interpret=interpret,
    )
    return out[:B]


@partial(jax.jit, static_argnames=("dirs", "count", "block_b", "block_d",
                                   "block_l", "interpret"))
def level_expand(
    cand: jax.Array,                      # [B, D] candidate window
    nbrs: jax.Array,                      # [P, B, L] predecessor windows
    extra: jax.Array | None = None,       # [B, E] prefix-vertex values
    cand_valid: jax.Array | None = None,  # [B, D] bool
    nbr_lens: jax.Array | None = None,    # [P, B] valid prefix lengths
    *,
    dirs: tuple = (),
    count: bool = False,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """One fused Pallas pass for a whole expansion level.

    mask[b, d] = cand_valid[b, d]
               ∧ (∀p: cand[b, d] ∈ nbrs[p, b, :nbr_lens[p, b]])
               ∧ (∀e: cand[b, d] <op dirs[e]> extra[b, e])
    with <op> ∈ {+1: >, -1: <, 0: !=}.
    `count=True` returns cnt[b] = Σ_d mask[b, d] (int32) instead.

    Contract: nbr rows STRICTLY increasing on their valid prefix (CSR
    neighborhoods are) — the kernel's per-candidate hit accumulator
    relies on at most one match per predecessor row, so a duplicated
    neighbor value would double-count.
    """
    B, D = cand.shape
    P, _, L = nbrs.shape
    cand = cand.astype(jnp.int32)
    nbrs = nbrs.astype(jnp.int32)
    if cand_valid is not None:
        cand = jnp.where(cand_valid, cand, CAND_PAD)
    if nbr_lens is not None:
        pos = jnp.arange(L, dtype=jnp.int32)[None, None, :]
        nbrs = jnp.where(pos < nbr_lens[:, :, None], nbrs, NBR_PAD)
    cand_p = _pad_to(cand, block_b, block_d, CAND_PAD)
    pb = (-B) % block_b
    pL = (-L) % block_l
    if pb or pL:
        nbrs = jnp.pad(nbrs, ((0, 0), (0, pb), (0, pL)),
                       constant_values=NBR_PAD)
    if dirs:
        extra = extra.astype(jnp.int32)
        if pb:
            extra = jnp.pad(extra, ((0, pb), (0, 0)))
    out = level_expand_pallas(
        cand_p, nbrs, extra if dirs else None,
        dirs=tuple(dirs), count=count,
        block_b=block_b, block_d=block_d, block_l=block_l,
        interpret=interpret,
    )
    return out[:B] if count else out[:B, :D]


# ------------------------------------------------------------ attention ---
@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "interpret"))
def flash_attention(
    q: jax.Array,                 # [B, Sq, H, hd]
    k: jax.Array,                 # [B, Sk, K, hd]
    v: jax.Array,                 # [B, Sk, K, hd]
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Model-layout wrapper: folds (B, heads) into the kernel's row dim,
    using the zero-copy GQA block-index mapping (kv heads are never
    materialized per q-head).  Falls back to shapes the kernel supports;
    callers guard on S % block == 0."""
    from .flash_attention import flash_attention_pallas

    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    # [B, S, H, hd] -> [B*H, S, hd] with q-heads of one kv-group adjacent,
    # so kernel row i maps to kv row i // G.
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    of = flash_attention_pallas(
        qf, kf, vf, causal=causal,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return of.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
