"""Jitted public wrappers around the Pallas kernels.

Handle padding to block multiples and the padding-value contract
(cand -1 / nbr INT_MAX), so callers pass ragged-ish data freely.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .intersect import (
    CAND_PAD, NBR_PAD, intersect_count_pallas, level_expand_pallas,
    membership_pallas,
)

# Largest block_l the level-expansion kernel accepts.  device_graph pads
# the device CSR by flat_gather_pad() sentinels so every in-kernel
# window DMA stays in bounds for any block_l up to this.
MAX_BLOCK_L = 512


def flat_gather_pad() -> int:
    """Sentinel entries to append to a flat CSR array so the kernel's
    in-grid window DMAs never read out of bounds.

    Safety invariant (what actually bounds the reads): every row must
    lie inside the UNPADDED array — starts[p, b] + lens[p, b] ≤ F — as
    real CSR rows do.  The kernel only DMAs l-blocks with
    li·block_l < lens[p, b], so the furthest read ends at
    starts + round_up(lens, block_l) ≤ F + block_l − 1.  A constant
    MAX_BLOCK_L pad therefore suffices for any row length / `window`,
    for any block_l ≤ MAX_BLOCK_L (asserted in level_expand)."""
    return MAX_BLOCK_L


def _pad_to(x: jax.Array, mult0: int, mult1: int, value) -> jax.Array:
    b = (-x.shape[0]) % mult0
    d = (-x.shape[1]) % mult1
    if b or d:
        x = jnp.pad(x, ((0, b), (0, d)), constant_values=value)
    return x


@partial(jax.jit, static_argnames=("block_b", "block_d", "block_l", "interpret"))
def sorted_membership(
    cand: jax.Array,
    nbr: jax.Array,
    cand_valid: jax.Array | None = None,
    nbr_len: jax.Array | None = None,
    *,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """mask[b, d] = cand[b, d] ∈ nbr[b, :nbr_len[b]] (rows sorted asc).

    cand_valid / nbr_len mask out ragged tails; padding never matches.
    """
    B, D = cand.shape
    cand = cand.astype(jnp.int32)
    nbr = nbr.astype(jnp.int32)
    if cand_valid is not None:
        cand = jnp.where(cand_valid, cand, CAND_PAD)
    if nbr_len is not None:
        pos = jnp.arange(nbr.shape[1], dtype=jnp.int32)[None, :]
        nbr = jnp.where(pos < nbr_len[:, None], nbr, NBR_PAD)
    cand_p = _pad_to(cand, block_b, block_d, CAND_PAD)
    nbr_p = _pad_to(nbr, block_b, block_l, NBR_PAD)
    out = membership_pallas(
        cand_p, nbr_p,
        block_b=block_b, block_d=block_d, block_l=block_l,
        interpret=interpret,
    )
    return out[:B, :D]


@partial(jax.jit, static_argnames=("block_b", "block_d", "block_l", "interpret"))
def intersect_count(
    cand: jax.Array,
    nbr: jax.Array,
    cand_valid: jax.Array | None = None,
    nbr_len: jax.Array | None = None,
    *,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """cnt[b] = |{d : cand[b,d] ∈ nbr[b,:]}| — fused count kernel.

    Contract: nbr rows strictly increasing on the valid prefix."""
    B, D = cand.shape
    cand = cand.astype(jnp.int32)
    nbr = nbr.astype(jnp.int32)
    if cand_valid is not None:
        cand = jnp.where(cand_valid, cand, CAND_PAD)
    if nbr_len is not None:
        pos = jnp.arange(nbr.shape[1], dtype=jnp.int32)[None, :]
        nbr = jnp.where(pos < nbr_len[:, None], nbr, NBR_PAD)
    cand_p = _pad_to(cand, block_b, block_d, CAND_PAD)
    nbr_p = _pad_to(nbr, block_b, block_l, NBR_PAD)
    out = intersect_count_pallas(
        cand_p, nbr_p,
        block_b=block_b, block_d=block_d, block_l=block_l,
        interpret=interpret,
    )
    return out[:B]


@partial(jax.jit, static_argnames=("dirs", "count", "neg_from", "window",
                                   "flat_padded", "block_b", "block_d",
                                   "block_l", "interpret"))
def level_expand(
    cand: jax.Array,                      # [B, D] candidate window
    flat: jax.Array,                      # [F] flat CSR indices array
    starts: jax.Array,                    # [P, B] CSR row offsets
    lens: jax.Array,                      # [P, B] valid row lengths
    extra: jax.Array | None = None,       # [B, E] prefix-vertex values
    cand_valid: jax.Array | None = None,  # [B, D] bool
    *,
    dirs: tuple = (),
    count: bool = False,
    neg_from: int | None = None,
    window: int,
    flat_padded: bool = False,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """One fused, self-feeding Pallas pass for a whole expansion level.

    mask[b, d] = cand_valid[b, d]
               ∧ (∀p: cand[b, d] ∈ flat[starts[p, b] : +lens[p, b]])
               ∧ (∀e: cand[b, d] <op dirs[e]> extra[b, e])
    with <op> ∈ {+1: >, -1: <, 0: !=}.
    `count=True` returns cnt[b] = Σ_d mask[b, d] (int32) instead; with
    `neg_from` set, columns ≥ neg_from subtract instead of add (the
    fused IEP prefix-correction tail — DESIGN.md §4).

    The predecessor neighborhoods are gathered INSIDE the kernel from
    `flat` (scalar-prefetched `starts`, per-row DMA) — no caller ever
    materializes a stacked [P, B, W] window array.

    Contracts:
      * rows flat[starts[p,b] : +lens[p,b]] STRICTLY increasing (CSR
        neighborhoods are) — the per-candidate hit accumulator relies on
        at most one match per predecessor row;
      * `window` (static) ≥ every lens[p, b] — blocks past it are never
        walked;
      * every row lies inside the unpadded array:
        starts[p, b] + lens[p, b] ≤ len(flat) (CSR rows do) — with the
        DMA skip this bounds reads to len(flat) + block_l − 1, so
        flat_gather_pad() sentinels make them safe;
      * flat_padded=True asserts the caller already appended those
        sentinels (device_graph does); with False the wrapper pads here
        (fine for tests, avoid per-call padding of a resident graph on
        the hot path).
    """
    B, D = cand.shape
    P, _ = starts.shape
    cand = cand.astype(jnp.int32)
    if cand_valid is not None:
        cand = jnp.where(cand_valid, cand, CAND_PAD)
    cand_p = _pad_to(cand, block_b, block_d, CAND_PAD)
    starts = starts.astype(jnp.int32)
    lens = lens.astype(jnp.int32)
    pb = (-B) % block_b
    if pb:
        # padded rows: offset 0 / length 0 — the kernel skips their DMAs
        starts = jnp.pad(starts, ((0, 0), (0, pb)))
        lens = jnp.pad(lens, ((0, 0), (0, pb)))
    flat = flat.astype(jnp.int32)
    assert block_l <= MAX_BLOCK_L, (block_l, MAX_BLOCK_L)
    if not flat_padded:
        flat = jnp.concatenate(
            [flat, jnp.full(flat_gather_pad(), NBR_PAD, jnp.int32)])
    if dirs:
        extra = extra.astype(jnp.int32)
        if pb:
            extra = jnp.pad(extra, ((0, pb), (0, 0)))
    out = level_expand_pallas(
        cand_p, flat, starts, lens, extra if dirs else None,
        dirs=tuple(dirs), count=count, neg_from=neg_from, window=window,
        block_b=block_b, block_d=block_d, block_l=block_l,
        interpret=interpret,
    )
    return out[:B] if count else out[:B, :D]


# ------------------------------------------------------------ attention ---
@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "interpret"))
def flash_attention(
    q: jax.Array,                 # [B, Sq, H, hd]
    k: jax.Array,                 # [B, Sk, K, hd]
    v: jax.Array,                 # [B, Sk, K, hd]
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Model-layout wrapper: folds (B, heads) into the kernel's row dim,
    using the zero-copy GQA block-index mapping (kv heads are never
    materialized per q-head).  Falls back to shapes the kernel supports;
    callers guard on S % block == 0."""
    from .flash_attention import flash_attention_pallas

    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    # [B, S, H, hd] -> [B*H, S, hd] with q-heads of one kv-group adjacent,
    # so kernel row i maps to kv row i // G.
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    of = flash_attention_pallas(
        qf, kf, vf, causal=causal,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return of.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
