"""Pure-jnp oracles for the Pallas kernels (the `ref.py` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def membership_ref(cand: jax.Array, nbr: jax.Array) -> jax.Array:
    """mask[b, d] = cand[b, d] ∈ nbr[b, :] — O(B·D·L) broadcast compare."""
    return (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)


def membership_ref_searchsorted(cand: jax.Array, nbr: jax.Array) -> jax.Array:
    """Second oracle via per-row binary search (nbr rows must be sorted)."""

    def row(c, nb):
        idx = jnp.searchsorted(nb, c)
        idx = jnp.minimum(idx, nb.shape[0] - 1)
        return nb[idx] == c

    return jax.vmap(row)(cand, nbr)


def intersect_count_ref(cand: jax.Array, nbr: jax.Array) -> jax.Array:
    return membership_ref(cand, nbr).astype(jnp.int32)


def level_expand_ref(
    cand: jax.Array,                      # [B, D]
    flat: jax.Array,                      # [F] flat CSR indices
    starts: jax.Array,                    # [P, B] row offsets
    lens: jax.Array,                      # [P, B] valid row lengths
    extra: jax.Array | None = None,       # [B, E]
    cand_valid: jax.Array | None = None,  # [B, D] bool
    *,
    dirs: tuple = (),
    count: bool = False,
    neg_from: int | None = None,
    window: int,
) -> jax.Array:
    """Oracle for the fused level-expansion kernel (ops.level_expand),
    covering the in-kernel gather AND the signed IEP-correction count:
    each predecessor window is gathered host-side from `flat` at
    `starts[p]` (positions ≥ lens[p] masked out), membership and the
    restriction / injectivity comparisons run as plain separate jnp
    passes.  `count=True` sums the mask per row; with `neg_from` set,
    columns ≥ neg_from are weighted −1 (the IEP prefix corrections).
    Same contract: rows strictly increasing on the valid prefix,
    window ≥ every lens[p, b]."""
    mask = jnp.ones(cand.shape, dtype=bool)
    if cand_valid is not None:
        mask &= cand_valid
    pos = jnp.arange(window, dtype=jnp.int32)[None, :]
    for p in range(starts.shape[0]):
        idx = jnp.minimum(starts[p][:, None] + pos, flat.shape[0] - 1)
        nb = jnp.where(pos < lens[p][:, None], flat[idx], -(2**31))
        mask &= membership_ref(cand, nb)
    for e, d in enumerate(dirs):
        ev = extra[:, e][:, None]
        if d > 0:
            mask &= cand > ev
        elif d < 0:
            mask &= cand < ev
        else:
            mask &= cand != ev
    if not count:
        return mask
    if neg_from is not None:
        w = jnp.where(jnp.arange(cand.shape[1]) < neg_from, 1, -1)
        return (mask.astype(jnp.int32) * w[None, :]).sum(axis=1)
    return mask.sum(axis=1).astype(jnp.int32)


# ------------------------------------------------------------ attention ---
def flash_attention_ref(q, k, v, *, causal=True, sm_scale=None):
    """Oracle for the flash kernel: plain softmax attention in fp32.

    q [BH, Sq, hd]; k/v [BK, Sk, hd] with BH % BK == 0 (GQA groups)."""
    import math

    BH, Sq, hd = q.shape
    BK = k.shape[0]
    g = BH // BK
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=0)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=0)
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32), kf) * sm_scale
    if causal:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(qi >= ki, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", w, vf).astype(q.dtype)
