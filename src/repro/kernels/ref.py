"""Pure-jnp oracles for the Pallas kernels (the `ref.py` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def membership_ref(cand: jax.Array, nbr: jax.Array) -> jax.Array:
    """mask[b, d] = cand[b, d] ∈ nbr[b, :] — O(B·D·L) broadcast compare."""
    return (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)


def membership_ref_searchsorted(cand: jax.Array, nbr: jax.Array) -> jax.Array:
    """Second oracle via per-row binary search (nbr rows must be sorted)."""

    def row(c, nb):
        idx = jnp.searchsorted(nb, c)
        idx = jnp.minimum(idx, nb.shape[0] - 1)
        return nb[idx] == c

    return jax.vmap(row)(cand, nbr)


def intersect_count_ref(cand: jax.Array, nbr: jax.Array) -> jax.Array:
    return membership_ref(cand, nbr).astype(jnp.int32)


def level_expand_ref(
    cand: jax.Array,                      # [B, D]
    nbrs: jax.Array,                      # [P, B, L]
    extra: jax.Array | None = None,       # [B, E]
    cand_valid: jax.Array | None = None,  # [B, D] bool
    nbr_lens: jax.Array | None = None,    # [P, B]
    *,
    dirs: tuple = (),
    count: bool = False,
) -> jax.Array:
    """Oracle for the fused level-expansion kernel (ops.level_expand):
    membership against every predecessor window, then the restriction /
    injectivity comparisons, as plain separate jnp passes.  Same
    contract: nbr rows strictly increasing on the valid prefix."""
    mask = jnp.ones(cand.shape, dtype=bool)
    if cand_valid is not None:
        mask &= cand_valid
    for p in range(nbrs.shape[0]):
        nb = nbrs[p]
        if nbr_lens is not None:
            pos = jnp.arange(nb.shape[1])[None, :]
            nb = jnp.where(pos < nbr_lens[p][:, None], nb, -(2**31))
        mask &= membership_ref(cand, nb)
    for e, d in enumerate(dirs):
        ev = extra[:, e][:, None]
        if d > 0:
            mask &= cand > ev
        elif d < 0:
            mask &= cand < ev
        else:
            mask &= cand != ev
    return mask.sum(axis=1).astype(jnp.int32) if count else mask


# ------------------------------------------------------------ attention ---
def flash_attention_ref(q, k, v, *, causal=True, sm_scale=None):
    """Oracle for the flash kernel: plain softmax attention in fp32.

    q [BH, Sq, hd]; k/v [BK, Sk, hd] with BH % BK == 0 (GQA groups)."""
    import math

    BH, Sq, hd = q.shape
    BK = k.shape[0]
    g = BH // BK
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=0)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=0)
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32), kf) * sm_scale
    if causal:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(qi >= ki, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", w, vf).astype(q.dtype)
