"""Pure-jnp oracles for the Pallas kernels (the `ref.py` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def membership_ref(cand: jax.Array, nbr: jax.Array) -> jax.Array:
    """mask[b, d] = cand[b, d] ∈ nbr[b, :] — O(B·D·L) broadcast compare."""
    return (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)


def membership_ref_searchsorted(cand: jax.Array, nbr: jax.Array) -> jax.Array:
    """Second oracle via per-row binary search (nbr rows must be sorted)."""

    def row(c, nb):
        idx = jnp.searchsorted(nb, c)
        idx = jnp.minimum(idx, nb.shape[0] - 1)
        return nb[idx] == c

    return jax.vmap(row)(cand, nbr)


def intersect_count_ref(cand: jax.Array, nbr: jax.Array) -> jax.Array:
    return membership_ref(cand, nbr).astype(jnp.int32)


# ------------------------------------------------------------ attention ---
def flash_attention_ref(q, k, v, *, causal=True, sm_scale=None):
    """Oracle for the flash kernel: plain softmax attention in fp32.

    q [BH, Sq, hd]; k/v [BK, Sk, hd] with BH % BK == 0 (GQA groups)."""
    import math

    BH, Sq, hd = q.shape
    BK = k.shape[0]
    g = BH // BK
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=0)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=0)
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32), kf) * sm_scale
    if causal:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(qi >= ki, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", w, vf).astype(q.dtype)
