"""Pallas TPU flash-attention (forward) kernel.

§Perf motivation: the XLA prefill path materializes fp32 score tensors
[B,H,q_chunk,S] at fusion boundaries — 2-4 HBM crossings of B·H·S²
elements per layer.  At S=32k that is the dominant memory term of every
prefill cell (e.g. minitron-4b: 4.1 TB of 5.0 TB total).  The flash
formulation keeps the score tile in VMEM and writes only the [S, hd]
output — HBM traffic drops to the q/k/v/o tensors themselves.

Kernel shape contract (ops.py handles folding/padding):
    q: [BH, Sq, hd]   — batch×heads folded; one grid row per BH
    k: [BK, Sk, hd]   — BK = BH (kv already gathered per q-head) or
                        BH/G (zero-copy GQA via the block index map)
    v: [BK, Sk, hd]
    o: [BH, Sq, hd]

Grid: (BH, Sq/block_q, Sk/block_k); the k axis is innermost and
accumulates into VMEM scratch (running max / sum / acc — the online
softmax), flushed to `o` on the last k-step.  Causal blocks entirely
above the diagonal are skipped with @pl.when (their DMA still runs; the
MXU work is saved — block-sparse index maps are a further refinement).

hd ≤ 128 fits one VREG lane tile; block_q=block_k=512 keeps
q+k+v+acc ≈ 512·128·(2+2+2+4)B ≈ 640 KiB in VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                block_q: int, block_k: int, sm_scale: float, causal: bool):
    j = pl.program_id(1)          # q block
    kk = pl.program_id(2)         # k block (innermost, accumulating)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    first_q = j * block_q
    first_k = kk * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32)                 # [bq, hd]
        k = k_ref[0].astype(jnp.float32)                 # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale                                     # [bq, bk]
        if causal:
            qi = first_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            ki = first_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qi >= ki, s, NEG_INF)
        m_prev = m_ref[...]                              # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                  # rescale old state
        p = jnp.exp(s - m_new)                           # [bq, bk]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                 # [bk, hd]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # [bq, hd]
        acc_ref[...] = acc_ref[...] * alpha[..., :] + pv
        m_ref[...] = m_new

    if causal:
        # skip k blocks strictly above the causal diagonal
        last_q = first_q + block_q - 1
        pl.when(last_q >= first_k)(compute)
    else:
        compute()

    @pl.when(kk == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,                 # [BH, Sq, hd]
    k: jax.Array,                 # [BK, Sk, hd]
    v: jax.Array,                 # [BK, Sk, hd]
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    BH, Sq, hd = q.shape
    BK, Sk, _ = k.shape
    assert BH % BK == 0, (q.shape, k.shape)
    group = BH // BK              # zero-copy GQA: q-heads per kv-head
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    grid = (BH, Sq // block_q, Sk // block_k)
    return pl.pallas_call(
        functools.partial(
            _flash_body, block_q=block_q, block_k=block_k,
            sm_scale=sm_scale, causal=causal,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda i, j, kk: (i, j, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda i, j, kk, g=group: (i // g, kk, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda i, j, kk, g=group: (i // g, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda i, j, kk: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
