"""Pallas TPU kernels for the set-intersection hot spot.

GraphPi's inner loop is a sorted-list merge intersection — a pointer-
chasing pattern that does not vectorize on TPU.  The TPU-native
formulation (DESIGN.md §3) is *blocked broadcast-compare*: tile the
candidate row and the neighbor row into VREG-shaped blocks in VMEM and
reduce equality matches across the neighbor dimension.  Arithmetic
intensity is D·L compares per D+L loaded words, so for typical
neighborhood lengths the kernel is compute-dense on the VPU instead of
latency-bound like a merge.

Two kernels:
  membership_kernel      mask[b, d] = cand[b, d] ∈ nbr[b, :]
  intersect_count_kernel cnt[b]     = |{d : cand[b, d] ∈ nbr[b, :]}|
                         (membership + in-kernel popcount, fused)

Padding contract: `cand` padded with -1, `nbr` padded with INT_MAX
(sorted ascending), so padding never produces a match.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NBR_PAD = jnp.iinfo(jnp.int32).max
CAND_PAD = -1


def _membership_body(cand_ref, nbr_ref, out_ref, *, block_l: int):
    """Grid = (B/bb, D/bd, L/bl); L is the innermost (accumulation) dim."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cand = cand_ref[...]                  # [bb, bd]
    nbr = nbr_ref[...]                    # [bb, bl]
    # broadcast-compare: [bb, bd, bl] equality cube, reduced over bl
    hit = (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)
    out_ref[...] |= hit


def _count_body(cand_ref, nbr_ref, out_ref, acc_ref, *, block_l: int):
    """Fused |A ∩ B| per row: the [bb, bd, bl] equality cube is reduced
    over BOTH d and l inside the kernel; the row accumulator lives in VMEM
    scratch and is flushed once per row-block.

    Contract: nbr rows strictly increasing on their valid prefix (CSR
    neighborhoods are), so a candidate matches in at most one l-block.
    """
    j = pl.program_id(1)
    k = pl.program_id(2)
    nj = pl.num_programs(1)
    nk = pl.num_programs(2)

    @pl.when((j == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cand = cand_ref[...]                  # [bb, bd]
    nbr = nbr_ref[...]                    # [bb, bl]
    hit = (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)
    acc_ref[...] += hit.sum(axis=1, keepdims=True).astype(jnp.int32)

    @pl.when((j == nj - 1) & (k == nk - 1))
    def _flush():
        out_ref[...] = acc_ref[...]


def membership_pallas(
    cand: jax.Array,
    nbr: jax.Array,
    *,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """mask[b, d] = cand[b, d] ∈ nbr[b, :].  Shapes must be pre-padded to
    block multiples (ops.sorted_membership handles that)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = cand.shape
    Bn, L = nbr.shape
    assert B == Bn, (cand.shape, nbr.shape)
    assert B % block_b == 0 and D % block_d == 0 and L % block_l == 0
    grid = (B // block_b, D // block_d, L // block_l)
    return pl.pallas_call(
        functools.partial(_membership_body, block_l=block_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_l), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.bool_),
        interpret=interpret,
    )(cand, nbr)


def intersect_count_pallas(
    cand: jax.Array,
    nbr: jax.Array,
    *,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """cnt[b] = |{d : cand[b, d] ∈ nbr[b, :]}| (int32), fully fused: the
    d and l reductions happen in-kernel, output is one scalar per row."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = cand.shape
    _, L = nbr.shape
    assert B % block_b == 0 and D % block_d == 0 and L % block_l == 0
    grid = (B // block_b, D // block_d, L // block_l)
    out = pl.pallas_call(
        functools.partial(_count_body, block_l=block_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_l), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, 1), jnp.int32)],
        interpret=interpret,
    )(cand, nbr)
    return out[:, 0]
