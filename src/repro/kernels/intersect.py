"""Pallas TPU kernels for the set-intersection hot spot.

GraphPi's inner loop is a sorted-list merge intersection — a pointer-
chasing pattern that does not vectorize on TPU.  The TPU-native
formulation (DESIGN.md §3) is *blocked broadcast-compare*: tile the
candidate row and the neighbor row into VREG-shaped blocks in VMEM and
reduce equality matches across the neighbor dimension.  Arithmetic
intensity is D·L compares per D+L loaded words, so for typical
neighborhood lengths the kernel is compute-dense on the VPU instead of
latency-bound like a merge.

Three kernels:
  membership_kernel      mask[b, d] = cand[b, d] ∈ nbr[b, :]
  intersect_count_kernel cnt[b]     = |{d : cand[b, d] ∈ nbr[b, :]}|
                         (membership + in-kernel popcount, fused)
  level_expand_kernel    the executor's whole per-level admissibility
                         test in ONE pass: membership against ALL
                         predecessor neighborhoods (stacked on the
                         innermost grid dimension), the asymmetric-
                         restriction comparisons and injectivity !=
                         masks against per-row prefix vertices, reduced
                         to either a mask or an in-kernel popcount.

Padding contract: `cand` padded with -1, `nbr` padded with INT_MAX
(sorted ascending), so padding never produces a match.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NBR_PAD = jnp.iinfo(jnp.int32).max
CAND_PAD = -1


def _membership_body(cand_ref, nbr_ref, out_ref, *, block_l: int):
    """Grid = (B/bb, D/bd, L/bl); L is the innermost (accumulation) dim."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cand = cand_ref[...]                  # [bb, bd]
    nbr = nbr_ref[...]                    # [bb, bl]
    # broadcast-compare: [bb, bd, bl] equality cube, reduced over bl
    hit = (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)
    out_ref[...] |= hit


def _count_body(cand_ref, nbr_ref, out_ref, acc_ref, *, block_l: int):
    """Fused |A ∩ B| per row: the [bb, bd, bl] equality cube is reduced
    over BOTH d and l inside the kernel; the row accumulator lives in VMEM
    scratch and is flushed once per row-block.

    Contract: nbr rows strictly increasing on their valid prefix (CSR
    neighborhoods are), so a candidate matches in at most one l-block.
    """
    j = pl.program_id(1)
    k = pl.program_id(2)
    nj = pl.num_programs(1)
    nk = pl.num_programs(2)

    @pl.when((j == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cand = cand_ref[...]                  # [bb, bd]
    nbr = nbr_ref[...]                    # [bb, bl]
    hit = (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)
    acc_ref[...] += hit.sum(axis=1, keepdims=True).astype(jnp.int32)

    @pl.when((j == nj - 1) & (k == nk - 1))
    def _flush():
        out_ref[...] = acc_ref[...]


def _level_expand_body(*refs, n_preds: int, dirs: tuple, count: bool):
    """Fused per-level admissibility test.

    Grid = (B/bb, D/bd, P·L/bl): the innermost dimension walks every
    (predecessor, neighbor-block) pair, so one grid sweep touches the
    candidate block once per predecessor block instead of re-launching a
    kernel (and re-streaming the candidate matrix through HBM) per
    predecessor.  A VMEM hit-accumulator counts, for each candidate, in
    how many predecessor neighborhoods it was found (nbr rows must be
    STRICTLY increasing on their valid prefix — as CSR neighborhoods
    are — so each row matches a candidate at most once, even across
    l-blocks); admissibility is hits == P, ANDed
    with the restriction (>/<) and injectivity (!=) comparisons against
    the per-row prefix-vertex values in `extra` — all applied at the
    final block, so the whole level is a single pass over HBM.

    refs layout: cand, nbr, [extra,] out, hits, [acc]
      cand  [bb, bd]    candidate block (CAND_PAD-masked)
      nbr   [1, bb, bl] one predecessor's neighbor block (NBR_PAD-masked)
      extra [bb, E]     prefix-vertex values, E == len(dirs) (if E > 0)
      out   [bb, bd] bool mask  — or [bb, 1] int32 row counts if `count`
      hits  [bb, bd] int32 VMEM scratch
      acc   [bb, 1]  int32 VMEM scratch (count mode only)
    """
    if dirs:
        cand_ref, nbr_ref, extra_ref, out_ref, *scratch = refs
    else:
        cand_ref, nbr_ref, out_ref, *scratch = refs
        extra_ref = None
    hits_ref = scratch[0]
    j = pl.program_id(1)
    k = pl.program_id(2)
    nj = pl.num_programs(1)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init_hits():
        hits_ref[...] = jnp.zeros_like(hits_ref)

    if count:
        acc_ref = scratch[1]

        @pl.when((j == 0) & (k == 0))
        def _init_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

    cand = cand_ref[...]                  # [bb, bd]
    nbr = nbr_ref[0]                      # [bb, bl]
    hit = (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)
    hits_ref[...] += hit.astype(jnp.int32)

    @pl.when(k == nk - 1)
    def _finalize():
        mask = hits_ref[...] == n_preds
        for e, d in enumerate(dirs):
            ev = extra_ref[:, e][:, None]  # [bb, 1]
            if d > 0:
                mask &= cand > ev
            elif d < 0:
                mask &= cand < ev
            else:
                mask &= cand != ev
        if count:
            acc_ref[...] += mask.sum(axis=1, keepdims=True).astype(jnp.int32)

            @pl.when(j == nj - 1)
            def _flush():
                out_ref[...] = acc_ref[...]
        else:
            out_ref[...] = mask


def level_expand_pallas(
    cand: jax.Array,                      # [B, D] int32, CAND_PAD-masked
    nbrs: jax.Array,                      # [P, B, L] int32, NBR_PAD-masked
    extra: jax.Array | None = None,       # [B, E] int32 (E == len(dirs))
    *,
    dirs: tuple = (),
    count: bool = False,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """One fused pass per expansion level (shapes pre-padded to block
    multiples — ops.level_expand handles that).

    mask[b, d] = (∀p: cand[b, d] ∈ nbrs[p, b, :]) ∧ extras(b, d), where
    extras applies dirs[e] ∈ {+1: cand > extra[b, e], -1: cand <,
    0: cand !=}.  `count=True` instead returns cnt[b] = Σ_d mask[b, d]
    via the in-kernel popcount accumulator (intersect_count pattern).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = cand.shape
    P, Bn, L = nbrs.shape
    assert B == Bn and P >= 1, (cand.shape, nbrs.shape)
    assert B % block_b == 0 and D % block_d == 0 and L % block_l == 0
    nl = L // block_l
    grid = (B // block_b, D // block_d, P * nl)
    in_specs = [
        pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        pl.BlockSpec((1, block_b, block_l),
                     lambda i, j, k: (k // nl, i, k % nl)),
    ]
    operands = [cand, nbrs]
    if dirs:
        assert extra is not None and extra.shape == (B, len(dirs))
        in_specs.append(
            pl.BlockSpec((block_b, len(dirs)), lambda i, j, k: (i, 0)))
        operands.append(extra)
    scratch = [pltpu.VMEM((block_b, block_d), jnp.int32)]
    if count:
        out_specs = pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0))
        out_shape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        scratch.append(pltpu.VMEM((block_b, 1), jnp.int32))
    else:
        out_specs = pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j))
        out_shape = jax.ShapeDtypeStruct((B, D), jnp.bool_)
    out = pl.pallas_call(
        functools.partial(_level_expand_body, n_preds=P, dirs=tuple(dirs),
                          count=count),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    return out[:, 0] if count else out


def membership_pallas(
    cand: jax.Array,
    nbr: jax.Array,
    *,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """mask[b, d] = cand[b, d] ∈ nbr[b, :].  Shapes must be pre-padded to
    block multiples (ops.sorted_membership handles that)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = cand.shape
    Bn, L = nbr.shape
    assert B == Bn, (cand.shape, nbr.shape)
    assert B % block_b == 0 and D % block_d == 0 and L % block_l == 0
    grid = (B // block_b, D // block_d, L // block_l)
    return pl.pallas_call(
        functools.partial(_membership_body, block_l=block_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_l), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.bool_),
        interpret=interpret,
    )(cand, nbr)


def intersect_count_pallas(
    cand: jax.Array,
    nbr: jax.Array,
    *,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """cnt[b] = |{d : cand[b, d] ∈ nbr[b, :]}| (int32), fully fused: the
    d and l reductions happen in-kernel, output is one scalar per row."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = cand.shape
    _, L = nbr.shape
    assert B % block_b == 0 and D % block_d == 0 and L % block_l == 0
    grid = (B // block_b, D // block_d, L // block_l)
    out = pl.pallas_call(
        functools.partial(_count_body, block_l=block_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_l), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, 1), jnp.int32)],
        interpret=interpret,
    )(cand, nbr)
    return out[:, 0]
