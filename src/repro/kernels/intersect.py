"""Pallas TPU kernels for the set-intersection hot spot.

GraphPi's inner loop is a sorted-list merge intersection — a pointer-
chasing pattern that does not vectorize on TPU.  The TPU-native
formulation (DESIGN.md §3) is *blocked broadcast-compare*: tile the
candidate row and the neighbor row into VREG-shaped blocks in VMEM and
reduce equality matches across the neighbor dimension.  Arithmetic
intensity is D·L compares per D+L loaded words, so for typical
neighborhood lengths the kernel is compute-dense on the VPU instead of
latency-bound like a merge.

Three kernels:
  membership_kernel      mask[b, d] = cand[b, d] ∈ nbr[b, :]
  intersect_count_kernel cnt[b]     = |{d : cand[b, d] ∈ nbr[b, :]}|
                         (membership + in-kernel popcount, fused)
  level_expand_kernel    the executor's whole per-level admissibility
                         test in ONE pass: membership against ALL
                         predecessor neighborhoods, the asymmetric-
                         restriction comparisons and injectivity !=
                         masks against per-row prefix vertices, reduced
                         to either a mask or an in-kernel popcount.

`level_expand_kernel` is self-feeding (DESIGN.md §4): it never sees a
materialized `[P, B, W]` stack of predecessor windows.  The CSR row
offsets and lengths of every predecessor arrive as scalar-prefetch
operands (`PrefetchScalarGridSpec`, resident in SMEM before the body
runs) and each neighbor block is DMA'd out of the flat CSR `indices`
array — which stays unblocked in HBM (`memory_space=ANY`) — into a VMEM
scratch buffer inside the grid.  Rows whose valid length ends before the
current block skip their DMA entirely, so power-law short rows cost no
HBM traffic at all.  The `count=True` path optionally applies a signed
weight per candidate column (`neg_from`): columns ≥ `neg_from` subtract
instead of add, which lets the executor fold the IEP prefix-correction
cardinalities into the same pass (the prefix vertices ride along as
negatively-weighted candidates).

Padding contract: `cand` padded with -1, neighbor rows masked to
INT_MAX past their valid length in-kernel (rows sorted ascending), so
padding never produces a match.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NBR_PAD = jnp.iinfo(jnp.int32).max
CAND_PAD = -1


def _membership_body(cand_ref, nbr_ref, out_ref, *, block_l: int):
    """Grid = (B/bb, D/bd, L/bl); L is the innermost (accumulation) dim."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cand = cand_ref[...]                  # [bb, bd]
    nbr = nbr_ref[...]                    # [bb, bl]
    # broadcast-compare: [bb, bd, bl] equality cube, reduced over bl
    hit = (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)
    out_ref[...] |= hit


def _count_body(cand_ref, nbr_ref, out_ref, acc_ref, *, block_l: int):
    """Fused |A ∩ B| per row: the [bb, bd, bl] equality cube is reduced
    over BOTH d and l inside the kernel; the row accumulator lives in VMEM
    scratch and is flushed once per row-block.

    Contract: nbr rows strictly increasing on their valid prefix (CSR
    neighborhoods are), so a candidate matches in at most one l-block.
    """
    j = pl.program_id(1)
    k = pl.program_id(2)
    nj = pl.num_programs(1)
    nk = pl.num_programs(2)

    @pl.when((j == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cand = cand_ref[...]                  # [bb, bd]
    nbr = nbr_ref[...]                    # [bb, bl]
    hit = (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)
    acc_ref[...] += hit.sum(axis=1, keepdims=True).astype(jnp.int32)

    @pl.when((j == nj - 1) & (k == nk - 1))
    def _flush():
        out_ref[...] = acc_ref[...]


def _level_expand_body(*refs, n_preds: int, nl: int, dirs: tuple,
                       count: bool, neg_from: int | None,
                       block_b: int, block_d: int, block_l: int):
    """Fused, self-feeding per-level admissibility test.

    Grid = (B/bb, D/bd, P·nl): the innermost dimension walks every
    (predecessor, neighbor-block) pair.  Each step DMAs its own neighbor
    block out of the flat CSR array in HBM — one `block_l`-wide slice per
    frontier row, at `starts[p, row] + li·block_l` — into VMEM scratch.
    Rows whose valid length (`lens[p, row]`) ends before this block skip
    the DMA; their stale buffer contents are masked to NBR_PAD before the
    compare, so they can never match.

    A VMEM hit-accumulator counts, for each candidate, in how many
    predecessor neighborhoods it was found (CSR rows are STRICTLY
    increasing on their valid prefix, so each row matches a candidate at
    most once, even across l-blocks); admissibility is hits == P, ANDed
    with the restriction (>/<) and injectivity (!=) comparisons against
    the per-row prefix-vertex values in `extra` — all applied at the
    final block, so the whole level is a single pass.

    refs layout:
      starts [P, B] int32 SMEM (scalar prefetch) — CSR row offsets
      lens_s [P, B] int32 SMEM (scalar prefetch) — row lengths (DMA skip)
      cand   [bb, bd]    candidate block (CAND_PAD-masked)
      flat   [F]         whole CSR indices array, unblocked (HBM/ANY)
      lens   [1, bb]     row lengths again, blocked (vector tail mask)
      extra  [bb, E]     prefix-vertex values, E == len(dirs) (if E > 0)
      out    [bb, bd] bool mask — or [bb, 1] int32 row counts if `count`
      nbr    [bb, bl] int32 VMEM scratch (DMA landing buffer)
      hits   [bb, bd] int32 VMEM scratch
      acc    [bb, 1]  int32 VMEM scratch (count mode only)
      sems   [bb] DMA semaphores (one per frontier row)
    """
    if dirs:
        (starts_sref, lens_sref, cand_ref, flat_ref, lens_ref, extra_ref,
         out_ref, *scratch) = refs
    else:
        (starts_sref, lens_sref, cand_ref, flat_ref, lens_ref,
         out_ref, *scratch) = refs
        extra_ref = None
    nbr_ref, hits_ref = scratch[0], scratch[1]
    sems_ref = scratch[-1]
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)
    nj = pl.num_programs(1)
    nk = pl.num_programs(2)
    p = k // nl
    li = k % nl

    @pl.when(k == 0)
    def _init_hits():
        hits_ref[...] = jnp.zeros_like(hits_ref)

    if count:
        acc_ref = scratch[2]

        @pl.when((j == 0) & (k == 0))
        def _init_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

    # gather this predecessor's neighbor block: one DMA per frontier row
    # (each row starts at its own CSR offset), skipped when the row's
    # valid prefix ends before this l-block
    dmas = []
    for r in range(block_b):
        row = i * block_b + r
        live = lens_sref[p, row] > li * block_l
        dma = pltpu.make_async_copy(
            flat_ref.at[pl.ds(starts_sref[p, row] + li * block_l, block_l)],
            nbr_ref.at[r],
            sems_ref.at[r],
        )

        @pl.when(live)
        def _start(dma=dma):
            dma.start()

        dmas.append((live, dma))
    for live, dma in dmas:
        @pl.when(live)
        def _wait(dma=dma):
            dma.wait()

    # mask the ragged tail (and any skipped row's stale buffer) to the
    # never-matching sentinel
    pos = li * block_l + jax.lax.broadcasted_iota(
        jnp.int32, (block_b, block_l), 1)
    nbr = jnp.where(pos < lens_ref[0][:, None], nbr_ref[...], NBR_PAD)
    cand = cand_ref[...]                  # [bb, bd]
    hit = (cand[:, :, None] == nbr[:, None, :]).any(axis=-1)
    hits_ref[...] += hit.astype(jnp.int32)

    @pl.when(k == nk - 1)
    def _finalize():
        mask = hits_ref[...] == n_preds
        for e, d in enumerate(dirs):
            ev = extra_ref[:, e][:, None]  # [bb, 1]
            if d > 0:
                mask &= cand > ev
            elif d < 0:
                mask &= cand < ev
            else:
                mask &= cand != ev
        if count:
            if neg_from is not None:
                # signed popcount: columns ≥ neg_from are the IEP
                # prefix-correction candidates and subtract instead of add
                col = j * block_d + jax.lax.broadcasted_iota(
                    jnp.int32, (block_b, block_d), 1)
                w = jnp.where(col < neg_from, 1, -1).astype(jnp.int32)
            else:
                w = jnp.int32(1)
            acc_ref[...] += (mask.astype(jnp.int32) * w).sum(
                axis=1, keepdims=True).astype(jnp.int32)

            @pl.when(j == nj - 1)
            def _flush():
                out_ref[...] = acc_ref[...]
        else:
            out_ref[...] = mask


def level_expand_pallas(
    cand: jax.Array,                      # [B, D] int32, CAND_PAD-masked
    flat: jax.Array,                      # [F] int32 flat CSR indices
    starts: jax.Array,                    # [P, B] int32 CSR row offsets
    lens: jax.Array,                      # [P, B] int32 row lengths
    extra: jax.Array | None = None,       # [B, E] int32 (E == len(dirs))
    *,
    dirs: tuple = (),
    count: bool = False,
    neg_from: int | None = None,
    window: int,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """One fused, self-feeding pass per expansion level (cand pre-padded
    to block multiples — ops.level_expand handles that).

    mask[b, d] = (∀p: cand[b, d] ∈ flat[starts[p,b] : +lens[p,b]])
               ∧ extras(b, d), where extras applies dirs[e] ∈
    {+1: cand > extra[b, e], -1: cand <, 0: cand !=}.  `count=True`
    instead returns cnt[b] = Σ_d mask[b, d] via the in-kernel popcount
    accumulator; with `neg_from` set, columns ≥ neg_from are weighted −1
    (the fused IEP prefix-correction tail — DESIGN.md §4).

    `window` (static) bounds every row length and sets how many
    `block_l`-blocks the grid walks per predecessor.  DMA safety
    contract: every row lies inside the unpadded flat array
    (starts[p, b] + lens[p, b] ≤ F, as real CSR rows do) and flat
    carries ≥ block_l − 1 trailing sentinels — the DMA skip only reads
    l-blocks below a row's length, so reads end before
    F + block_l (ops.flat_gather_pad / device_graph provide the pad).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = cand.shape
    P, Bs = starts.shape
    assert B == Bs and P >= 1, (cand.shape, starts.shape)
    assert lens.shape == (P, B), (lens.shape, starts.shape)
    assert B % block_b == 0 and D % block_d == 0
    nl = max(-(-window // block_l), 1)
    grid = (B // block_b, D // block_d, P * nl)
    in_specs = [
        pl.BlockSpec((block_b, block_d), lambda i, j, k, ss, ls: (i, j)),
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec((1, block_b), lambda i, j, k, ss, ls: (k // nl, i)),
    ]
    operands = [cand, flat, lens]
    if dirs:
        assert extra is not None and extra.shape == (B, len(dirs))
        in_specs.append(
            pl.BlockSpec((block_b, len(dirs)),
                         lambda i, j, k, ss, ls: (i, 0)))
        operands.append(extra)
    scratch = [
        pltpu.VMEM((block_b, block_l), jnp.int32),
        pltpu.VMEM((block_b, block_d), jnp.int32),
    ]
    if count:
        out_specs = pl.BlockSpec((block_b, 1), lambda i, j, k, ss, ls: (i, 0))
        out_shape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        scratch.append(pltpu.VMEM((block_b, 1), jnp.int32))
    else:
        out_specs = pl.BlockSpec((block_b, block_d),
                                 lambda i, j, k, ss, ls: (i, j))
        out_shape = jax.ShapeDtypeStruct((B, D), jnp.bool_)
    scratch.append(pltpu.SemaphoreType.DMA((block_b,)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        functools.partial(
            _level_expand_body, n_preds=P, nl=nl, dirs=tuple(dirs),
            count=count, neg_from=neg_from,
            block_b=block_b, block_d=block_d, block_l=block_l),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(starts, lens, *operands)
    return out[:, 0] if count else out


def membership_pallas(
    cand: jax.Array,
    nbr: jax.Array,
    *,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """mask[b, d] = cand[b, d] ∈ nbr[b, :].  Shapes must be pre-padded to
    block multiples (ops.sorted_membership handles that)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = cand.shape
    Bn, L = nbr.shape
    assert B == Bn, (cand.shape, nbr.shape)
    assert B % block_b == 0 and D % block_d == 0 and L % block_l == 0
    grid = (B // block_b, D // block_d, L // block_l)
    return pl.pallas_call(
        functools.partial(_membership_body, block_l=block_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_l), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.bool_),
        interpret=interpret,
    )(cand, nbr)


def intersect_count_pallas(
    cand: jax.Array,
    nbr: jax.Array,
    *,
    block_b: int = 8,
    block_d: int = 128,
    block_l: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """cnt[b] = |{d : cand[b, d] ∈ nbr[b, :]}| (int32), fully fused: the
    d and l reductions happen in-kernel, output is one scalar per row."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, D = cand.shape
    _, L = nbr.shape
    assert B % block_b == 0 and D % block_d == 0 and L % block_l == 0
    grid = (B // block_b, D // block_d, L // block_l)
    out = pl.pallas_call(
        functools.partial(_count_body, block_l=block_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_l), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, 1), jnp.int32)],
        interpret=interpret,
    )(cand, nbr)
    return out[:, 0]
