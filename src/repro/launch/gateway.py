"""The unified serving front door: graph queries + LM decode, one mesh.

    PYTHONPATH=src python -m repro.launch.gateway --dataset tiny-er \
        --workload smoke --arch qwen3-1.7b --gen 8 --batch 2 \
        --prompt-len 16 --graph-quantum 4 --lm-quantum 2

Builds ONE Gateway that owns the process mesh and co-schedules two
tenants on it: a `GraphQueryWorkload` (the pattern-query engine's
ticket queue — same request format and synthetic workloads as
`launch/query_serve.py`, and bit-identical counts: only the scheduling
differs) and an `LMDecodeWorkload` (`LMSession`, resumable).  The round
scheduler interleaves them under the per-workload Share policy
(quantum/weight/priority); same-isomorphism-class graph queries that
land in one round coalesce into a single plan execution.

`--no-lm` serves graph traffic only (the trace-identity configuration:
a request file replayed here and through `launch/query_serve.py` must
produce identical counts per query).  `--model-buckets` sizes the
executor's degree buckets from the perf model's predicted frontier
occupancy instead of the legacy 4×-margin heuristic.

`--listen PORT` turns the process into the multi-tenant RPC front door
(serve/rpc.py): instead of draining a fixed workload and exiting, the
gateway stays resident and N client processes submit/poll/cancel
tickets over length-prefixed JSON frames (`python -m repro.serve.rpc
--connect HOST:PORT --requests trace.jsonl`).  PORT 0 binds an
ephemeral port; `--port-file` writes "host port" once bound so scripts
can rendezvous.  `--preempt-dispatches` bounds kernel dispatches per
round (huge queries checkpoint and resume), `--tenant-depth` bounds
each tenant's queue (admission control).
"""
from __future__ import annotations

import argparse
import sys

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    # ---- graph-query tenant
    ap.add_argument("--dataset", default="tiny-er")
    ap.add_argument("--requests", default="",
                    help="JSON-lines request file (overrides --workload)")
    ap.add_argument("--workload", default="mixed",
                    choices=["mixed", "smoke"])
    ap.add_argument("--repeat", type=int, default=1)
    ap.add_argument("--use-iep", action="store_true")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--capacity", type=int, default=1 << 15)
    ap.add_argument("--chunk", type=int, default=0)
    ap.add_argument("--max-entries", type=int, default=256)
    ap.add_argument("--cache-dir", default="",
                    help="persistent plan store (DESIGN.md §5)")
    ap.add_argument("--warm-from-disk", action="store_true")
    ap.add_argument("--model-buckets", action="store_true",
                    help="size degree buckets from the perf model's "
                         "predicted frontier occupancy (default: legacy "
                         "4x-margin heuristic)")
    ap.add_argument("--graph-quantum", type=int, default=4,
                    help="graph tickets per scheduler turn (duplicates "
                         "within a turn coalesce)")
    ap.add_argument("--expect-min-hits", type=int, default=-1)
    ap.add_argument("--expect-coalesced", type=int, default=-1,
                    help="fail unless >= this many tickets coalesced")
    # ---- LM tenant
    ap.add_argument("--no-lm", action="store_true",
                    help="graph-only (trace-identity mode)")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--full-lm", action="store_true",
                    help="full config instead of the CPU smoke variant")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--lm-quantum", type=int, default=2,
                    help="decode steps per scheduler turn")
    ap.add_argument("--lm-weight", type=int, default=1,
                    help="LM turns per round (fair-share weight)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    # ---- RPC front door / multi-tenancy
    ap.add_argument("--listen", type=int, default=-1, metavar="PORT",
                    help="serve tickets over a socket instead of draining "
                         "a fixed workload (0 = ephemeral port)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port-file", default="",
                    help="write 'host port' here once the socket is bound")
    ap.add_argument("--preempt-dispatches", type=int, default=0,
                    help="kernel-dispatch budget per engine round (0 = "
                         "run every class to completion)")
    ap.add_argument("--tenant-depth", type=int, default=0,
                    help="max queued tickets per tenant (0 = unbounded)")
    ap.add_argument("--live", action="store_true",
                    help="serve over a MUTABLE graph: accept mutate RPC "
                         "verbs (insert_edges/delete_edges/compact), "
                         "applied at round boundaries via the delta "
                         "overlay (src/repro/live/)")
    # ---- shared
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--single-device", action="store_true",
                    help="graph engine off the mesh (LM still uses it)")
    ap.add_argument("--seed", type=int, default=0)
    from ..obs.cli import add_trace_args, finish_tracing, start_tracing

    add_trace_args(ap)
    args = ap.parse_args(argv)

    from ..configs.graphpi import get_dataset, get_pattern
    from ..core.executor import ExecutorConfig, auto_buckets, compute_stats
    from ..launch.mesh import shared_host_mesh
    from ..launch.query_serve import build_requests
    from ..obs import MetricsRegistry
    from ..query import PlanCache, PlanStore, QueryEngine, canonical_key
    from ..serve.gateway import (
        Gateway, GraphQueryWorkload, LMDecodeWorkload, Share,
    )
    from ..serve.session import LMSession

    start_tracing(args)

    if args.warm_from_disk and not args.cache_dir:
        print("[gateway] --warm-from-disk requires --cache-dir")
        return 2
    if args.resume and not args.ckpt_dir:
        print("[gateway] --resume requires --ckpt-dir")
        return 2

    mesh = shared_host_mesh(model=args.model_axis)
    graph = get_dataset(args.dataset)
    graph_mesh = None
    if not args.single_device and len(jax.devices()) > 1:
        graph_mesh = mesh

    cfg = ExecutorConfig(capacity=args.capacity)
    stats = None
    if args.model_buckets:
        stats = compute_stats(graph, cfg)
        from dataclasses import replace

        cfg = replace(cfg, degree_buckets=auto_buckets(graph, stats=stats))
    store = PlanStore(args.cache_dir) if args.cache_dir else None
    # ONE registry for the whole front door: the engine's query-latency
    # histogram and the scheduler's per-share turn histograms land in
    # the same snapshot (and reset_window resets both at once)
    metrics = MetricsRegistry()
    engine = QueryEngine(
        graph, cfg=cfg, mesh=graph_mesh, chunk=args.chunk or None,
        cache=PlanCache(max_entries=args.max_entries or None, store=store),
        stats=stats, metrics=metrics,
        preempt_dispatches=args.preempt_dispatches or None,
        tenant_depth=args.tenant_depth or None,
        live=args.live or None,
    )
    print(f"[gateway] graph={graph.name} (|V|={graph.n}, |E|={graph.m}) "
          f"resident on {engine.summary()['devices']} device(s)"
          f"{'; LIVE (mutable, delta overlay)' if args.live else ''}"
          f"{'; model buckets ' + repr(cfg.degree_buckets) if args.model_buckets else ''}")
    if args.warm_from_disk:
        n = engine.warm_from_disk()
        print(f"[gateway] warm-from-disk: {n} plan(s) preloaded")

    listen = args.listen >= 0
    # a listening server starts with an empty queue unless a trace file
    # pre-seeds it — clients are the request source
    requests = [] if (listen and not args.requests) \
        else build_requests(args, get_pattern)
    distinct = len({canonical_key(r.pattern) for r in requests})
    print(f"[gateway] {len(requests)} graph requests "
          f"({distinct} distinct isomorphism classes)")

    gw = Gateway(mesh=mesh, metrics=metrics)
    graph_wl = gw.add(GraphQueryWorkload(engine, requests),
                      Share(quantum=max(args.graph_quantum, 1)))
    if not args.no_lm:
        session = LMSession(
            args.arch, smoke=not args.full_lm, batch=args.batch,
            prompt_len=args.prompt_len, gen=args.gen, mesh=mesh,
            seed=args.seed, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, metrics=metrics,
        )
        gw.add(LMDecodeWorkload(session, resume=args.resume),
               Share(quantum=max(args.lm_quantum, 1),
                     weight=max(args.lm_weight, 1)))
        print(f"[gateway] lm={args.arch} "
              f"({'smoke' if not args.full_lm else 'full'}): "
              f"{args.batch}x{args.prompt_len} prompt, {args.gen} steps")

    if listen:
        from ..serve.rpc import GatewayRPCServer

        server = GatewayRPCServer(gw, graph_wl, host=args.host,
                                  port=args.listen,
                                  get_pattern=get_pattern)

        def on_ready(host, port):
            print(f"[gateway] listening on {host}:{port}", flush=True)
            if args.port_file:
                import os
                tmp = args.port_file + ".tmp"
                with open(tmp, "w") as f:
                    f.write(f"{host} {port}\n")
                os.replace(tmp, args.port_file)

        server.serve_forever(on_ready=on_ready)
        s = engine.summary()
        print(f"[gateway] served {server.rounds} rounds over "
              f"{server.connections} connection(s): "
              f"{s['requests_resolved']} requests, "
              f"{s['executions']} executions, {s['coalesced']} coalesced, "
              f"{s['preemptions']} preemptions, "
              f"{s['rejections']} rejected")
        if args.live:
            lv = s["live"]
            print(f"[gateway] live: edge_epoch={lv['edge_epoch']} "
                  f"mutations={lv['mutations_applied']} "
                  f"compactions={lv['compactions']} "
                  f"rebinds={lv['matcher_rebinds']} "
                  f"incremental={lv['incremental_hits']} "
                  f"memo_hits={lv['memo_hits']}")
        finish_tracing(args, registry=metrics, tag="gateway")
        return 0

    gw.run()

    results = graph_wl.results()
    for r in results:
        print("[gateway]", r.line())

    rep = gw.report()
    names = [t.name for t in gw.trace.turns]
    print(f"[gateway] {rep['rounds']} rounds, interleaving: "
          f"{' '.join(names[:24])}{' ...' if len(names) > 24 else ''}")
    s = engine.summary()
    print(f"[gateway] graph: {s['requests_resolved']} requests, "
          f"{s['executions']} executions, {s['coalesced']} coalesced; "
          f"p50={s['latency']['p50_ms']:.1f}ms "
          f"p99={s['latency']['p99_ms']:.1f}ms; "
          f"cache {s['cache']['hits']} hits / {s['cache']['misses']} misses")
    # interference evidence: per-item turn latency split solo vs
    # contended, for every workload that has either bin (a tenant the
    # other side outlasts is 100% contended — still worth printing; the
    # solo baseline then comes from benchmarks/gateway_mix.py's
    # dedicated solo phase)
    for name, wr in rep["workloads"].items():
        tm = wr["turn_item_ms"]
        parts = [f"{bin_} {tm[bin_]['p50_ms']:.1f}ms (n={tm[bin_]['n']})"
                 for bin_ in ("solo", "contended") if tm[bin_]["n"]]
        if not parts:
            continue
        x = (f"; contended/solo = {wr['interference_x']:.2f}x"
             if "interference_x" in wr else "")
        print(f"[gateway] {name} per-item turn p50: "
              f"{', '.join(parts)}{x}")
    if not args.no_lm:
        m = rep["workloads"]["lm"]["metrics"]
        how = (f"resumed from step {m['resumed_from']}"
               if m["resumed_from"] is not None
               else f"prefill {m['prefill_seconds']:.3f}s")
        print(f"[gateway] lm: {m['steps_done']}/{m['steps_total']} steps "
              f"({how}, {m['decode_tok_s']:.1f} tok/s, "
              f"{m['ms_per_step']:.1f} ms/step)")

    finish_tracing(args, registry=metrics, tag="gateway")

    rc = 0
    bad = [r for r in results if r.verified is False]
    if bad:
        print(f"[gateway] VERIFY FAILED for {[r.pattern_name for r in bad]}")
        rc = 1
    over = [r for r in results if r.overflowed]
    if over:
        print(f"[gateway] OVERFLOWED (truncated counts) for "
              f"{[r.pattern_name for r in over]}")
        rc = rc or 3
    if args.expect_min_hits >= 0 and s["cache"]["hits"] < args.expect_min_hits:
        print(f"[gateway] EXPECTED >= {args.expect_min_hits} cache hits, "
              f"got {s['cache']['hits']}")
        rc = rc or 2
    if args.expect_coalesced >= 0 and s["coalesced"] < args.expect_coalesced:
        print(f"[gateway] EXPECTED >= {args.expect_coalesced} coalesced "
              f"tickets, got {s['coalesced']}")
        rc = rc or 2
    return rc


if __name__ == "__main__":
    sys.exit(main())
