"""Pattern-query serving driver (the request path of the ROADMAP's
serve-path integration).

    PYTHONPATH=src python -m repro.launch.query_serve --dataset tiny-er
    PYTHONPATH=src python -m repro.launch.query_serve --dataset tiny-er \
        --workload smoke --verify --expect-min-hits 1
    PYTHONPATH=src python -m repro.launch.query_serve --dataset small-rmat \
        --requests reqs.jsonl

Loads the dataset ONCE into a `QueryEngine` (CSR resident on the mesh
when >1 device) and streams a workload of pattern-count requests
through the `PlanCache`.  Requests come from a JSON-lines file —

    {"pattern": "P1"}
    {"pattern": "P2", "use_iep": true, "verify": true}
    {"pattern": {"n": 3, "edges": [[0, 1], [1, 2], [0, 2]]}}

— or from a synthetic workload: `mixed` serves three distinct patterns
plus isomorphic relabelings of each (cache hits), `smoke` is the
2-pattern CI variant.  Per-query latency, p50/p99, and the cache
counters (hits never re-search or re-JIT) are reported at the end.

Since the Gateway landed this CLI is a thin client of it: requests are
enqueued as tickets on a `GraphQueryWorkload` and drained by the round
scheduler (`--round-quantum` tickets per round; same-class duplicates
within a round coalesce into one execution).  Counts are bit-identical
to the direct engine path — only the scheduling differs.  Mixed
graph + LM traffic lives in `launch/gateway.py`.

With `--cache-dir` the plan cache persists across restarts (searched
configurations + AOT-compiled executables, DESIGN.md §5): a restarted
replica replays a prior workload with zero configuration searches and
zero fresh JIT traces.  `--warm-from-disk` preloads every compatible
persisted plan before the first request.  `scripts/plan_warmup.py`
populates a store offline (P1–P6 × modes).
"""
from __future__ import annotations

import argparse
import json
import sys

import jax


def build_requests(args, get_pattern):
    from ..core.pattern import Pattern
    from ..query import QueryRequest, relabeled_variant

    if args.requests:
        reqs = []
        with open(args.requests) as f:
            for line in f:
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                spec = json.loads(line)
                pat = spec["pattern"]
                if isinstance(pat, str):
                    pattern = get_pattern(pat)
                else:
                    pattern = Pattern(
                        int(pat["n"]),
                        tuple((int(u), int(v)) for u, v in pat["edges"]),
                        name=pat.get("name", "inline"),
                    )
                reqs.append(QueryRequest(
                    pattern,
                    use_iep=bool(spec.get("use_iep", args.use_iep)),
                    verify=bool(spec.get("verify", args.verify)),
                    mode=spec.get("mode", "graphpi"),
                ))
        return reqs

    names = {"mixed": ["P1", "P2", "P4"], "smoke": ["P1", "P2"]}[args.workload]
    reqs = []
    for rep in range(max(args.repeat, 1)):
        for i, name in enumerate(names):
            p = get_pattern(name)
            # original first, then an isomorphic relabeling — the relabeled
            # re-query MUST be a plan-cache hit
            reqs.append(QueryRequest(p, use_iep=args.use_iep,
                                     verify=args.verify))
            reqs.append(QueryRequest(
                relabeled_variant(p, seed=args.seed + 7 * rep + i),
                use_iep=args.use_iep, verify=args.verify))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tiny-er")
    ap.add_argument("--requests", default="",
                    help="JSON-lines request file (overrides --workload)")
    ap.add_argument("--workload", default="mixed",
                    choices=["mixed", "smoke"])
    ap.add_argument("--repeat", type=int, default=1,
                    help="synthetic workload rounds")
    ap.add_argument("--use-iep", action="store_true")
    ap.add_argument("--verify", action="store_true",
                    help="check every count against the oracle (small graphs)")
    ap.add_argument("--capacity", type=int, default=1 << 15)
    ap.add_argument("--chunk", type=int, default=0,
                    help="outer-loop vertex chunk (0 = executor default)")
    ap.add_argument("--max-entries", type=int, default=256,
                    help="plan-cache LRU bound (0 = unbounded)")
    ap.add_argument("--cache-dir", default="",
                    help="persistent plan store directory: searched "
                         "configurations + AOT executables survive "
                         "restarts (DESIGN.md §5)")
    ap.add_argument("--warm-from-disk", action="store_true",
                    help="preload every compatible persisted plan before "
                         "serving (requires --cache-dir)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--single-device", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--expect-min-hits", type=int, default=-1,
                    help="fail unless the cache records >= this many hits")
    ap.add_argument("--round-quantum", type=int, default=1,
                    help="tickets per scheduler round; >1 coalesces "
                         "same-class duplicates within a round into one "
                         "execution")
    from ..obs.cli import add_trace_args, finish_tracing, start_tracing

    add_trace_args(ap)
    args = ap.parse_args(argv)

    from ..configs.graphpi import get_dataset, get_pattern
    from ..core.executor import ExecutorConfig
    from ..launch.mesh import shared_host_mesh
    from ..obs import MetricsRegistry
    from ..query import PlanCache, PlanStore, QueryEngine, canonical_key
    from ..serve.gateway import Gateway, GraphQueryWorkload, Share

    start_tracing(args)

    if args.warm_from_disk and not args.cache_dir:
        print("[serve] --warm-from-disk requires --cache-dir")
        return 2

    graph = get_dataset(args.dataset)
    mesh = None
    if not args.single_device and len(jax.devices()) > 1:
        mesh = shared_host_mesh(model=args.model_axis)
    store = PlanStore(args.cache_dir) if args.cache_dir else None
    # one registry shared by engine and gateway (one snapshot per run)
    metrics = MetricsRegistry()
    engine = QueryEngine(
        graph,
        cfg=ExecutorConfig(capacity=args.capacity),
        mesh=mesh,
        chunk=args.chunk or None,
        cache=PlanCache(max_entries=args.max_entries or None, store=store),
        metrics=metrics,
    )
    print(f"[serve] graph={graph.name} (|V|={graph.n}, |E|={graph.m}) "
          f"resident on {engine.summary()['devices']} device(s); "
          f"stats in {engine.stats_seconds:.2f}s (tri_cnt="
          f"{engine.stats.tri_cnt})")
    if store is not None:
        print(f"[serve] plan store at {store.vdir} ({len(store)} entries)")
    if args.warm_from_disk:
        n = engine.warm_from_disk()
        print(f"[serve] warm-from-disk: {n} plan(s) preloaded "
              f"({engine.cache.stats.aot_loads} AOT executables, "
              f"{engine.cache.stats.n_compiles} re-JITs)")

    requests = build_requests(args, get_pattern)
    distinct = len({canonical_key(r.pattern) for r in requests})
    print(f"[serve] {len(requests)} requests "
          f"({distinct} distinct isomorphism classes)")

    gw = Gateway(mesh=mesh, metrics=metrics)
    workload = gw.add(GraphQueryWorkload(engine, requests),
                      Share(quantum=max(args.round_quantum, 1)))
    gw.run()
    results = workload.results()
    for r in results:
        print("[serve]", r.line())

    s = engine.summary()
    lat, cache = s["latency"], s["cache"]
    print(f"[serve] latency: n={lat['n']} p50={lat['p50_ms']:.1f}ms "
          f"p99={lat['p99_ms']:.1f}ms mean={lat['mean_ms']:.1f}ms")
    print(f"[serve] rounds: {gw.report()['rounds']} "
          f"({s['requests_resolved']} requests, {s['executions']} "
          f"executions, {s['coalesced']} coalesced)")
    print(f"[serve] cache: {cache['hits']} hits / {cache['misses']} misses "
          f"({s['cache_entries']} entries); {cache['n_searches']} config "
          f"searches ({cache['search_seconds']:.3f}s), {cache['n_compiles']} "
          f"compiles ({cache['compile_seconds']:.3f}s)")
    if "store" in s:
        print(f"[serve] store: {cache['persist_hits']} persist hits "
              f"({cache['aot_loads']} AOT loads in "
              f"{cache['aot_load_seconds']:.3f}s, "
              f"{cache['aot_load_fails']} AOT rejects), "
              f"{s['store']['saves']} saves, "
              f"{cache['export_fails']} export failures, "
              f"rejects={s['store']['rejects']}")

    finish_tracing(args, registry=metrics, tag="serve")

    rc = 0
    bad = [r for r in results if r.verified is False]
    if bad:
        print(f"[serve] VERIFY FAILED for {[r.pattern_name for r in bad]}")
        rc = 1
    over = [r for r in results if r.overflowed]
    if over:
        # frontier exceeded MAX_CAPACITY: those counts are undercounts
        print(f"[serve] OVERFLOWED (truncated counts) for "
              f"{[r.pattern_name for r in over]}")
        rc = rc or 3
    if args.expect_min_hits >= 0 and cache["hits"] < args.expect_min_hits:
        print(f"[serve] EXPECTED >= {args.expect_min_hits} cache hits, "
              f"got {cache['hits']}")
        rc = rc or 2
    return rc


if __name__ == "__main__":
    sys.exit(main())
