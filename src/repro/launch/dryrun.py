import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Roofline mode: Pallas kernels lower as HBM-footprint-equivalent stubs
# (opaque custom calls on real hardware too); their MXU flops are added
# analytically below.  Tests/examples run the real interpret-mode kernels.
os.environ["REPRO_FLASH_STUB"] = "1"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder devices.

For each cell this:
  1. builds abstract params/batch/cache (jax.eval_shape — no allocation),
  2. jits the train/prefill/decode step with the production shardings,
  3. .lower().compile() against the requested mesh,
  4. records memory_analysis / cost_analysis / collective bytes
     (roofline terms) into a JSON artifact.

Also includes the GraphPi cell (`--arch graphpi`): the paper's
distributed counting kernel lowered over the same mesh.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh multi --out artifacts/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np


def model_flops_estimate(cfg, shape) -> float:
    """6·N_active·D for train (fwd+bwd), 2·N_active·D for serving."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch            # one new token per sequence
    return 2.0 * n * tokens


def flash_kernel_flops(cfg, shape, mesh) -> float:
    """Per-DEVICE MXU flops of the stubbed Pallas flash-attention calls.

    Engages only where layers._flash_sharded would: prefill, Sq == Sk,
    S % 512 == 0, hd <= 128.  qk^T + pv = 4·B·H·S²·hd, halved for causal
    masking (block-skipped above the diagonal).  Sharding: batch over the
    data axes and — when H divides |model| — heads over `model`;
    otherwise the kernel is replicated over `model` (dp-only fallback)."""
    if shape.kind != "prefill" or cfg.n_heads == 0:
        return 0.0
    S, B = shape.seq_len, shape.global_batch
    if S % 512 or cfg.head_dim > 128:
        return 0.0
    from ..models.transformer import layer_kinds

    n_causal = sum(1 for k in layer_kinds(cfg) if k == "attn")
    # whisper: bidirectional encoder self-attn + per-decoder-layer cross
    n_full = cfg.enc_layers + (cfg.n_layers if cfg.family == "encdec" else 0)
    per_layer = 4.0 * B * cfg.n_heads * float(S) ** 2 * cfg.head_dim
    total = per_layer * (0.5 * n_causal + n_full)
    mdl = mesh.shape.get("model", 1)
    ndp = 1
    for a in ("pod", "data"):
        if a in mesh.shape and B % (ndp * mesh.shape[a]) == 0:
            ndp *= mesh.shape[a]
    shards = ndp * (mdl if cfg.n_heads % mdl == 0 else 1)
    return total / shards


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str, *,
               opts=None):
    """Lower+compile one cell; returns (compiled, model_flops)."""
    from ..compat import set_mesh
    from ..configs import SHAPES, get_config, input_specs
    from ..models import transformer as T
    from ..serve.serve_step import make_decode, make_prefill
    from ..train.optimizer import AdamWConfig, init_opt_state
    from ..train.train_step import TrainOptions, abstract_params, make_train_step

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mf = model_flops_estimate(cfg, shape)

    with set_mesh(mesh):
        if shape.kind == "train":
            batch_shape = input_specs(cfg, shape)
            opts = opts or TrainOptions()
            step, p_sh, o_sh, b_sh = make_train_step(
                cfg, AdamWConfig(), mesh, opts, batch_shape
            )
            p_shape = abstract_params(cfg)
            o_shape = jax.eval_shape(init_opt_state, p_shape)
            lowered = step.lower(p_shape, o_shape, batch_shape)
        elif shape.kind == "prefill":
            batch_shape = input_specs(cfg, shape)
            step, p_sh, b_sh = make_prefill(cfg, mesh, batch_shape)
            lowered = step.lower(abstract_params(cfg), batch_shape)
        else:  # decode
            step, p_sh, c_sh, cache_shape = make_decode(
                cfg, mesh, batch=shape.global_batch, max_seq=shape.seq_len
            )
            tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = step.lower(abstract_params(cfg), tok, cache_shape, pos)
        compiled = lowered.compile()
    return compiled, mf


def lower_graphpi(mesh, mesh_name: str, *, buckets: bool | None = None):
    """The paper's cell: distributed house-pattern counting on the mesh.

    `buckets` toggles the degree-bucketed expansion (§Perf): None reads
    REPRO_GRAPHPI_BUCKETS (default on; set 0 for the paper-faithful
    single-window baseline).  REPRO_GRAPHPI_MODEL_BUCKETS=1 sizes the
    bucket fractions from the perf model's predicted frontier occupancy
    instead of the legacy 4×-margin heuristic."""
    from ..core.config_search import search_configuration
    from ..core.executor import (
        ExecutorConfig, _bs_iters, _make_count_fn, device_graph,
        auto_buckets,
    )
    from ..core.pattern import house
    from ..core.perf_model import GraphStats
    from ..graph.datasets import rmat
    from jax.sharding import PartitionSpec as P

    if buckets is None:
        buckets = os.environ.get("REPRO_GRAPHPI_BUCKETS", "1") == "1"
    g = rmat(16, 12, seed=0)                 # 65k vertices, ~700k edges
    stats = GraphStats(g.n, g.m, tri_cnt=max(g.m, 1))  # plan-time proxy
    res = search_configuration(house(), stats, use_iep=True)
    plan = res.plan(house())
    model_buckets = os.environ.get("REPRO_GRAPHPI_MODEL_BUCKETS", "0") == "1"
    cfg = ExecutorConfig(
        capacity=1 << 15,
        degree_buckets=auto_buckets(
            g, stats=stats if model_buckets else None) if buckets else None,
    )
    W = max(g.max_degree, 1)
    count_fn = _make_count_fn(plan, W, _bs_iters(W), cfg)
    indptr, degrees, flat = (np.asarray(x) for x in device_graph(g)[:3])

    axes = [a for a in mesh.axis_names if a != "model"]
    nsh = int(np.prod([mesh.shape[a] for a in axes]))
    per = -(-g.n // nsh)
    v0 = np.full(nsh * per, g.n, dtype=np.int32)
    v0[: g.n] = np.arange(g.n, dtype=np.int32)
    v0 = v0.reshape(per, nsh).T.reshape(-1)
    ax = tuple(axes) if len(axes) > 1 else axes[0]

    def shard_fn(indptr, degrees, flat, v0_local):
        cnt, needed = count_fn(indptr, degrees, flat, v0_local)
        return jax.lax.psum(cnt, ax), jax.lax.pmax(needed, ax)

    from ..compat import enable_x64, shard_map

    with enable_x64(True):
        fn = jax.jit(
            shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(), P(), P(), P(ax)),
                out_specs=(P(), P()),
            )
        )
        lowered = fn.lower(
            jax.ShapeDtypeStruct(indptr.shape, indptr.dtype),
            jax.ShapeDtypeStruct(degrees.shape, degrees.dtype),
            jax.ShapeDtypeStruct(flat.shape, flat.dtype),
            jax.ShapeDtypeStruct(v0.shape, v0.dtype),
        )
        compiled = lowered.compile()
    # "model flops" proxy: ~W compares per expanded embedding is data-dep;
    # report 0 and rely on the measured terms for this cell.
    return compiled, 0.0


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str):
    from ..launch.mesh import make_production_mesh
    from ..roofline.analysis import analyze

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    extra_flops = 0.0
    if arch == "graphpi":
        compiled, mf = lower_graphpi(mesh, mesh_name)
    else:
        compiled, mf = lower_cell(arch, shape_name, mesh, mesh_name)
        from ..configs import SHAPES, get_config

        extra_flops = flash_kernel_flops(get_config(arch), SHAPES[shape_name],
                                         mesh)
    dt = time.time() - t0
    r = analyze(arch, shape_name, mesh_name, chips, compiled, mf,
                extra_flops_per_device=extra_flops)
    rec = r.to_json()
    rec["compile_seconds"] = dt
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = str(ma)
    except Exception as e:  # pragma: no cover
        rec["memory_analysis"] = f"unavailable: {e}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(
        f"[dryrun OK] {arch} × {shape_name} × {mesh_name}: "
        f"compile={dt:.1f}s compute={r.compute_s:.4f}s memory={r.memory_s:.4f}s "
        f"collective={r.collective_s:.4f}s bottleneck={r.bottleneck} "
        f"useful={r.useful_flops_ratio:.2f}"
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    from ..configs import ARCHS, supported_shapes

    cells = []
    if args.all:
        for a in ARCHS:
            for s in supported_shapes(a):
                cells.append((a, s))
        cells.append(("graphpi", "count"))
    else:
        assert args.arch, "--arch or --all required"
        shapes = [args.shape] if args.shape else (
            ["count"] if args.arch == "graphpi"
            else supported_shapes(args.arch))
        cells = [(args.arch, s) for s in shapes]

    failures = []
    for a, s in cells:
        try:
            run_cell(a, s, args.mesh, args.out)
        except Exception as e:
            failures.append((a, s, repr(e)))
            print(f"[dryrun FAIL] {a} × {s} × {args.mesh}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")


if __name__ == "__main__":
    main()
