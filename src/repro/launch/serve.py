"""Batched serving driver: continuous prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 64 --gen 32

Runs the production serving path (same make_prefill/make_decode the
dry-run lowers) on the host mesh: prefill a batch of prompts, then decode
`--gen` tokens greedily, reporting per-phase throughput.  With --smoke
the reduced same-family config is used so the loop runs on CPU.

Fault tolerance hooks mirror the trainer: the decode loop checkpoints its
cache + tokens every --ckpt-every steps (restartable serving for long
generations — a 500k-token decode at 1000-node scale must survive
preemption).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=0,
                    help="cache size (default prompt+gen)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args(argv)

    from ..configs import get_config, get_smoke_config, input_specs
    from ..configs.base import ShapeConfig
    from ..compat import set_mesh
    from ..launch.mesh import make_host_mesh
    from ..models import transformer as T
    from ..serve.serve_step import make_decode, make_prefill
    from ..train import checkpoint as ckpt
    from ..train.train_step import abstract_params

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(model=args.model_axis)
    B, S = args.batch, args.prompt_len
    max_seq = args.max_seq or (S + args.gen)

    key = jax.random.PRNGKey(args.seed)
    with set_mesh(mesh):
        params = jax.jit(lambda k: T.init(cfg, k))(key)

        # ---- prefill --------------------------------------------------------
        shape = ShapeConfig("serve", S, B, "prefill")
        batch = _fake_prompts(cfg, B, S, key)
        prefill, p_sh, b_sh = make_prefill(cfg, mesh, input_specs(cfg, shape),
                                           q_chunk=0)
        t0 = time.perf_counter()
        logits, prefill_cache = jax.block_until_ready(prefill(params, batch))
        t_prefill = time.perf_counter() - t0
        print(f"[serve] prefill: {B}×{S} tokens in {t_prefill:.3f}s "
              f"({B * S / t_prefill:.0f} tok/s)  logits={logits.shape}")

        # ---- decode ---------------------------------------------------------
        decode, _, c_sh, cache_shape = make_decode(
            cfg, mesh, batch=B, max_seq=max_seq
        )
        cache = jax.jit(
            lambda: T.init_cache(cfg, B, max_seq), out_shardings=c_sh
        )()
        cache = _seed_cache(cache, prefill_cache, S)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        generated = [np.asarray(tokens)]
        t0 = time.perf_counter()
        for i in range(args.gen):
            pos = jnp.asarray(S + i, jnp.int32)
            logits, cache = decode(params, tokens, cache, pos)
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            generated.append(np.asarray(tokens))
            if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, i + 1,
                          {"cache": cache, "tokens": tokens})
        jax.block_until_ready(tokens)
        t_dec = time.perf_counter() - t0
        print(f"[serve] decode: {args.gen} steps × {B} seqs in {t_dec:.3f}s "
              f"({args.gen * B / t_dec:.1f} tok/s, "
              f"{1e3 * t_dec / args.gen:.1f} ms/step)")
        out = np.concatenate(generated, axis=1)
        print(f"[serve] sample tokens[0,:16] = {out[0, :16].tolist()}")
    return 0


def _fake_prompts(cfg, B, S, key):
    if cfg.stub_frontend and cfg.family == "vlm":
        return {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16),
            "positions3": jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (B, 3, S)
            ),
        }
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, S, cfg.d_model), jnp.bfloat16
        )
    return batch


def _seed_cache(cache, prefill_cache, S):
    """Copy prefill K/V (length S) into the front of the decode cache."""
    import jax

    def put(dst, src):
        if dst.ndim >= 2 and src.ndim == dst.ndim and src.shape != dst.shape:
            # K/V: [..., S, K, hd] into [..., max_seq, K, hd]
            ax = next(
                i for i in range(dst.ndim) if src.shape[i] != dst.shape[i]
            )
            idx = [slice(None)] * dst.ndim
            idx[ax] = slice(0, src.shape[ax])
            return dst.at[tuple(idx)].set(src.astype(dst.dtype))
        return src.astype(dst.dtype) if src.shape == dst.shape else dst

    if "blocks" in prefill_cache:
        new_blocks = jax.tree.map(put, cache["blocks"], prefill_cache["blocks"])
        cache = {**cache, "blocks": new_blocks}
    if "cross_kv" in prefill_cache:
        cache = {**cache, "cross_kv": put(cache["cross_kv"],
                                          prefill_cache["cross_kv"])}
    return cache


if __name__ == "__main__":
    sys.exit(main())
