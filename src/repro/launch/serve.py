"""Batched serving driver: continuous prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 64 --gen 32

Thin client of the serving Gateway (`repro.serve.gateway`): builds one
`LMSession` (the reusable prefill/decode loop extracted from the old
monolithic main) and schedules it as the Gateway's sole workload.  With
--smoke the reduced same-family config is used so the loop runs on CPU.
Mixed graph-query + LM traffic lives in `launch/gateway.py`.

Fault tolerance mirrors the trainer — and now actually round-trips: the
decode loop checkpoints its cache + tokens every --ckpt-every steps,
and `--resume` reloads the latest step and continues decoding
(restartable serving for long generations — a 500k-token decode at
1000-node scale must survive preemption).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=0,
                    help="cache size (default prompt+gen)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest --ckpt-dir checkpoint "
                         "(cache+tokens+step) and continue decoding")
    ap.add_argument("--step-quantum", type=int, default=0,
                    help="decode steps per scheduler turn (0 = all)")
    args = ap.parse_args(argv)

    from ..launch.mesh import shared_host_mesh
    from ..serve.gateway import Gateway, LMDecodeWorkload, Share
    from ..serve.session import LMSession

    if args.resume and not args.ckpt_dir:
        print("[serve] --resume requires --ckpt-dir")
        return 2

    mesh = shared_host_mesh(model=args.model_axis)
    session = LMSession(
        args.arch, smoke=args.smoke, batch=args.batch,
        prompt_len=args.prompt_len, gen=args.gen, max_seq=args.max_seq,
        mesh=mesh, seed=args.seed,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    gw = Gateway(mesh=mesh)
    gw.add(LMDecodeWorkload(session, resume=args.resume),
           Share(quantum=args.step_quantum or args.gen))
    gw.run()

    m = session.metrics()
    B, S = args.batch, args.prompt_len
    if session.resumed_from is not None:
        print(f"[serve] resumed from checkpoint step {session.resumed_from} "
              f"(skipped prefill; {m['steps_total'] - session.resumed_from} "
              f"steps remained)")
    else:
        tp = B * S / m["prefill_seconds"] if m["prefill_seconds"] else 0.0
        print(f"[serve] prefill: {B}×{S} tokens in "
              f"{m['prefill_seconds']:.3f}s ({tp:.0f} tok/s)")
    steps = m["steps_done"] - (session.resumed_from or 0)
    print(f"[serve] decode: {steps} steps × {B} seqs in "
          f"{m['decode_seconds']:.3f}s ({m['decode_tok_s']:.1f} tok/s, "
          f"{m['ms_per_step']:.1f} ms/step)")
    out = session.tokens_out()
    print(f"[serve] sample tokens[0,:16] = {out[0, :16].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
