"""End-to-end training driver (fault-tolerant).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 300 --batch 8 --seq 256 --smoke --ckpt-dir /tmp/ckpt

Fault tolerance:
 * periodic atomic checkpoints (params + opt state + step);
 * resume: picks up from LATEST automatically, data pipeline is a pure
   function of step → exact stream continuation;
 * SIGTERM/SIGINT (preemption) → checkpoint now → exit 0;
 * elastic: restart with a different device count / mesh reshapes the
   checkpoint onto the new topology (shardings recomputed at load).
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    from ..configs import get_config, get_smoke_config, input_specs
    from ..configs.base import ShapeConfig
    from ..compat import set_mesh
    from ..launch.mesh import make_host_mesh
    from ..train import checkpoint as ckpt
    from ..train.data import DataConfig, SyntheticLM
    from ..train.optimizer import AdamWConfig, init_opt_state
    from ..train.train_step import (
        TrainOptions, abstract_params, init_sharded, make_train_step,
    )

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(model=args.model_axis)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    batch_shape = input_specs(cfg, shape)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5))

    with set_mesh(mesh):
        step_fn, p_sh, o_sh, b_sh = make_train_step(
            cfg, opt_cfg, mesh,
            TrainOptions(remat=True, q_chunk=0, loss_chunk=0,
                         accum_steps=args.accum),
            batch_shape,
        )
        start = 0
        if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
            p_shape = abstract_params(cfg)
            o_shape = jax.eval_shape(init_opt_state, p_shape)
            (params, opt_state), start = _restore(
                args.ckpt_dir, p_shape, o_shape, p_sh, o_sh
            )
            print(f"[train] resumed from step {start}")
        else:
            params, opt_state, _, _ = init_sharded(cfg, mesh)

        data = SyntheticLM(
            DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch), cfg
        )

        stop = {"now": False}

        def _sig(_s, _f):
            stop["now"] = True

        signal.signal(signal.SIGTERM, _sig)
        signal.signal(signal.SIGINT, _sig)

        t0 = time.time()
        tokens_done = 0
        for s in range(start, args.steps):
            batch = jax.device_put(data.batch(s), b_sh)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            tokens_done += args.batch * args.seq
            if (s + 1) % args.log_every == 0:
                dt = time.time() - t0
                print(
                    f"step {s+1}/{args.steps} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"lr={float(metrics['lr']):.2e} "
                    f"tok/s={tokens_done/dt:.0f}"
                )
            want_ckpt = args.ckpt_dir and (
                (s + 1) % args.ckpt_every == 0 or stop["now"]
                or s + 1 == args.steps
            )
            if want_ckpt:
                ckpt.save(args.ckpt_dir, s + 1,
                          {"p": params, "o": opt_state})
                if stop["now"]:
                    print(f"[train] preempted at step {s+1}; "
                          "checkpointed, exiting cleanly")
                    return 0
    print("[train] done")
    return 0


def _restore(ckpt_dir, p_shape, o_shape, p_sh, o_sh):
    from ..train import checkpoint as ckpt

    tree, step = ckpt.restore(
        ckpt_dir, {"p": p_shape, "o": o_shape},
        shardings={"p": p_sh, "o": o_sh},
    )
    return (tree["p"], tree["o"]), step


if __name__ == "__main__":
    sys.exit(main())
