"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).  Mesh
construction goes through repro.compat.make_mesh, which drops the
axis-types kwarg on JAX releases that predate jax.sharding.AxisType
(every axis is implicitly auto there — the semantics we want)."""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: (16, 16) single pod, (2, 16, 16) two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=("auto",) * len(axes))


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (CI/local): data × model."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=("auto", "auto"),
    )


_SHARED_MESHES: dict[int, object] = {}


def shared_host_mesh(model: int = 1):
    """The process-wide mesh the serving Gateway owns.

    Co-scheduled workloads (graph queries + LM decode) must share ONE
    device pool — two independently constructed meshes over the same
    devices would each believe they own the hardware.  This memoizes
    `make_host_mesh` per model-axis width so every Gateway tenant in a
    process resolves to the same Mesh object."""
    if model not in _SHARED_MESHES:
        _SHARED_MESHES[model] = make_host_mesh(model=model)
    return _SHARED_MESHES[model]


HW = {
    # TPU v5e per-chip numbers used for the roofline terms
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_link_bw": 50e9,           # B/s per link (prompt-specified)
    "hbm_bytes": 16e9,
}
