"""End-to-end distributed pattern-matching driver (the paper's workload).

    PYTHONPATH=src python -m repro.launch.mine --pattern P1 --dataset tiny-er
    PYTHONPATH=src python -m repro.launch.mine --pattern P2 --dataset small-rmat \
        --use-iep --verify

Pipeline (paper Fig. 3): restriction generation (Alg. 1) → 2-phase
schedule generation → performance-model configuration selection → JAX
compilation → distributed counting (shard_map over the host mesh's data
axis, fine-grained task striping).  `--mode graphzero` runs the baseline
(single restriction set, degree-heuristic schedule) for comparison.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="P1")
    ap.add_argument("--dataset", default="tiny-er")
    ap.add_argument("--mode", default="graphpi",
                    choices=["graphpi", "graphzero", "naive"])
    ap.add_argument("--use-iep", action="store_true")
    ap.add_argument("--verify", action="store_true",
                    help="check against the pure-python oracle (small graphs)")
    ap.add_argument("--capacity", type=int, default=1 << 15)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--single-device", action="store_true")
    args = ap.parse_args(argv)

    from ..configs.graphpi import get_dataset, get_pattern
    from ..core.config_search import graphzero_configuration, search_configuration
    from ..core.executor import (
        ExecutorConfig, compute_stats, count_embeddings,
        count_embeddings_sharded,
    )
    from ..core.plan import build_plan
    from ..core.restrictions import generate_restriction_sets
    from ..launch.mesh import make_host_mesh

    pattern = get_pattern(args.pattern)
    graph = get_dataset(args.dataset)
    cfg = ExecutorConfig(capacity=args.capacity)
    print(f"[mine] pattern={pattern.name} (n={pattern.n}, m={pattern.m}, "
          f"|Aut|={pattern.aut_count()})  graph={graph.name} "
          f"(|V|={graph.n}, |E|={graph.m}, max_deg={graph.max_degree})")

    # -- preprocessing (paper: configuration generation + prediction) -------
    t0 = time.perf_counter()
    stats = compute_stats(graph, cfg)
    t_stats = time.perf_counter() - t0
    print(f"[mine] stats: tri_cnt={stats.tri_cnt} ({t_stats:.2f}s)")

    t0 = time.perf_counter()
    if args.mode == "graphpi":
        res = search_configuration(pattern, stats, use_iep=args.use_iep)
        best = res.best
        print(f"[mine] searched {len(res.all_configs)} configurations "
              f"({res.n_schedules} schedules × {res.n_restriction_sets} "
              f"restriction sets) in {res.preprocess_seconds:.3f}s")
    elif args.mode == "graphzero":
        best = graphzero_configuration(pattern, stats, use_iep=args.use_iep)
    else:  # naive: no restrictions; divide by |Aut| afterwards
        res = search_configuration(pattern, stats, use_iep=False)
        best = res.best
    t_pre = time.perf_counter() - t0

    res_set = () if args.mode == "naive" else best.res_set
    plan = build_plan(pattern, best.order, res_set, iep_k=best.iep_k)
    print(f"[mine] config: schedule={best.order} restrictions={res_set} "
          f"iep_k={best.iep_k} predicted_cost={best.predicted_cost:.3e} "
          f"(preprocess {t_pre:.3f}s)")

    # -- distributed counting ------------------------------------------------
    t0 = time.perf_counter()
    if args.single_device or len(jax.devices()) == 1:
        out = count_embeddings(graph, plan, cfg)
    else:
        mesh = make_host_mesh(model=args.model_axis)
        out = count_embeddings_sharded(graph, plan, mesh, cfg=cfg)
    dt = time.perf_counter() - t0
    count = out.count // pattern.aut_count() if args.mode == "naive" else out.count

    print(f"[mine] count={count}  wall={dt:.3f}s  "
          f"(max frontier rows used: {out.max_needed}"
          f"{', OVERFLOWED' if out.overflowed else ''})")

    if args.verify:
        from ..core.oracle import count_embeddings_oracle

        t0 = time.perf_counter()
        expect = count_embeddings_oracle(graph.n, graph.edge_array(), pattern)
        print(f"[mine] oracle={expect} ({time.perf_counter() - t0:.2f}s)  "
              f"{'OK' if expect == count else 'MISMATCH'}")
        if expect != count:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
