"""End-to-end distributed pattern-matching driver (the paper's workload).

    PYTHONPATH=src python -m repro.launch.mine --pattern P1 --dataset tiny-er
    PYTHONPATH=src python -m repro.launch.mine --pattern P2 --dataset small-rmat \
        --use-iep --verify

Pipeline (paper Fig. 3): restriction generation (Alg. 1) → 2-phase
schedule generation → performance-model configuration selection → JAX
compilation → distributed counting (shard_map over the host mesh's data
axis, fine-grained task striping).  `--mode graphzero` runs the baseline
(single restriction set, degree-heuristic schedule) for comparison.

Since the query-serving subsystem landed, this CLI is a one-request
client of the same `PlanCache`/`QueryEngine` code path that
`launch/query_serve.py` serves traffic through — there is exactly one
request path.  With `--cache-dir` repeat invocations load the persisted
plan (and its AOT executable) instead of re-searching/re-tracing.
"""
from __future__ import annotations

import argparse
import sys

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="P1")
    ap.add_argument("--dataset", default="tiny-er")
    ap.add_argument("--mode", default="graphpi",
                    choices=["graphpi", "graphzero", "naive"])
    ap.add_argument("--use-iep", action="store_true")
    ap.add_argument("--verify", action="store_true",
                    help="check against the pure-python oracle (small graphs)")
    ap.add_argument("--capacity", type=int, default=1 << 15)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--single-device", action="store_true")
    ap.add_argument("--cache-dir", default="",
                    help="persistent plan store: repeat invocations skip "
                         "the configuration search (and the JIT, single-"
                         "device) via the on-disk cache (DESIGN.md §5)")
    from ..obs.cli import add_trace_args, finish_tracing, start_tracing

    add_trace_args(ap)
    args = ap.parse_args(argv)

    from ..configs.graphpi import get_dataset, get_pattern
    from ..core.executor import ExecutorConfig
    from ..launch.mesh import make_host_mesh
    from ..query import PlanStore, QueryEngine, QueryRequest

    start_tracing(args)

    pattern = get_pattern(args.pattern)
    graph = get_dataset(args.dataset)
    print(f"[mine] pattern={pattern.name} (n={pattern.n}, m={pattern.m}, "
          f"|Aut|={pattern.aut_count()})  graph={graph.name} "
          f"(|V|={graph.n}, |E|={graph.m}, max_deg={graph.max_degree})")

    mesh = None
    if not args.single_device and len(jax.devices()) > 1:
        mesh = make_host_mesh(model=args.model_axis)
    store = PlanStore(args.cache_dir) if args.cache_dir else None
    engine = QueryEngine(graph, cfg=ExecutorConfig(capacity=args.capacity),
                         mesh=mesh, store=store)
    print(f"[mine] stats: tri_cnt={engine.stats.tri_cnt} "
          f"({engine.stats_seconds:.2f}s)")

    res = engine.submit(QueryRequest(
        pattern, use_iep=args.use_iep, verify=args.verify, mode=args.mode))
    cs = engine.cache.stats
    how = ("cache hit" if res.cache_hit
           else "persisted plan" if cs.persist_hits else "cache miss")
    print(f"[mine] config: schedule={res.order} restrictions={res.res_set} "
          f"iep_k={res.iep_k} (search {res.search_seconds:.3f}s, "
          f"compile {res.compile_seconds:.3f}s, {how}"
          f"{', AOT executable' if cs.aot_loads else ''})")
    exec_s = res.latency_s - res.search_seconds - res.compile_seconds
    print(f"[mine] count={res.count}  wall={exec_s:.3f}s  "
          f"(query latency {res.latency_s:.3f}s incl. search+compile; "
          f"max frontier rows used: {res.max_needed}"
          f"{', OVERFLOWED' if res.overflowed else ''})")

    finish_tracing(args, registry=engine.metrics, tag="mine")

    if args.verify:
        print(f"[mine] oracle={res.expected}  "
              f"{'OK' if res.verified else 'MISMATCH'}")
        if not res.verified:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
