"""Pass 1 — plan/restriction soundness, proved without touching a graph.

GraphPi's counting correctness rests on plan-time invariants that are
only enforced at construction time; this pass re-proves them for any
(Pattern, Schedule, RestrictionSet, IEP split) — or a whole persisted
`MatchingPlan` record — so the PlanStore fsck and the CI gate can catch
schema drift, buggy writers, or hand-edited records before they serve a
wrong count.

What "sound" means here (paper §IV, GraphZero's linear-ordering form):

  * partition: the automorphism group acting on id-orders must tile S_n
    so every subgraph instance is found EXACTLY once — for every order
    σ, #{p ∈ Aut : σ∘p satisfies R} == 1.  This single condition
    implies both the paper's validate() count
    (#satisfying orders == n!/|Aut|, i.e. the multi-set of |Aut|
    transformed sets covers all n! orders) and survivor elimination
    (only the identity survives `no_conflict`).  All three are checked
    independently — they fail differently under different corruptions.
  * schedule: a permutation of 0..n-1, prefix-connected (every loop
    intersects at least one earlier neighborhood — otherwise candidate
    generation is unseeded and the executor's predecessor gather is
    ill-defined).
  * restrictions are checkable where scheduled: each (a, b) is enforced
    at max(pos[a], pos[b]); under an IEP split only positions < depth
    are enumerated, so tail restrictions must be dropped AND the
    surviving prefix set must give a CONSTANT per-subgraph multiplicity
    (plan.py's `iep_multiplicity`) matching the plan's divisor.
  * IEP tail: the folded vertices must be pairwise non-adjacent in the
    schedule-relabeled pattern.
  * derived-field drift (plans only): preds/neqs/restr/iep are persisted
    pre-derived for O(read) loads; they must equal a fresh
    `build_plan` of the same inputs bit-for-bit.

Everything is pure Python/numpy over n ≤ 8 patterns — milliseconds,
same ballpark as the paper's plan-time stage (Table III).
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np

from ..core.pattern import Pattern, Perm, identity_perm
from ..core.restrictions import (
    Restriction, count_orders_satisfying, perm_matrix, surviving_perms,
)
from ..core.schedule import Schedule, is_prefix_connected
from .findings import ERROR, INFO, Finding


def _err(rule: str, location: str, message: str) -> Finding:
    return Finding(ERROR, rule, location, message)


# ------------------------------------------------------- restriction sets
def partition_multiplicities(
    pattern: Pattern, res_set: Sequence[Restriction]
) -> np.ndarray:
    """m[σ] = #{p ∈ Aut : σ∘p satisfies res_set} for every σ ∈ S_n.

    A restriction set is sound iff m == 1 everywhere: each subgraph
    instance (generic id ranking σ) is counted exactly once.  This is
    the full-set case of plan.py's `iep_multiplicity`.
    """
    n = pattern.n
    sigmas = perm_matrix(n)
    m = np.zeros(len(sigmas), dtype=np.int64)
    for p in pattern.automorphisms():
        ok = np.ones(len(sigmas), dtype=bool)
        for (a, b) in res_set:
            ok &= sigmas[:, p[a]] > sigmas[:, p[b]]
        m += ok
    return m


def verify_restriction_set(
    pattern: Pattern,
    res_set: Sequence[Restriction],
    *,
    complete: bool = True,
    location: str = "",
) -> list[Finding]:
    """Prove `res_set` sound for `pattern` (no graph needed).

    `complete=False` (the naive-mode shape: empty set, count divided by
    |Aut| afterwards) skips the automorphism-elimination proofs and only
    validates structure.
    """
    loc = location or f"{pattern.name or 'pattern'} res_set={tuple(res_set)}"
    out: list[Finding] = []
    n = pattern.n

    seen: set[tuple[int, int]] = set()
    for (a, b) in res_set:
        if not (0 <= a < n and 0 <= b < n) or a == b:
            out.append(_err(
                "restriction-range", loc,
                f"restriction ({a}, {b}) is malformed for n={n}"))
        elif (a, b) in seen:
            out.append(_err(
                "restriction-range", loc, f"duplicate restriction ({a}, {b})"))
        elif (b, a) in seen:
            out.append(_err(
                "restriction-range", loc,
                f"contradictory pair ({a}, {b}) and ({b}, {a}): no id order "
                f"can satisfy both"))
        seen.add((a, b))
    if out or not complete:
        return out            # group-theory proofs need well-formed input

    auts = pattern.automorphisms()
    ident = identity_perm(n)
    survivors = surviving_perms(auts, tuple(res_set))
    if survivors != [ident]:
        extra = [p for p in survivors if p != ident]
        out.append(_err(
            "restriction-survivors", loc,
            f"{len(extra)} non-identity automorphism(s) survive, e.g. "
            f"{extra[0] if extra else survivors}; every embedding would be "
            f"found multiple times"))

    target = math.factorial(n) // len(auts)
    got = count_orders_satisfying(n, tuple(res_set))
    if got != target:
        out.append(_err(
            "restriction-order-count", loc,
            f"{got} id-orders satisfy the set; a complete set keeps exactly "
            f"n!/|Aut| = {target} (GraphZero: the |Aut| transformed sets "
            f"must tile all n! orders)"))

    m = partition_multiplicities(pattern, res_set)
    if not (m == 1).all():
        over = int((m > 1).sum())
        under = int((m == 0).sum())
        out.append(_err(
            "restriction-partition", loc,
            f"automorphism orbits do not partition the order space: "
            f"{over} orders counted multiple times, {under} never counted"))
    return out


# --------------------------------------------------------------- schedules
def verify_schedule(
    pattern: Pattern, order: Schedule, *, location: str = ""
) -> list[Finding]:
    loc = location or f"{pattern.name or 'pattern'} order={tuple(order)}"
    out: list[Finding] = []
    if sorted(order) != list(range(pattern.n)):
        out.append(_err(
            "schedule-permutation", loc,
            f"order {tuple(order)} is not a permutation of 0..{pattern.n - 1}"))
        return out
    if not is_prefix_connected(pattern, order):
        out.append(_err(
            "schedule-connected", loc,
            "schedule is not prefix-connected: some loop has no earlier "
            "neighbor to intersect against (unseeded candidate set)"))
    return out


# ----------------------------------------------------------- configurations
def verify_configuration(
    pattern: Pattern,
    order: Schedule,
    res_set: Sequence[Restriction],
    iep_k: int = 0,
    *,
    expected_divisor: int | None = None,
    complete: bool = True,
    location: str = "",
) -> list[Finding]:
    """Prove a whole (schedule × restriction set × IEP split) sound."""
    loc = location or (f"{pattern.name or 'pattern'} order={tuple(order)} "
                       f"iep_k={iep_k}")
    out = verify_schedule(pattern, order, location=loc)
    out += verify_restriction_set(
        pattern, res_set, complete=complete, location=loc)
    if any(f.rule in ("schedule-permutation", "restriction-range")
           for f in out):
        return out            # position math below needs sane input
    n = pattern.n
    if not (0 <= iep_k < n):
        out.append(_err(
            "iep-split-range", loc,
            f"iep_k={iep_k} out of range for n={n} (need 0 <= k < n: at "
            f"least one explicit loop)"))
        return out

    pos = {v: i for i, v in enumerate(order)}
    depth = n - iep_k

    # restrictions landing at folded positions >= depth are never
    # enumerated; build_plan drops them into the divisor, so here they
    # are only an observation — the iep-multiplicity check below is what
    # proves the drop sound
    if iep_k > 0:
        for (a, b) in res_set:
            p = max(pos[a], pos[b])
            if p >= depth:
                out.append(Finding(
                    INFO, "restriction-folded", loc,
                    f"restriction ({a}, {b}) lands at folded position {p} "
                    f">= depth {depth}; dropped into the IEP divisor"))

        rel_adj = pattern.relabel(order).adjacency()
        tail = range(depth, n)
        bad = [(int(a), int(b)) for a in tail for b in tail
               if a < b and rel_adj[a, b]]
        if bad:
            out.append(_err(
                "iep-tail-independent", loc,
                f"IEP tail positions {list(tail)} are not an independent "
                f"set (adjacent pairs {bad}): the closed-form cardinality "
                f"product is invalid"))

        from ..core.plan import iep_multiplicity

        surviving = tuple((a, b) for (a, b) in res_set
                          if max(pos[a], pos[b]) < depth)
        div = iep_multiplicity(pattern, surviving)
        if div is None:
            out.append(_err(
                "iep-multiplicity", loc,
                f"surviving restrictions {surviving} give a NON-CONSTANT "
                f"per-subgraph multiplicity; no single divisor makes "
                f"IEP k={iep_k} exact for this schedule"))
        elif expected_divisor is not None and div != expected_divisor:
            out.append(_err(
                "iep-multiplicity", loc,
                f"recorded IEP divisor {expected_divisor} != recomputed "
                f"multiplicity {div}; the replayed count would be off by "
                f"{expected_divisor}/{div}x"))
    elif expected_divisor is not None and expected_divisor != 1:
        out.append(_err(
            "iep-multiplicity", loc,
            f"divisor {expected_divisor} recorded without an IEP tail "
            f"(k=0 always divides by 1)"))
    return out


# ----------------------------------------------------------------- plans
def verify_plan(plan, *, mode: str = "graphpi",
                location: str = "") -> list[Finding]:
    """Prove a compiled/persisted `MatchingPlan` sound end to end.

    Beyond the configuration proofs this cross-checks every persisted
    DERIVED field (preds/neqs/restr/iep/divisor) against a fresh
    `build_plan` of the same inputs: the store's load path is O(read)
    by design (plan_to_dict persists the derivation), which is exactly
    where schema drift or a buggy writer silently corrupts counts.
    """
    from ..core.plan import build_plan

    loc = location or (f"plan[{plan.pattern.name or 'anon'} "
                       f"order={tuple(plan.order)}]")
    iep_k = plan.iep.k if plan.iep is not None else 0
    out = verify_configuration(
        plan.pattern, plan.order, plan.res_set, iep_k,
        expected_divisor=plan.iep_divisor,
        complete=(mode != "naive"),
        location=loc,
    )
    if plan.n != plan.pattern.n:
        out.append(_err(
            "plan-derived-drift", loc,
            f"plan.n={plan.n} != pattern.n={plan.pattern.n}"))
    # every persisted positional restriction must be checkable where it
    # is scheduled: against an EARLIER position, at an ENUMERATED one —
    # a tampered/drifted entry here compares against a vertex that is
    # unassigned (or never materialized) at check time
    depth = plan.depth
    for i, entries in enumerate(plan.restr):
        for (other, _dir) in entries:
            if not (0 <= other < i) or i >= depth:
                out.append(_err(
                    "restriction-checkable", loc,
                    f"restr[{i}] entry (other={other}, dir={_dir}) is not "
                    f"checkable: needs 0 <= other < {i} < depth {depth}"))
    if any(f.rule in ("schedule-permutation", "restriction-range",
                      "iep-split-range") for f in out):
        return out
    try:
        rebuilt = build_plan(plan.pattern, plan.order, plan.res_set,
                             iep_k=iep_k)
    except Exception as e:          # noqa: BLE001 — any rebuild failure
        out.append(_err(
            "plan-rebuild", loc,
            f"build_plan rejects the plan's own inputs: {e}"))
        return out
    # vlabels is derived too: it must be the pattern's labels permuted to
    # schedule order — a record whose labels and vlabels disagree serves
    # a different typed query than its key claims
    for field in ("preds", "neqs", "restr", "iep", "iep_divisor",
                  "vlabels"):
        want = getattr(rebuilt, field)
        got = getattr(plan, field)
        if got != want:
            out.append(_err(
                "plan-derived-drift", loc,
                f"persisted {field}={got!r} != derived {want!r} for the "
                f"recorded (pattern, order, res_set, iep_k)"))
    return out
