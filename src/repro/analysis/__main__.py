"""Front door: `python -m repro.analysis` — run the static verifier.

Default runs all three passes over the repo and the P1–P6 pattern
library; exit status is 1 iff any ERROR finding is produced, so the CI
gate and `scripts/static_check.sh` are just this module's exit code.

  python -m repro.analysis                      # lint + kernel + soundness
  python -m repro.analysis --lint               # one pass only
  python -m repro.analysis --soundness
  python -m repro.analysis --kernel-contracts --deep
  python -m repro.analysis --fsck /path/to/plan-store
  python -m repro.analysis --root /some/checkout --lint
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .findings import Finding, error_count, format_findings
from .kernel_contracts import check_graph_contract
from .lint import lint_tree
from .soundness import verify_plan, verify_restriction_set

# shape-only contract probes at paper scale (n, m, max_degree) — graphs
# CI cannot materialize but production serves (Table I ballpark)
_PAPER_SHAPES = (
    ("wiki-vote", (7_115, 103_689, 1_065)),
    ("patents", (3_774_768, 16_518_948, 793)),
    ("orkut", (3_072_441, 117_185_083, 33_313)),
)


def run_lint(root: Path) -> list[Finding]:
    return lint_tree(root)


def run_soundness() -> list[Finding]:
    """Prove every restriction set the planner can generate for the
    benchmark patterns, then one end-to-end plan per pattern."""
    from ..configs.graphpi import EXTRA_PATTERNS, PATTERNS
    from ..core.plan import best_iep_k, build_plan
    from ..core.restrictions import generate_restriction_sets
    from ..core.schedule import generate_schedules

    out: list[Finding] = []
    for name, pat in {**PATTERNS, **EXTRA_PATTERNS}.items():
        for rs in generate_restriction_sets(pat):
            out += verify_restriction_set(
                pat, rs, location=f"{name} res_set={tuple(rs)}")
        rs = generate_restriction_sets(pat)[0]
        order = next(iter(generate_schedules(pat)))
        k = best_iep_k(pat, order, rs)
        plan = build_plan(pat, order, rs, iep_k=k)
        out += verify_plan(plan, location=f"{name} plan iep_k={k}")
    return out


def run_kernel_contracts(*, deep: bool) -> list[Finding]:
    out: list[Finding] = []
    if deep:
        from ..graph.datasets import named_dataset

        out += check_graph_contract(named_dataset("tiny-er"), deep=True)
    for label, shape in _PAPER_SHAPES:
        for f in check_graph_contract(shape):
            out.append(Finding(f.severity, f.rule,
                               f"{label}/{f.location}", f.message))
    return out


def run_fsck(store_dir: Path) -> list[Finding]:
    from ..query.store import PlanStore

    store = PlanStore(store_dir)
    report = store.fsck()
    out: list[Finding] = []
    for digest, findings in report["findings"].items():
        out += findings
    sys.stdout.write(
        f"fsck: {report['checked']} records checked, "
        f"{report['quarantined']} quarantined, "
        f"{report['stats_checked']} stats records checked, "
        f"{report['overlays_checked']} overlay records checked\n")
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static soundness verifier (DESIGN.md, Static analysis layer)")
    ap.add_argument("--root", type=Path, default=Path.cwd(),
                    help="repo checkout to lint (default: cwd)")
    ap.add_argument("--lint", action="store_true",
                    help="repo-invariant AST lint only")
    ap.add_argument("--soundness", action="store_true",
                    help="plan/restriction soundness over P1-P6 only")
    ap.add_argument("--kernel-contracts", action="store_true",
                    help="kernel contract proofs only")
    ap.add_argument("--deep", action="store_true",
                    help="also abstractly trace kernel call sites "
                         "(eval_shape + jaxpr walk; needs jax)")
    ap.add_argument("--fsck", type=Path, metavar="DIR",
                    help="run PlanStore.fsck() on this store directory")
    args = ap.parse_args(argv)

    selected = args.lint or args.soundness or args.kernel_contracts \
        or args.fsck is not None
    findings: list[Finding] = []
    if args.lint or not selected:
        findings += run_lint(args.root)
    if args.kernel_contracts or not selected:
        findings += run_kernel_contracts(deep=args.deep)
    if args.soundness or not selected:
        findings += run_soundness()
    if args.fsck is not None:
        findings += run_fsck(args.fsck)

    errs = error_count(findings)
    print(format_findings(
        findings,
        header=f"repro.analysis: {len(findings)} finding(s), {errs} error(s)"))
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
