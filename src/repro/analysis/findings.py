"""Structured findings — the one output type every analysis pass emits.

A Finding is deliberately flat (severity, rule, location, message) so
passes compose: the CLI concatenates lists from independent passes, the
PlanStore fsck keys them per record digest, and tests assert on stable
`rule` identifiers instead of message text.

Severity policy (DESIGN.md §6):
  error    the checked object is UNSOUND — serving it can return wrong
           counts or crash on device; gates CI, quarantines fsck records.
  warning  suspicious but not provably wrong (e.g. a contract that holds
           only because of a current default); never gates.
  info     observations useful in reports (e.g. pass statistics).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Sequence

ERROR = "error"
WARNING = "warning"
INFO = "info"

_SEVERITIES = (ERROR, WARNING, INFO)


@dataclass(frozen=True)
class Finding:
    severity: str            # one of ERROR / WARNING / INFO
    rule: str                # stable kebab-case rule id (tests key on it)
    location: str            # "path.py:12" | "P1 order=(0,1,2)" | digest
    message: str             # human-readable diagnosis

    def __post_init__(self) -> None:
        if self.severity not in _SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not in {_SEVERITIES}")

    def line(self) -> str:
        return f"{self.severity.upper():<7} [{self.rule}] " \
               f"{self.location}: {self.message}"


def has_errors(findings: Iterable[Finding]) -> bool:
    return any(f.severity == ERROR for f in findings)


def error_count(findings: Iterable[Finding]) -> int:
    return sum(1 for f in findings if f.severity == ERROR)


def format_findings(findings: Sequence[Finding], *, header: str = "") -> str:
    out = [header] if header else []
    sev_rank = {ERROR: 0, WARNING: 1, INFO: 2}
    for f in sorted(findings, key=lambda f: (sev_rank[f.severity],
                                             f.location, f.rule)):
        out.append("  " + f.line())
    if not findings:
        out.append("  (no findings)")
    return "\n".join(out)
