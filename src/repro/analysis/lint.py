"""Pass 3 — repo-invariant lint: AST enforcement of rules the codebase
states only in comments.

The rule classes over `src/repro`:

  scheduler-no-jax        serve/scheduler.py promises "Nothing in this
                          module imports JAX" — the Gateway relies on it
                          to unit-test scheduling with scripted fakes
                          and to keep dispatch single-threaded semantics
                          out of the policy layer.
  scheduler-determinism   the round-robin path must be deterministic:
                          no `time.time`/`time.time_ns`, no `random`,
                          `numpy.random`, `secrets`, or `uuid` in
                          serve/scheduler.py (`repro.obs.timer` is the
                          sanctioned clock — it only feeds latency
                          reports, never ordering).
  no-raw-timing           modules under serve/ and query/ must not call
                          `time.perf_counter` (or `perf_counter_ns`,
                          `monotonic`, `monotonic_ns`, `process_time`)
                          directly: latency measured ad hoc never
                          reaches the metrics registry or the trace.
                          `repro.obs` (`timer()`, `Timer`, tracer
                          spans) is the one clock; obs/ itself is the
                          sanctioned home of the raw calls.
  compat-only-drift       JAX APIs that moved between releases
                          (shard_map, enable_x64, export,
                          sharding.set_mesh/get_abstract_mesh) are
                          shimmed once in compat.py; every other module
                          must import the shim, never either home
                          directly — old OR new, since using the new
                          home directly silently breaks the pin.
                          `jax.experimental.pallas` is not drifted and
                          stays allowed.
  no-tracer-concretize    inside jit-decorated functions and Pallas
                          kernel bodies (`*_body` / `*_kernel`),
                          `.item()`, `int(x)`, `float(x)` on traced
                          values raise ConcretizationTypeError at trace
                          time — or worse, silently constant-fold a
                          weak type.  Static-shape reads
                          (`int(x.shape[0])`, `len(...)`) are allowed.
  label-coverage          every identity/serialization surface that two
                          label variants of one skeleton could alias
                          through must keep referencing the labels
                          field: `canonical_key` + `_wl_cells`
                          (query/canon.py), `Pattern.to_dict` +
                          `_automorphisms_cached` (core/pattern.py),
                          `plan_to_dict` (core/plan.py, vlabels),
                          `fingerprint` (graph/csr.py), and the store's
                          `_record_labeled`.  A refactor that drops
                          labels from any of them would silently merge
                          a labeled pattern with its skeleton — cache
                          aliasing that no runtime check catches —
                          so the lint fails if the function loses its
                          labels reference OR disappears outright.
  no-stale-fingerprint    modules under serve/ and query/ must not stash
                          a graph fingerprint on long-lived object state
                          (`self.fp = graph.fingerprint`, `self._key =
                          graph_fingerprint(...)`): on a live engine the
                          graph mutates between rounds, so a captured
                          fingerprint silently keys new-epoch counts
                          under an old-epoch identity.  Hold an
                          `EpochStamp` (live/epoch.py) instead — it is
                          swapped atomically at round boundaries — and
                          read fingerprints through it at use sites.
                          Locals are fine; only attribute stores
                          (state that survives a round) are flagged.

Pure `ast` — no imports of the linted modules, so a module that fails
to import is still lintable (and a syntax error becomes a finding).
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import ERROR, Finding

# dotted names whose ONLY sanctioned home is compat.py (old + new homes)
_DRIFTED_ATTRS = {
    "jax.experimental.shard_map",
    "jax.experimental.enable_x64",
    "jax.experimental.export",
    "jax.shard_map",
    "jax.enable_x64",
    "jax.export",
    "jax.sharding.set_mesh",
    "jax.sharding.get_abstract_mesh",
}
# `from <module> import <name>` forms of the same APIs
_DRIFTED_FROM = {
    "jax.experimental": {"shard_map", "enable_x64", "export"},
    "jax.experimental.shard_map": None,      # None = any name
    "jax.experimental.export": None,
    "jax": {"shard_map", "enable_x64", "export"},
    "jax.sharding": {"set_mesh", "get_abstract_mesh"},
}

_NONDETERMINISTIC_MODULES = {"random", "secrets", "uuid"}
_NONDETERMINISTIC_ATTRS = {
    "time.time", "time.time_ns", "numpy.random", "np.random",
    "os.urandom",
}

# raw clocks forbidden outside repro/obs in the serving + query layers
_RAW_TIMING_NAMES = {
    "perf_counter", "perf_counter_ns", "monotonic", "monotonic_ns",
    "process_time", "process_time_ns",
}
_RAW_TIMING_ATTRS = {f"time.{n}" for n in _RAW_TIMING_NAMES}

# label-coverage: (path suffix) -> {function name: required token}.
# Each named function is an identity or serialization surface; losing
# its labels/vlabels reference would alias labeled patterns with their
# unlabeled skeletons somewhere downstream (cache keys, store records,
# graph fingerprints, automorphism groups).
_LABEL_SURFACES: dict[str, dict[str, str]] = {
    "core/pattern.py": {"to_dict": "labels",
                        "_automorphisms_cached": "labels"},
    "query/canon.py": {"canonical_key": "labels", "_wl_cells": "labels"},
    "core/plan.py": {"plan_to_dict": "vlabels"},
    "graph/csr.py": {"fingerprint": "labels"},
    "query/store.py": {"_record_labeled": "vlabels"},
}


def _in_timed_scope(rel: str) -> bool:
    """True for modules under serve/ or query/ (where `no-raw-timing`
    applies), excluding repro/obs — the one sanctioned home of the raw
    clock calls."""
    p = rel.replace("\\", "/")
    if "/obs/" in p or p.startswith("obs/"):
        return False
    return any(f"/{d}/" in p or p.startswith(f"{d}/")
               for d in ("serve", "query"))


def _err(rule: str, loc: str, msg: str) -> Finding:
    return Finding(ERROR, rule, loc, msg)


def _dotted(node: ast.AST) -> str | None:
    """'jax.sharding.set_mesh' for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _has_jit_decorator(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _dotted(target) or ""
        if name in ("jit", "jax.jit", "pjit", "jax.pjit"):
            return True
        # @partial(jax.jit, ...) / @functools.partial(jit, ...)
        if isinstance(dec, ast.Call) and name.split(".")[-1] == "partial":
            for arg in dec.args[:1]:
                inner = _dotted(arg) or ""
                if inner in ("jit", "jax.jit", "pjit", "jax.pjit"):
                    return True
    return False


def _is_static_shape_read(arg: ast.AST) -> bool:
    """int(x.shape[0]) / float(len(xs)) / int(x.ndim) are trace-safe."""
    for node in ast.walk(arg):
        if isinstance(node, ast.Attribute) and node.attr in (
                "shape", "ndim", "size", "dtype"):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "len":
            return True
    return False


def _check_traced_body(fn, rel: str) -> list[Finding]:
    out: list[Finding] = []
    for node in ast.walk(fn):
        loc = f"{rel}:{getattr(node, 'lineno', fn.lineno)}"
        if isinstance(node, ast.Call):
            callee = node.func
            if isinstance(callee, ast.Attribute) and callee.attr == "item":
                out.append(_err(
                    "no-tracer-concretize", loc,
                    f".item() inside traced function {fn.name!r} forces a "
                    f"device sync / concretization at trace time"))
            elif isinstance(callee, ast.Name) and callee.id in (
                    "int", "float") and node.args:
                arg = node.args[0]
                if not isinstance(arg, ast.Constant) \
                        and not _is_static_shape_read(arg):
                    out.append(_err(
                        "no-tracer-concretize", loc,
                        f"{callee.id}() on a (potentially traced) value "
                        f"inside {fn.name!r}; only static shape reads are "
                        f"trace-safe"))
    return out


def _mentions_fingerprint(node: ast.AST) -> bool:
    """Does this expression read a `.fingerprint` attribute (property or
    method) or call/reference `graph_fingerprint`?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "fingerprint":
            return True
        if isinstance(sub, ast.Name) and sub.id == "graph_fingerprint":
            return True
    return False


def _check_stale_fingerprint(node, rel: str) -> list[Finding]:
    """no-stale-fingerprint: an attribute store in serve/query whose
    value derives from a fingerprint captures graph identity on state
    that outlives the round — stale the moment a live engine mutates."""
    targets = node.targets if isinstance(node, ast.Assign) else [node.target]
    if not any(isinstance(sub, ast.Attribute)
               for t in targets for sub in ast.walk(t)):
        return []
    value = node.value
    if value is None or not _mentions_fingerprint(value):
        return []
    return [_err(
        "no-stale-fingerprint", f"{rel}:{node.lineno}",
        "fingerprint captured on long-lived state in the serve/query "
        "path; on a live engine it goes stale at the next mutation "
        "round — hold an EpochStamp (repro.live.epoch) and read "
        "fingerprints through it at use sites instead")]


def _references_token(fn: ast.AST, token: str) -> bool:
    """Does the function body mention `token` as an attribute, name, or
    string literal (dict key)?"""
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr == token:
            return True
        if isinstance(node, ast.Name) and node.id == token:
            return True
        if isinstance(node, ast.Constant) and node.value == token:
            return True
        if isinstance(node, ast.keyword) and node.arg == token:
            return True
    return False


def _check_label_surfaces(tree: ast.Module, rel: str,
                          surfaces: dict[str, str]) -> list[Finding]:
    found: set[str] = set()
    out: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        token = surfaces.get(node.name)
        if token is None:
            continue
        found.add(node.name)
        if not _references_token(node, token):
            out.append(_err(
                "label-coverage", f"{rel}:{node.lineno}",
                f"{node.name}() no longer references {token!r}: labeled "
                f"patterns would alias their unlabeled skeletons through "
                f"this identity/serialization surface"))
    for name in sorted(set(surfaces) - found):
        out.append(_err(
            "label-coverage", rel,
            f"expected label-carrying function {name}() not found; if it "
            f"was renamed, update _LABEL_SURFACES to keep the labels "
            f"field pinned to the new surface"))
    return out


def lint_source(src: str, rel: str) -> list[Finding]:
    """Lint one module's source; `rel` is the repo-relative path used in
    finding locations and to select per-file rules."""
    try:
        tree = ast.parse(src, filename=rel)
    except SyntaxError as e:
        return [_err("syntax", f"{rel}:{e.lineno or 0}", f"does not parse: {e.msg}")]

    posix = rel.replace("\\", "/")
    is_scheduler = posix.endswith("serve/scheduler.py")
    is_compat = posix.endswith("repro/compat.py")
    is_timed = _in_timed_scope(rel)
    out: list[Finding] = []
    for suffix, surfaces in _LABEL_SURFACES.items():
        if posix.endswith(suffix):
            out += _check_label_surfaces(tree, rel, surfaces)

    for node in ast.walk(tree):
        loc = f"{rel}:{getattr(node, 'lineno', 0)}"

        if isinstance(node, ast.Import):
            for alias in node.names:
                root = alias.name.split(".")[0]
                if is_scheduler and root == "jax":
                    out.append(_err(
                        "scheduler-no-jax", loc,
                        f"import {alias.name}: the scheduler is the "
                        f"JAX-free policy layer by contract"))
                if is_scheduler and root in _NONDETERMINISTIC_MODULES:
                    out.append(_err(
                        "scheduler-determinism", loc,
                        f"import {alias.name}: nondeterminism in the "
                        f"round-robin path breaks the tested interleaving"))

        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            root = mod.split(".")[0]
            if is_scheduler and root == "jax":
                out.append(_err(
                    "scheduler-no-jax", loc,
                    f"from {mod} import ...: the scheduler is the "
                    f"JAX-free policy layer by contract"))
            if is_scheduler and root in _NONDETERMINISTIC_MODULES:
                out.append(_err(
                    "scheduler-determinism", loc,
                    f"from {mod} import ...: nondeterminism in the "
                    f"round-robin path"))
            if is_timed and mod == "time":
                for a in node.names:
                    if a.name in _RAW_TIMING_NAMES:
                        out.append(_err(
                            "no-raw-timing", loc,
                            f"from time import {a.name}: raw timing in "
                            f"the serve/query path — use repro.obs "
                            f"(timer()/Timer or a tracer span) so the "
                            f"measurement reaches the metrics registry"))
            if not is_compat and mod in _DRIFTED_FROM:
                allowed = _DRIFTED_FROM[mod]
                names = [a.name for a in node.names
                         if allowed is None or a.name in allowed]
                for name in names:
                    out.append(_err(
                        "compat-only-drift", loc,
                        f"from {mod} import {name}: drifted JAX API — "
                        f"import it from repro.compat instead"))

        elif isinstance(node, ast.Attribute):
            name = _dotted(node)
            if name is None:
                continue
            if is_scheduler and name.split(".")[0] == "jax":
                out.append(_err(
                    "scheduler-no-jax", loc,
                    f"{name}: the scheduler must not touch JAX"))
            if not is_compat and name in _DRIFTED_ATTRS:
                out.append(_err(
                    "compat-only-drift", loc,
                    f"{name}: drifted JAX API — go through repro.compat"))
            if is_scheduler and name in _NONDETERMINISTIC_ATTRS:
                out.append(_err(
                    "scheduler-determinism", loc,
                    f"{name}: nondeterministic call in the round-robin "
                    f"path (repro.obs.timer is the sanctioned clock)"))
            if is_timed and name in _RAW_TIMING_ATTRS:
                out.append(_err(
                    "no-raw-timing", loc,
                    f"{name}: raw timing in the serve/query path — use "
                    f"repro.obs (timer()/Timer or a tracer span) so the "
                    f"measurement reaches the metrics registry"))

        elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            if is_timed:
                out += _check_stale_fingerprint(node, rel)

        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _has_jit_decorator(node) or node.name.endswith(("_body",
                                                              "_kernel")):
                out += _check_traced_body(node, rel)

    return out


def lint_path(path: Path, root: Path) -> list[Finding]:
    rel = str(path.relative_to(root))
    try:
        src = path.read_text()
    except OSError as e:
        return [_err("syntax", rel, f"unreadable: {e}")]
    return lint_source(src, rel)


def lint_tree(root: Path | str) -> list[Finding]:
    """Lint every Python module under `<root>/src/repro` (or `root`
    itself when it already points inside a source tree)."""
    root = Path(root)
    base = root / "src" / "repro"
    if not base.is_dir():
        base = root
    out: list[Finding] = []
    for path in sorted(base.rglob("*.py")):
        out += lint_path(path, root)
    return out
