# Static soundness verifier (DESIGN.md §6): plan/restriction soundness
# proofs (soundness), abstract kernel-contract checking
# (kernel_contracts), and the repo-invariant AST lint (lint), all
# reporting structured Finding records.  Front doors: the
# `python -m repro.analysis` CLI and `PlanStore.fsck()`.
from .findings import (
    ERROR, INFO, WARNING, Finding, error_count, format_findings, has_errors,
)
from .soundness import (
    verify_configuration, verify_plan, verify_restriction_set,
    verify_schedule,
)

__all__ = [
    "ERROR",
    "INFO",
    "WARNING",
    "Finding",
    "error_count",
    "format_findings",
    "has_errors",
    "verify_configuration",
    "verify_plan",
    "verify_restriction_set",
    "verify_schedule",
]
