"""Pass 2 — static kernel-contract checking for `level_expand_pallas`.

The fused level-expansion kernel (kernels/intersect.py) states its
safety contract only in docstrings: int32 operands, block-multiple
shapes, `block_l ≤ MAX_BLOCK_L` so `flat_gather_pad()` sentinels cover
the furthest in-grid DMA, rows inside the unpadded flat array, and CSR
offsets that fit int32.  Violations today surface at trace time or —
for the DMA window and offset-overflow cases — as wrong reads on
device.  This pass proves the contract abstractly for a given
`GraphCSR` shape and `ExecutorConfig`, mirroring the exact call shapes
the executor generates (one spec per degree bucket, enumeration and
IEP-tail variants), and abstractly evaluates the real `ops.level_expand`
wrapper via `jax.eval_shape` + jaxpr inspection so dtype/shape drift in
the wrapper itself is caught without compiling or running anything.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .findings import ERROR, WARNING, Finding

INT32_MAX = np.iinfo(np.int32).max


def _err(rule: str, loc: str, msg: str) -> Finding:
    return Finding(ERROR, rule, loc, msg)


@dataclass(frozen=True)
class LevelExpandSpec:
    """Static facets of one `ops.level_expand` call site.

    B/D are the pre-padding candidate-matrix shape (the wrapper pads to
    block multiples); `flat_len` is the UNPADDED CSR indices length
    (row extents must end inside it); `padded` says the caller ships a
    `flat_gather_pad()`-sentinel tail (`flat_padded=True` — the
    resident-graph path through `core.executor.device_graph`).
    """

    B: int                    # frontier rows
    D: int                    # candidate columns (window or window+depth)
    P: int                    # predecessor count
    E: int = 0                # extra (restriction/injectivity) columns
    window: int = 0           # static row-length bound (graph max degree)
    flat_len: int = 0         # unpadded flat CSR length (2m)
    count: bool = False
    neg_from: int | None = None
    padded: bool = True
    block_b: int = 8
    block_d: int = 128
    block_l: int = 128
    label: str = "level_expand"


def check_spec(spec: LevelExpandSpec) -> list[Finding]:
    """Contract proofs that need no tracing at all."""
    from ..kernels.ops import MAX_BLOCK_L, flat_gather_pad

    loc = spec.label
    out: list[Finding] = []
    if spec.block_l > MAX_BLOCK_L:
        out.append(_err(
            "kernel-dma-window", loc,
            f"block_l={spec.block_l} > MAX_BLOCK_L={MAX_BLOCK_L}: the "
            f"furthest in-grid DMA reads up to flat_len + block_l - 1, "
            f"past the {flat_gather_pad()}-sentinel pad — out-of-bounds "
            f"HBM reads on device"))
    if spec.padded and spec.window > 0 and spec.flat_len == 0:
        out.append(Finding(
            WARNING, "kernel-dma-window", loc,
            "flat_padded=True with an unknown flat length: cannot prove "
            "the row-extent invariant starts + lens <= flat_len"))
    for name, val, mult in (("block_b", spec.block_b, 8),
                            ("block_d", spec.block_d, 128),
                            ("block_l", spec.block_l, 128)):
        if val <= 0 or val % mult:
            out.append(_err(
                "kernel-block-shape", loc,
                f"{name}={val} is not a positive multiple of {mult} "
                f"(TPU lane/sublane tiling)"))
    if spec.window <= 0:
        out.append(_err(
            "kernel-window", loc,
            f"window={spec.window}: the grid would walk zero neighbor "
            f"blocks and every membership test would be vacuously false"))
    if spec.neg_from is not None and not (0 <= spec.neg_from <= spec.D):
        out.append(_err(
            "kernel-window", loc,
            f"neg_from={spec.neg_from} outside candidate columns "
            f"0..{spec.D}: the signed IEP popcount would mis-weight real "
            f"candidates"))

    # int32 offset overflow: the kernel computes starts + li*block_l in
    # int32 SMEM; the largest offset it can form is
    # flat_len + round_up(window, block_l).
    if spec.window > 0 and spec.block_l > 0:
        nl = max(-(-spec.window // spec.block_l), 1)
        reach = spec.flat_len + nl * spec.block_l
        if reach > INT32_MAX:
            out.append(_err(
                "kernel-int32-offset", loc,
                f"max DMA offset {reach} (flat_len={spec.flat_len} + "
                f"{nl}x{spec.block_l}) overflows int32: CSR offsets wrap "
                f"and the kernel reads the wrong neighborhoods"))
    return out


def abstract_eval_spec(spec: LevelExpandSpec) -> list[Finding]:
    """Trace (never run) the real `ops.level_expand` wrapper with this
    spec's abstract shapes: `jax.eval_shape` catches shape/dtype drift
    between the wrapper and the kernel, and the jaxpr walk proves a
    `pallas_call` with int32 operands is actually on the path."""
    import jax
    import jax.numpy as jnp

    from ..kernels import ops

    loc = spec.label
    out: list[Finding] = []
    pad = ops.flat_gather_pad() if spec.padded else 0
    cand = jax.ShapeDtypeStruct((spec.B, spec.D), jnp.int32)
    flat = jax.ShapeDtypeStruct((spec.flat_len + pad,), jnp.int32)
    starts = jax.ShapeDtypeStruct((spec.P, spec.B), jnp.int32)
    lens = jax.ShapeDtypeStruct((spec.P, spec.B), jnp.int32)
    dirs = tuple([0] * spec.E)
    extra = (jax.ShapeDtypeStruct((spec.B, spec.E), jnp.int32)
             if spec.E else None)
    valid = jax.ShapeDtypeStruct((spec.B, spec.D), jnp.bool_)

    def call(cand, flat, starts, lens, extra, valid):
        return ops.level_expand(
            cand, flat, starts, lens, extra, valid,
            dirs=dirs, count=spec.count, neg_from=spec.neg_from,
            window=spec.window, flat_padded=spec.padded,
            block_b=spec.block_b, block_d=spec.block_d,
            block_l=spec.block_l, interpret=True,
        )

    try:
        shape = jax.eval_shape(call, cand, flat, starts, lens, extra, valid)
    except Exception as e:          # noqa: BLE001 — any trace rejection
        out.append(_err(
            "kernel-abstract-eval", loc,
            f"abstract evaluation rejects the call: {type(e).__name__}: "
            f"{e}"))
        return out
    want = ((spec.B,), jnp.int32) if spec.count \
        else ((spec.B, spec.D), jnp.bool_)
    if (tuple(shape.shape), shape.dtype) != want:
        out.append(_err(
            "kernel-abstract-eval", loc,
            f"output {shape.shape}/{shape.dtype} drifted from the "
            f"contract {want[0]}/{np.dtype(want[1])}"))

    # jaxpr inspection: a pallas_call must be on the traced path and its
    # integer array operands must all be int32 (dtype drift to int64 —
    # e.g. under x64 — doubles DMA widths and breaks the SMEM prefetch).
    try:
        jaxpr = jax.make_jaxpr(call)(cand, flat, starts, lens, extra, valid)
    except Exception:               # eval_shape above already vetted it
        return out
    eqns = list(_iter_eqns(jaxpr.jaxpr))
    pallas = [e for e in eqns if e.primitive.name == "pallas_call"]
    if not pallas:
        out.append(Finding(
            WARNING, "kernel-abstract-eval", loc,
            "no pallas_call primitive in the traced program — the "
            "wrapper silently stopped dispatching the fused kernel"))
    for eqn in pallas:
        for v in eqn.invars:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and np.issubdtype(dt, np.integer) \
                    and dt != np.int32:
                out.append(_err(
                    "kernel-dtype-drift", loc,
                    f"pallas_call integer operand has dtype {dt}, "
                    f"contract is int32"))
    return out


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from _iter_eqns(sub)


def _sub_jaxprs(eqn):
    from jax._src import core as jcore

    for val in eqn.params.values():
        if isinstance(val, jcore.ClosedJaxpr):
            yield val.jaxpr
        elif isinstance(val, jcore.Jaxpr):
            yield val
        elif isinstance(val, (tuple, list)):
            for v in val:
                if isinstance(v, jcore.ClosedJaxpr):
                    yield v.jaxpr
                elif isinstance(v, jcore.Jaxpr):
                    yield v


def executor_specs(n: int, m: int, max_degree: int, cfg=None,
                   *, label: str = "graph") -> list[LevelExpandSpec]:
    """The call shapes `core.executor.expand_core`/`iep_card_fused`
    actually generate for a graph of this shape under `cfg` — one spec
    per degree bucket × (mask, count, IEP-signed) variant."""
    from ..core.executor import ExecutorConfig

    cfg = cfg or ExecutorConfig()
    W = max(int(max_degree), 1)
    flat_len = 2 * int(m)
    buckets = cfg.degree_buckets
    if buckets is not None:
        buckets = tuple((min(int(w), W), float(f)) for (w, f) in buckets)
        if buckets[-1][0] < W:
            buckets = buckets + ((W, buckets[-1][1]),)
    else:
        buckets = ((W, 1.0),)
    specs = []
    for bi, (width, frac) in enumerate(buckets):
        cap = max(int(cfg.capacity * frac), 8)
        base = dict(P=2, window=W, flat_len=flat_len, padded=True)
        specs.append(LevelExpandSpec(
            B=cap, D=width, E=2, count=False,
            label=f"{label}/bucket{bi}[w={width}]/mask", **base))
        specs.append(LevelExpandSpec(
            B=cap, D=width, E=1, count=True,
            label=f"{label}/bucket{bi}[w={width}]/count", **base))
        # IEP tail: prefix vertices ride along as negatively-weighted
        # candidate columns starting at `width`
        specs.append(LevelExpandSpec(
            B=cap, D=width + 4, E=0, count=True, neg_from=width,
            label=f"{label}/bucket{bi}[w={width}]/iep", **base))
    return specs


def check_graph_contract(graph_or_shape, cfg=None, *,
                         deep: bool = False) -> list[Finding]:
    """Prove the kernel contract for a graph shape + executor config.

    `graph_or_shape` is a `GraphCSR` or an (n, m, max_degree) triple —
    the latter lets CI reason about graphs too big to materialize.
    `deep=True` additionally traces every generated call site
    abstractly (eval_shape + jaxpr walk); the shape proofs alone are
    pure arithmetic.
    """
    if hasattr(graph_or_shape, "indptr"):
        n, m = graph_or_shape.n, graph_or_shape.m
        W = graph_or_shape.max_degree
        label = graph_or_shape.name or "graph"
    else:
        n, m, W = graph_or_shape
        label = f"shape(n={n},m={m},W={W})"
    out: list[Finding] = []
    from ..kernels.ops import flat_gather_pad

    if 2 * m + flat_gather_pad() > INT32_MAX:
        out.append(_err(
            "kernel-int32-offset", label,
            f"padded flat CSR length {2 * m + flat_gather_pad()} "
            f"overflows int32 indexing; the graph needs int64 offsets "
            f"the kernel does not implement"))
    if n > INT32_MAX:
        out.append(_err(
            "kernel-int32-offset", label,
            f"|V|={n} overflows int32 vertex ids"))
    for spec in executor_specs(n, m, W, cfg, label=label):
        out += check_spec(spec)
        if deep and not out:
            out += abstract_eval_spec(spec)
    return out
