"""Attribution tool for the roofline walk: which HLO ops carry the bytes
/ flops / collective traffic?  This is the dry-run "profiler" the §Perf
hypothesis loop reads (no real-TPU trace exists in this container).

    PYTHONPATH=src python -m repro.roofline.explain --arch granite-34b \
        --shape decode_32k --mesh single --top 15
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass

from .hlo_cost import _BODY_RE, _CALLS_RE, HloCost, type_bytes


@dataclass
class Contribution:
    bytes_: float = 0.0
    flops: float = 0.0
    count: int = 0


def attribute(hc: HloCost, comp_name: str | None = None, mult: float = 1.0,
              out: dict[str, Contribution] | None = None, label: str = ""):
    """Walk like hbm_bytes/flops but accumulate per-op-signature totals.

    Signature = opcode + result type (fusions get their kind attr), so
    repeated layers aggregate into one row."""
    out = out if out is not None else defaultdict(Contribution)
    comp_name = comp_name or hc.entry
    comp = hc.comps.get(comp_name)
    if comp is None:
        return out
    for op in comp.ops:
        if op.opcode == "while":
            b = _BODY_RE.search(op.attrs)
            if b:
                attribute(hc, b.group(1), mult * hc._trips(op), out, label)
            continue
        if op.opcode in hc.__class__.__dict__.get("_noop", ()) :
            continue
        from .hlo_cost import _DONE, _SKIP_BYTES_OPS

        if op.opcode in _SKIP_BYTES_OPS or op.opcode in _DONE:
            continue
        kind = op.opcode
        if op.opcode == "fusion":
            km = re.search(r"kind=(\w+)", op.attrs)
            kind = f"fusion[{km.group(1) if km else '?'}]"
        sig = f"{kind} -> {op.type_str[:64]}"
        c = out[sig]
        c.count += int(mult)
        c.bytes_ += mult * (hc._result_write_bytes(comp, op)
                            + hc._operand_read_bytes(comp, op))
        if op.opcode == "dot":
            c.flops += mult * hc._dot_flops(comp, op)
        elif op.opcode == "fusion":
            cc = _CALLS_RE.search(op.attrs)
            if cc:
                c.flops += mult * hc.flops(cc.group(1))
    return out


def explain(hlo_text: str, top: int = 20) -> str:
    hc = HloCost(hlo_text)
    contrib = attribute(hc)
    total_b = sum(c.bytes_ for c in contrib.values())
    total_f = sum(c.flops for c in contrib.values())
    lines = [f"total bytes={total_b:.3e}  total flops={total_f:.3e}",
             f"{'bytes':>12s} {'%':>6s} {'flops':>12s} {'n':>6s}  op"]
    for sig, c in sorted(contrib.items(), key=lambda kv: -kv[1].bytes_)[:top]:
        lines.append(
            f"{c.bytes_:12.3e} {100 * c.bytes_ / max(total_b, 1):6.2f} "
            f"{c.flops:12.3e} {c.count:6d}  {sig}"
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    # late import so XLA_FLAGS from dryrun applies first
    from ..launch import dryrun

    mesh_obj = None
    from ..launch.mesh import make_production_mesh

    mesh_obj = make_production_mesh(multi_pod=(args.mesh == "multi"))
    if args.arch == "graphpi":
        compiled, _ = dryrun.lower_graphpi(mesh_obj, args.mesh)
    else:
        compiled, _ = dryrun.lower_cell(args.arch, args.shape, mesh_obj,
                                        args.mesh)
    print(explain(compiled.as_text(), args.top))


if __name__ == "__main__":
    main()
