"""Trip-count-aware cost extraction from scheduled HLO text.

XLA's `compiled.cost_analysis()` counts every `while` body ONCE — a
scan-over-layers model therefore under-reports flops/bytes by ~n_layers.
This module parses the optimized HLO, builds the computation call graph,
and multiplies through `known_trip_count` backend configs:

    flops(comp)  = Σ dot-flops(op)            (2 · numel(result) · K)
                 + Σ fusion → flops(called)
                 + Σ while  → trips × flops(body)
    hbm(comp)    = Σ (result + operand bytes) at fusion/op granularity
                   (fusion internals excluded: only materialized
                   boundaries touch HBM)
    colls(comp)  = collective result bytes × ring-model factor, with the
                   same trip multipliers.

Elementwise flops are ignored (dot-dominated workloads; documented).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?(%?[\w.\-]+)\s*\((.*)\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\("
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}
_DONE = {"all-reduce-done", "all-gather-done", "collective-permute-done"}


def type_bytes(type_str: str) -> int:
    return sum(
        _nelem(dims) * _DTYPE_BYTES.get(dt, 4)
        for dt, dims in _SHAPE_RE.findall(type_str)
    )


def _nelem(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    args: list[str]
    attrs: str
    arg_text: str = ""        # raw text inside the call parens


@dataclass
class Computation:
    name: str
    types: dict[str, str] = field(default_factory=dict)   # %name -> type
    ops: list[Op] = field(default_factory=list)

    def param_names(self) -> list[str]:
        """Parameter op names ordered by their parameter(i) index."""
        ps = []
        for op in self.ops:
            if op.opcode == "parameter":
                try:
                    idx = int(op.arg_text.strip())
                except ValueError:
                    idx = len(ps)
                ps.append((idx, op.name))
        return [name for _, name in sorted(ps)]


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for line in text.splitlines():
        h = _HEADER_RE.match(line)
        if h and ("=" not in line.split("(")[0]):
            name = h.group(2)
            if not name.startswith("%"):
                name = "%" + name
            cur = Computation(name)
            comps[name] = cur
            if h.group(1):
                entry = name
            # parameters: "a.1: f32[2,3]{1,0}, b: (f32[], s32[2])"
            params = h.group(3)
            for pm in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^)]*\))|[^,()]+)",
                                  params):
                cur.types["%" + pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        rest = line[m.end():]
        arg_str = rest.split(")")[0]
        args = re.findall(r"%[\w.\-]+", arg_str)
        attrs = rest[len(arg_str):]
        op = Op(name, type_str, opcode, args, attrs, arg_text=arg_str)
        cur.types[name] = type_str
        cur.ops.append(op)
    return comps, entry


class HloCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._flops: dict[str, float] = {}
        self._bytes: dict[str, float] = {}
        self._colls: dict[str, dict[str, float]] = {}

    # ------------------------------------------------------------- helpers
    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out_elems = _nelem(_SHAPE_RE.search(op.type_str).group(2)) \
            if _SHAPE_RE.search(op.type_str) else 0
        lhs_type = comp.types.get(op.args[0], "") if op.args else ""
        lhs_dims = _shape_dims(lhs_type)
        m = _LHS_C_RE.search(op.attrs)
        k = 1
        if m and lhs_dims:
            for d in m.group(1).split(","):
                if d:
                    k *= lhs_dims[int(d)]
        return 2.0 * out_elems * k

    def _cc_flops(self, comp: Computation, op: Op) -> float:
        if "matmul" not in op.attrs and "dot" not in op.attrs:
            return 0.0
        out = _nelem(_SHAPE_RE.search(op.type_str).group(2)) \
            if _SHAPE_RE.search(op.type_str) else 0
        lhs = _shape_dims(comp.types.get(op.args[0], "")) if op.args else []
        k = lhs[-1] if lhs else 1
        return 2.0 * out * k

    def _trips(self, op: Op) -> float:
        m = _TRIP_RE.search(op.attrs)
        return float(m.group(1)) if m else 1.0

    # ----------------------------------------------------------- recursion
    def flops(self, comp_name: str | None = None) -> float:
        comp_name = comp_name or self.entry
        if comp_name in self._flops:
            return self._flops[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        self._flops[comp_name] = 0.0  # cycle guard
        total = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                total += self._dot_flops(comp, op)
            elif op.opcode == "convolution":
                out = _nelem(_SHAPE_RE.search(op.type_str).group(2)) \
                    if _SHAPE_RE.search(op.type_str) else 0
                rhs = _shape_dims(comp.types.get(op.args[1], "")) \
                    if len(op.args) > 1 else []
                import numpy as _np
                total += 2.0 * out * (float(_np.prod(rhs[1:])) if rhs else 1.0)
            elif op.opcode == "custom-call":
                total += self._cc_flops(comp, op)
            elif op.opcode == "fusion":
                c = _CALLS_RE.search(op.attrs)
                if c:
                    total += self.flops(c.group(1))
            elif op.opcode == "while":
                b = _BODY_RE.search(op.attrs)
                if b:
                    total += self._trips(op) * self.flops(b.group(1))
            elif op.opcode in ("call", "async-start"):
                c = _CALLS_RE.search(op.attrs) or _BODY_RE.search(op.attrs)
                if c:
                    total += self.flops(c.group(1))
            elif op.opcode == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}",
                                     op.attrs)
                if branches:
                    names = re.findall(r"%[\w.\-]+", branches.group(1))
                    total += max((self.flops(n) for n in names), default=0.0)
        self._flops[comp_name] = total
        return total

    # ------------------------------------------------------ sliced access
    _SLICED_READ = {"gather", "dynamic-slice"}

    def _operand_read_bytes(self, comp: Computation, op: Op) -> float:
        """Bytes READ for `op`'s operands, slice-aware.

        A fusion (or top-level op) whose operand is used ONLY as the table
        of gather/dynamic-slice ops does not stream the whole table from
        HBM — it reads ~the gathered window.  Same for the in-place buffer
        of dynamic-update-slice (XLA aliases it; only the updated window is
        written, nothing else is read).  Without this, an embedding-table
        gather (or a KV-cache update) is billed the full table every layer
        — 10-100× overcounts on decode graphs.
        """
        reads = 0.0
        called = None
        if op.opcode == "fusion":
            c = _CALLS_RE.search(op.attrs)
            called = self.comps.get(c.group(1)) if c else None
        for i, a in enumerate(op.args):
            full = type_bytes(comp.types.get(a, ""))
            if called is not None:
                pnames = called.param_names()
                if i < len(pnames):
                    pname = pnames[i]
                    uses = [u for u in called.ops if pname in u.args]
                    if uses and all(
                        u.opcode in self._SLICED_READ and u.args
                        and u.args[0] == pname
                        for u in uses
                    ):
                        reads += min(
                            sum(type_bytes(u.type_str) for u in uses), full
                        )
                        continue
                    if uses and all(
                        u.opcode == "dynamic-update-slice" and u.args
                        and u.args[0] == pname
                        for u in uses
                    ):
                        continue  # aliased in-place target: no read
            elif op.opcode in self._SLICED_READ and i == 0:
                reads += min(type_bytes(op.type_str), full)
                continue
            elif op.opcode == "dynamic-update-slice" and i == 0:
                continue
            reads += full
        return reads

    def _result_write_bytes(self, comp: Computation, op: Op) -> float:
        """Bytes WRITTEN for `op`'s result, DUS-aware: a (fusion ending in)
        dynamic-update-slice writes the update window, not the buffer."""
        if op.opcode == "dynamic-update-slice" and len(op.args) >= 2:
            return type_bytes(comp.types.get(op.args[1], op.type_str))
        if op.opcode == "fusion":
            c = _CALLS_RE.search(op.attrs)
            called = self.comps.get(c.group(1)) if c else None
            if called is not None and called.ops:
                root = called.ops[-1]
                if root.opcode == "dynamic-update-slice" and len(root.args) >= 2:
                    return type_bytes(called.types.get(root.args[1],
                                                       op.type_str))
        return type_bytes(op.type_str)

    def hbm_bytes(self, comp_name: str | None = None) -> float:
        comp_name = comp_name or self.entry
        if comp_name in self._bytes:
            return self._bytes[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        self._bytes[comp_name] = 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode == "while":
                b = _BODY_RE.search(op.attrs)
                if b:
                    total += self._trips(op) * self.hbm_bytes(b.group(1))
                continue
            if op.opcode == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}",
                                     op.attrs)
                if branches:
                    names = re.findall(r"%[\w.\-]+", branches.group(1))
                    total += max((self.hbm_bytes(n) for n in names),
                                 default=0.0)
                continue
            if op.opcode in _SKIP_BYTES_OPS or op.opcode in _DONE:
                continue
            # fusion boundary (or plain op): result + operands touch HBM
            total += self._result_write_bytes(comp, op)
            total += self._operand_read_bytes(comp, op)
        self._bytes[comp_name] = total
        return total

    def collective_bytes(self, comp_name: str | None = None) -> dict[str, float]:
        comp_name = comp_name or self.entry
        if comp_name in self._colls:
            return self._colls[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return {}
        self._colls[comp_name] = {}
        out: dict[str, float] = {}

        def add(kind, b, mult=1.0):
            out[kind] = out.get(kind, 0.0) + b * mult

        for op in comp.ops:
            if op.opcode in _COLLECTIVES:
                kind = op.opcode.replace("-start", "")
                b = type_bytes(op.type_str)
                gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs)
                g = int(gm.group(2)) if gm else 2
                factor = {"all-reduce": 2.0, "reduce-scatter": float(g)}.get(
                    kind, 1.0
                )
                add(kind, b * factor)
            elif op.opcode == "while":
                b = _BODY_RE.search(op.attrs)
                if b:
                    for k, v in self.collective_bytes(b.group(1)).items():
                        add(k, v, self._trips(op))
            elif op.opcode in ("fusion", "call"):
                c = _CALLS_RE.search(op.attrs)
                if c:
                    for k, v in self.collective_bytes(c.group(1)).items():
                        add(k, v)
        self._colls[comp_name] = out
        return out
