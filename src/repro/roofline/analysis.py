"""Roofline terms from a compiled dry-run artifact.

    compute    = HLO_FLOPs_global   / (chips × peak_FLOP/s)
    memory     = HLO_bytes_global   / (chips × HBM_bw)
    collective = collective_bytes_g / (chips × link_bw)

`compiled.cost_analysis()` reports the PER-DEVICE partitioned module, so
global = per-device × chips and the formulas above reduce to
per-device / per-chip-rate.  Collective bytes are not in cost_analysis —
we parse the optimized HLO and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(async *-start variants included, *-done skipped).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

from ..launch.mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# In scheduled (post-optimization) HLO the operand types are omitted, so we
# parse the RESULT type:  %name = f32[2,64]{1,0} all-reduce(%x), ...
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[0-9,]*\][^)\s]*(?:,\s*[a-z0-9]+\[[0-9,]*\][^)\s]*)*\)?)\s*"
    r"(all-reduce-start|all-gather-start|collective-permute-start|"
    r"all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind ICI bytes per device (ring model).

    Using result shapes (operands are untyped in scheduled HLO):
      all-reduce          ~ 2 × size      (reduce-scatter + all-gather ring)
      all-gather          ~ size          (bytes landed per device)
      reduce-scatter      ~ size × g      (input traverses the ring)
      all-to-all          ~ size          ((g-1)/g of the payload crosses)
      collective-permute  ~ size
    """
    out: dict[str, int] = {}
    for m in _OP_RE.finditer(hlo_text):
        result_types, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        b = sum(
            _shape_bytes(dt, dims)
            for dt, dims in _SHAPE_RE.findall(result_types)
        )
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start(): line_end if line_end > 0 else m.end()]
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else 2
        factor = {"all-reduce": 2, "reduce-scatter": g}.get(kind, 1)
        out[kind] = out.get(kind, 0) + b * factor
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict = field(default_factory=dict)
    model_flops: float = 0.0          # 6·N·D (train) / 2·N·D (serve), global
    peak_memory_bytes: float = 0.0    # from memory_analysis, per device
    raw_cost_flops: float = 0.0       # cost_analysis aggregate (body-once)
    raw_cost_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / HW["peak_flops_bf16"]

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HW["hbm_bw"]

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / HW["ici_link_bw"]

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the lower-bound step
        time, counting only MODEL (useful) flops: how close the cell is to
        'useful compute at peak'."""
        if self.step_time_s == 0:
            return 0.0
        useful_per_chip = self.model_flops / self.chips
        return (useful_per_chip / self.step_time_s) / HW["peak_flops_bf16"]

    def to_json(self) -> dict:
        d = asdict(self)
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
            step_time_s=self.step_time_s,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def analyze(arch, shape, mesh_name, chips, compiled, model_flops, *,
            extra_flops_per_device: float = 0.0) -> Roofline:
    """Terms from the trip-count-aware HLO parse (hlo_cost.py).

    The raw cost_analysis aggregates count while bodies once, so a
    scan-over-layers model under-reports by ~n_layers; we keep them in the
    artifact for reference but the roofline uses the corrected walk."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    if hlo:
        from .hlo_cost import HloCost

        hc = HloCost(hlo)
        flops = max(hc.flops(), raw_flops) + extra_flops_per_device
        # NOT max() with raw_bytes: cost_analysis bills gathered tables /
        # DUS buffers in full, which the slice-aware walk corrects.
        nbytes = hc.hbm_bytes()
        coll = hc.collective_bytes()
    else:  # pragma: no cover
        flops, nbytes, coll = raw_flops, raw_bytes, collective_bytes(hlo)
    mem = 0.0
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)
            )
    except Exception:
        pass
    r = Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=nbytes,
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops,
        peak_memory_bytes=mem,
        raw_cost_flops=raw_flops,
        raw_cost_bytes=raw_bytes,
    )
    return r
