"""Version-compatibility shims for JAX API drift.

The repo targets a JAX compatibility floor of 0.4.37 (the pinned
toolchain image) while using names that moved or were renamed in later
releases.  Everything version-sensitive funnels through here so call
sites stay clean:

  enable_x64()   jax.enable_x64 (new) / jax.experimental.enable_x64 (old)
  shard_map(...) jax.shard_map (new) / jax.experimental.shard_map (old),
                 translating the `check_vma=` kwarg to `check_rep=` on
                 old releases where the varying-manual-axes checker did
                 not exist yet
  make_mesh(...) drops the `axis_types=` kwarg (jax.sharding.AxisType)
                 on releases that predate explicit axis types
  jax_export     jax.export (>= 0.4.30) / jax.experimental.export (old);
                 None when neither exists — callers must degrade to
                 re-JIT instead of AOT executable persistence

Import-time cost is one getattr per name; no jax device state is
touched (mesh construction stays lazy, see launch/mesh.py).
"""
from __future__ import annotations

import jax

# --------------------------------------------------------------- x64 ----
if hasattr(jax, "enable_x64"):                       # jax >= 0.5
    enable_x64 = jax.enable_x64
else:                                                # jax 0.4.x
    from jax.experimental import enable_x64 as _enable_x64_ctx

    def enable_x64(new_val: bool = True):
        """Context manager enabling 64-bit jnp types locally."""
        return _enable_x64_ctx(new_val)


# --------------------------------------------------------- shard_map ----
if hasattr(jax, "shard_map"):                        # jax >= 0.6
    shard_map = jax.shard_map
else:                                                # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        """Old-API shard_map: `check_vma` was called `check_rep`."""
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map_old(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )


# ------------------------------------------------------ ambient mesh ----
if hasattr(jax.sharding, "set_mesh"):                # jax >= 0.6
    set_mesh = jax.sharding.set_mesh
    get_abstract_mesh = jax.sharding.get_abstract_mesh
else:                                                # jax 0.4.x
    def set_mesh(mesh):
        """Old JAX: Mesh is itself the ambient-mesh context manager."""
        return mesh

    def get_abstract_mesh():
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        return None if m.empty else m


# -------------------------------------------------------- jax.export ----
try:                                                 # jax >= 0.4.30
    from jax import export as jax_export
except ImportError:                                  # pragma: no cover
    try:
        from jax.experimental import export as jax_export
    except ImportError:
        jax_export = None


# ---------------------------------------------------------- AxisType ----
AxisType = getattr(jax.sharding, "AxisType", None)


def make_mesh(shape, axis_names, *, axis_types=None):
    """jax.make_mesh that tolerates missing jax.sharding.AxisType.

    `axis_types` entries may be given as the strings "auto" / "explicit"
    so callers never import AxisType directly; on releases without
    explicit axis types the kwarg is silently dropped (every axis is
    implicitly auto there, which is the semantics all our meshes want).
    """
    if AxisType is None or axis_types is None:
        return jax.make_mesh(shape, axis_names)
    resolved = tuple(
        getattr(AxisType, t.capitalize()) if isinstance(t, str) else t
        for t in axis_types
    )
    return jax.make_mesh(shape, axis_names, axis_types=resolved)
