"""PlanStore — the persistent half of the plan cache (DESIGN.md §5).

The in-memory `PlanCache` pays the GraphPi configuration search and the
executor JIT once per process and loses both on restart.  Cache keys are
already process-stable (canonical pattern sha256 + content fingerprints
— nothing keyed on `id()` or Python hashing), so persistence is purely
additive: this module maps each key to an on-disk record holding

  * the searched `Configuration` (core/config_search.py dict round-trip),
  * the compiled `MatchingPlan` (core/plan.py dict round-trip), and
  * optionally the AOT-compiled executable — the `jax.export`
    serialization of the exact (capacity, chunk-width) trace the matcher
    warms up, so a replica restart skips Python re-tracing too.

Layout under the cache dir (one schema version = one directory, so a
format change never aliases old records):

    <root>/v2/<key-digest>.json      header + config + plan records
    <root>/v2/<key-digest>.exec      serialized AOT executable (optional)

Schema v2 (this version) carries vertex labels: the pattern record may
hold a "labels" list and the plan record a "vlabels" list (both omitted
for unlabeled patterns, whose encoding is byte-identical to v1).  The
v1 directory is still READ for unlabeled patterns — a v2 store opened
over a v1 tree warm-loads every compatible unlabeled record — but a v1
record claiming label fields is rejected (`v1-labeled`): v1 writers
could not have produced it, so it can only be tampering or corruption.
All writes target the v2 directory.

`<key-digest>` is sha256 over the canonical JSON of the full PlanCache
entry key — (canonical pattern key, graph fingerprint, executor
fingerprint string, mode, use_iep, layout fingerprint) — so anything
that would change the searched configuration or the compiled program
lands at a different path by construction.

Invalidation headers.  Every record carries (schema_version, jax,
jaxlib, repro_fingerprint, backend).  A version or code-fingerprint
mismatch REJECTS the whole record: plans built by different plan-time
code may be stale in ways no structural check catches.  A backend
mismatch (e.g. a store written on CPU, loaded on TPU) only drops the
executable — the config/plan records are device-independent, so the
loader falls back to re-JIT while still skipping the search.  All
rejections are counted, never raised: a corrupt or stale store must
degrade to cold-start, not take down serving.

Writes are atomic (tmp file + `os.replace`) so a crashed writer or two
racing replicas warming the same dir never leave torn records.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
import tempfile
import time
from dataclasses import dataclass, field
from typing import Iterator

import jax
import jaxlib

from ..core.config_search import (
    Configuration, config_from_dict, config_to_dict,
)
from ..core.pattern import Pattern
from ..core.plan import MatchingPlan, plan_from_dict, plan_to_dict
from ..obs import get_tracer

SCHEMA_VERSION = 2
# Older schema directories the loader still reads (unlabeled records
# only); writes always target the current version.
LEGACY_SCHEMA_VERSIONS = (1,)

# Modules whose source shapes plan records or compiled programs — the
# full plan-time pipeline (pattern/labels, schedule/restriction
# generation, perf-model ranking, configuration search) plus the
# executor/kernel code the AOT trace bakes in: a drift in any of them
# invalidates every persisted entry (cheap and sound — false
# invalidation just costs one cold start per entry).
_FINGERPRINTED_MODULES = (
    "repro.core.config_search",
    "repro.core.executor",
    "repro.core.iep",
    "repro.core.pattern",
    "repro.core.perf_model",
    "repro.core.plan",
    "repro.core.restrictions",
    "repro.core.schedule",
    "repro.kernels.ops",
    "repro.kernels.intersect",
    "repro.query.canon",
)


@functools.lru_cache(maxsize=1)
def repro_fingerprint() -> str:
    """sha256 over the source bytes of the plan/executor-shaping modules."""
    import importlib

    h = hashlib.sha256()
    for name in _FINGERPRINTED_MODULES:
        mod = importlib.import_module(name)
        with open(mod.__file__, "rb") as f:
            h.update(name.encode())
            h.update(f.read())
    return h.hexdigest()


def _jsonify(obj):
    """Canonical JSON-compatible form of a (nested-tuple) cache key."""
    if isinstance(obj, (tuple, list)):
        return [_jsonify(x) for x in obj]
    return obj


def key_digest(key: tuple) -> str:
    """Stable digest of a PlanCache entry key (any nesting of primitives)."""
    payload = json.dumps(_jsonify(key), separators=(",", ":"),
                         sort_keys=False)
    return hashlib.sha256(payload.encode()).hexdigest()


@dataclass
class StoreStats:
    loads: int = 0               # records successfully loaded
    misses: int = 0              # key not present
    saves: int = 0
    exec_drops: int = 0          # executable rejected, plans kept
    save_fails: int = 0
    verify_fails: int = 0        # records rejected by the soundness pass
    rejects: dict = field(default_factory=dict)   # reason -> count

    def reject(self, reason: str) -> None:
        self.rejects[reason] = self.rejects.get(reason, 0) + 1

    def as_dict(self) -> dict:
        return dict(self.__dict__, rejects=dict(self.rejects))


@dataclass
class StoreRecord:
    """One rehydrated entry: everything the cache needs except a matcher."""

    digest: str
    pattern: Pattern             # canonical labeling (as searched)
    config: Configuration
    plan: MatchingPlan
    mode: str
    use_iep: bool
    sharded: bool
    exec_bytes: bytes | None     # None = re-JIT fallback
    header: dict                 # raw record header (reporting/debugging)

    @property
    def search_seconds(self) -> float:
        return float(self.header.get("search_seconds", 0.0))


class PlanStore:
    """Versioned on-disk index of searched plans + AOT executables."""

    def __init__(self, root: str):
        self.root = root
        self.vdir = os.path.join(root, f"v{SCHEMA_VERSION}")
        os.makedirs(self.vdir, exist_ok=True)
        self.stats = StoreStats()

    def _version_dirs(self) -> list[tuple[int, str]]:
        """(schema_version, dir) pairs the loader consults, current first.
        Legacy dirs are only listed when they exist on disk."""
        out = [(SCHEMA_VERSION, self.vdir)]
        for v in LEGACY_SCHEMA_VERSIONS:
            d = os.path.join(self.root, f"v{v}")
            if os.path.isdir(d):
                out.append((v, d))
        return out

    # Non-plan record filename prefixes sharing the version dirs:
    # graph-stats records and live-overlay records (both keyed on graph
    # content, not plan keys).
    _AUX_PREFIXES = ("stats-", "live-")

    def __len__(self) -> int:
        return sum(
            1
            for _, d in self._version_dirs()
            for f in os.listdir(d)
            if f.endswith(".json")
            and not f.startswith(self._AUX_PREFIXES)
        )

    # ------------------------------------------------------------ paths
    def _paths(self, digest: str, vdir: str | None = None
               ) -> tuple[str, str]:
        base = os.path.join(vdir or self.vdir, digest)
        return base + ".json", base + ".exec"

    def header(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "jax": jax.__version__,
            "jaxlib": jaxlib.__version__,
            "repro_fingerprint": repro_fingerprint(),
            "backend": jax.default_backend(),
        }

    def _check_header(self, rec: dict,
                      expect_version: int = SCHEMA_VERSION) -> str | None:
        """None when the record is usable, else the rejection reason."""
        if rec.get("schema_version") != expect_version:
            return "schema_version"
        if rec.get("jax") != jax.__version__ or \
                rec.get("jaxlib") != jaxlib.__version__:
            return "jax_version"
        if rec.get("repro_fingerprint") != repro_fingerprint():
            return "repro_fingerprint"
        return None

    @staticmethod
    def _record_labeled(rec: dict) -> bool:
        """Does the raw record claim any v2 label field?"""
        return (
            rec.get("pattern", {}).get("labels") is not None
            or rec.get("plan", {}).get("vlabels") is not None
            or rec.get("plan", {}).get("pattern", {}).get("labels")
            is not None
        )

    @staticmethod
    def _key_mismatch(rec: dict, *patterns: Pattern) -> bool:
        """True when any given pattern's canonical key disagrees with the
        record's own stored key — i.e. the record sits in a slot that a
        different (label-)isomorphism class owns.  `canonical_key` folds
        labels into the digest, so swapping two labels in a persisted
        pattern/plan moves its key even when the automorphism structure
        and every internal invariant are untouched."""
        from .canon import canonical_key

        key = rec.get("key")
        if not isinstance(key, list) or not key or \
                not isinstance(key[0], str):
            return True
        try:
            return any(canonical_key(p) != key[0] for p in patterns)
        except ValueError:          # uncanonicalizable pattern
            return True

    # ------------------------------------------------------------- save
    def save(self, key: tuple, *, pattern: Pattern, config: Configuration,
             plan: MatchingPlan, exec_bytes: bytes | None = None,
             search_seconds: float = 0.0,
             compile_seconds: float = 0.0,
             schema_version: int = SCHEMA_VERSION) -> str | None:
        """Write-behind one entry; returns the digest, or None when the
        write failed (serving never crashes on a read-only/full disk).

        `schema_version` is a migration/test seam: passing a legacy
        version writes the record into that version's directory with the
        matching header.  Labeled patterns refuse to downgrade — v1 has
        no label fields, so a "v1 labeled record" would be exactly the
        corruption the loader's `v1-labeled` check exists to catch."""
        if schema_version != SCHEMA_VERSION:
            if schema_version not in LEGACY_SCHEMA_VERSIONS:
                raise ValueError(f"unknown schema version {schema_version}")
            if pattern.labels is not None or plan.vlabels is not None:
                raise ValueError(
                    "labeled patterns cannot be written as schema "
                    f"v{schema_version} (labels are a v2 field)")
        vdir = os.path.join(self.root, f"v{schema_version}")
        os.makedirs(vdir, exist_ok=True)
        digest = key_digest(key)
        json_path, exec_path = self._paths(digest, vdir)
        record = {
            **self.header(),
            "schema_version": schema_version,
            "key": _jsonify(key),
            "mode": key[3],
            "use_iep": bool(key[4]),
            "sharded": bool(key[5] and key[5][0] == "sharded"),
            "created_at": time.time(),
            "search_seconds": float(search_seconds),
            "compile_seconds": float(compile_seconds),
            "pattern": pattern.to_dict(),
            "config": config_to_dict(config),
            "plan": plan_to_dict(plan),
            "has_executable": exec_bytes is not None,
        }
        with get_tracer().span("store.save", digest=digest[:12],
                               aot=exec_bytes is not None):
            try:
                if exec_bytes is not None:
                    self._atomic_write(exec_path, exec_bytes)
                self._atomic_write(
                    json_path,
                    json.dumps(record, separators=(",", ":")).encode())
            except OSError:
                self.stats.save_fails += 1
                return None
        self.stats.saves += 1
        return digest

    def _atomic_write(self, path: str, data: bytes) -> None:
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # ------------------------------------------------------------- load
    def load(self, key: tuple) -> StoreRecord | None:
        """Load-through for one key; None = absent or rejected (counted).

        Consults the current schema directory first, then any legacy
        directories (unlabeled records only — cache keys are stable
        across the v1→v2 bump for unlabeled patterns, so a v2 store
        opened over a v1 tree warm-loads the old records in place)."""
        return self._load_digest(key_digest(key))

    def _load_digest(self, digest: str) -> StoreRecord | None:
        with get_tracer().span("store.load", digest=digest[:12]) as sp:
            dirs = self._version_dirs()
            for version, vdir in dirs:
                json_path, _ = self._paths(digest, vdir)
                if os.path.exists(json_path):
                    return self._load_checked(digest, sp, version=version,
                                              vdir=vdir)
            self.stats.misses += 1
            sp.set(outcome="miss")
            return None

    def _load_checked(self, digest: str, sp, *,
                      version: int = SCHEMA_VERSION,
                      vdir: str | None = None) -> StoreRecord | None:
        json_path, exec_path = self._paths(digest, vdir)
        if not os.path.exists(json_path):
            self.stats.misses += 1
            sp.set(outcome="miss")
            return None
        try:
            with open(json_path) as f:
                rec = json.load(f)
        except (OSError, json.JSONDecodeError):
            self.stats.reject("corrupt")
            sp.set(outcome="corrupt")
            return None
        reason = self._check_header(rec, expect_version=version)
        if reason is not None:
            self.stats.reject(reason)
            sp.set(outcome=f"stale:{reason}")
            return None
        if version != SCHEMA_VERSION and self._record_labeled(rec):
            # labels are a v2 field; a v1 record claiming them was not
            # written by any v1 writer — tampering or corruption
            self.stats.reject("v1-labeled")
            sp.set(outcome="v1-labeled")
            return None
        try:
            pattern = Pattern.from_dict(rec["pattern"])
            config = config_from_dict(rec["config"])
            plan = plan_from_dict(rec["plan"])
        except (KeyError, TypeError, ValueError):
            self.stats.reject("corrupt")
            sp.set(outcome="corrupt")
            return None
        # The digest is derived from the CANONICAL pattern key, and the
        # record stores the canonically-relabeled pattern — so a record
        # whose pattern (edges OR labels) disagrees with its own key
        # serves some other query's slot.  Both the top-level pattern and
        # the plan's embedded copy are checked: flipped-label tampering
        # always lands here even when the flipped plan is internally
        # sound (verify_plan only proves internal consistency).
        if self._key_mismatch(rec, pattern, plan.pattern):
            self.stats.reject("key-pattern-mismatch")
            sp.set(outcome="key-pattern-mismatch")
            return None
        # plan_from_dict round-trips blindly by design (O(read) loads);
        # re-prove soundness here so a drifted/tampered record degrades
        # to a miss instead of serving a wrong count.
        mode = str(rec.get("mode", "graphpi"))
        from ..analysis.findings import has_errors
        from ..analysis.soundness import verify_plan

        with get_tracer().span("store.verify", digest=digest[:12]):
            bad = has_errors(verify_plan(plan, mode=mode, location=digest))
        if bad:
            self.stats.verify_fails += 1
            self.stats.reject("verify")
            sp.set(outcome="verify_fail")
            return None
        exec_bytes = None
        if rec.get("has_executable"):
            if rec.get("backend") != jax.default_backend():
                self.stats.exec_drops += 1      # plans survive, exe doesn't
            else:
                try:
                    with open(exec_path, "rb") as f:
                        exec_bytes = f.read()
                except OSError:
                    self.stats.exec_drops += 1
        self.stats.loads += 1
        sp.set(outcome="load", aot=exec_bytes is not None)
        return StoreRecord(
            digest=digest,
            pattern=pattern,
            config=config,
            plan=plan,
            mode=mode,
            use_iep=bool(rec.get("use_iep", False)),
            sharded=bool(rec.get("sharded", False)),
            exec_bytes=exec_bytes,
            header={k: rec[k] for k in rec
                    if k not in ("pattern", "config", "plan")},
        )

    def records(self) -> Iterator[StoreRecord]:
        """Every loadable record (rejections counted, not raised) — the
        warm-from-disk path iterates these and keeps the compatible ones.
        Spans all version directories; when the same digest exists in
        several, the newest schema's copy shadows the legacy one (exactly
        what `load` would serve)."""
        seen: set[str] = set()
        for _, vdir in self._version_dirs():
            for fname in sorted(os.listdir(vdir)):
                if not fname.endswith(".json") or \
                        fname.startswith(self._AUX_PREFIXES):
                    continue
                digest = fname[: -len(".json")]
                if digest in seen:
                    continue
                seen.add(digest)
                rec = self._load_digest(digest)
                if rec is not None:
                    yield rec

    # ------------------------------------------------------- graph stats
    # GraphStats (|V|, |E|, exact triangle count) is a property of the
    # DATA GRAPH, not of plan-time code, so its record is keyed purely by
    # the graph's content fingerprint and survives code/jax upgrades that
    # invalidate plan records; only a schema change rejects it.
    def _stats_path(self, graph_fingerprint: str) -> str:
        return os.path.join(self.vdir, f"stats-{graph_fingerprint}.json")

    def save_graph_stats(self, graph_fingerprint: str, stats) -> bool:
        """Persist |V|/|E|/tri_cnt for one graph; False on write failure
        (same degradation policy as plan saves)."""
        record = {
            "schema_version": SCHEMA_VERSION,
            "created_at": time.time(),
            "graph_fingerprint": graph_fingerprint,
            "n_vertices": int(stats.n_vertices),
            "n_edges": int(stats.n_edges),
            "tri_cnt": int(stats.tri_cnt),
        }
        try:
            self._atomic_write(
                self._stats_path(graph_fingerprint),
                json.dumps(record, separators=(",", ":")).encode())
        except OSError:
            self.stats.save_fails += 1
            return False
        self.stats.saves += 1
        return True

    def load_graph_stats(self, graph_fingerprint: str):
        """Rehydrated `GraphStats` for this graph, or None (counted)."""
        from ..core.perf_model import GraphStats

        path = self._stats_path(graph_fingerprint)
        if not os.path.exists(path):
            self.stats.misses += 1
            return None
        try:
            with open(path) as f:
                rec = json.load(f)
        except (OSError, json.JSONDecodeError):
            self.stats.reject("stats_corrupt")
            return None
        if rec.get("schema_version") != SCHEMA_VERSION or \
                rec.get("graph_fingerprint") != graph_fingerprint:
            self.stats.reject("stats_mismatch")
            return None
        try:
            stats = GraphStats(n_vertices=int(rec["n_vertices"]),
                               n_edges=int(rec["n_edges"]),
                               tri_cnt=int(rec["tri_cnt"]))
        except (KeyError, TypeError, ValueError):
            self.stats.reject("stats_corrupt")
            return None
        if stats.n_vertices < 0 or stats.n_edges < 0 or stats.tri_cnt < 0:
            self.stats.reject("stats_corrupt")
            return None
        self.stats.loads += 1
        return stats

    # ---------------------------------------------------- overlay records
    # A live engine's delta overlay (live/overlay.py) is graph state, not
    # plan state: the record is keyed by the ORIGINAL base graph's content
    # fingerprint and holds the cumulative insert/delete sets vs that
    # base, so a restarted replica can replay the mutations and resume at
    # the same edge epoch.  Like stats records it survives code upgrades;
    # only a schema change or structural damage rejects it.
    def _overlay_path(self, base_fingerprint: str) -> str:
        return os.path.join(self.vdir, f"live-{base_fingerprint}.json")

    @staticmethod
    def _check_overlay(rec: dict, base_fingerprint: str | None = None
                       ) -> str | None:
        """None when structurally valid, else the rejection reason.
        Validates exactly what `DeltaOverlay.from_record` will trust:
        normalized (u < v, non-negative int) edge pairs, disjoint
        insert/delete sets, non-negative epoch counters."""
        if rec.get("schema_version") != SCHEMA_VERSION:
            return "overlay_schema"
        fp = rec.get("base_fingerprint")
        if not isinstance(fp, str) or not fp:
            return "overlay_fingerprint"
        if base_fingerprint is not None and fp != base_fingerprint:
            return "overlay_fingerprint"
        for key in ("edge_epoch", "stats_epoch", "compactions"):
            v = rec.get(key)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                return "overlay_epoch"
        sets = {}
        for key in ("inserts", "deletes"):
            edges = rec.get(key)
            if not isinstance(edges, list):
                return "overlay_edges"
            seen = set()
            for e in edges:
                if (not isinstance(e, list) or len(e) != 2
                        or not all(isinstance(x, int)
                                   and not isinstance(x, bool)
                                   for x in e)
                        or not 0 <= e[0] < e[1]):
                    return "overlay_edges"
                seen.add((e[0], e[1]))
            sets[key] = seen
        if sets["inserts"] & sets["deletes"]:
            return "overlay_edges"
        return None

    def save_overlay(self, record: dict) -> bool:
        """Write-behind one live-overlay record (the engine calls this at
        every mutation round boundary); False on a structurally invalid
        record or write failure — live serving never crashes on a bad
        disk, it just loses restart-resume."""
        rec = {"schema_version": SCHEMA_VERSION,
               "created_at": time.time(), **record}
        if self._check_overlay(rec) is not None:
            self.stats.save_fails += 1
            return False
        try:
            self._atomic_write(
                self._overlay_path(rec["base_fingerprint"]),
                json.dumps(rec, separators=(",", ":")).encode())
        except OSError:
            self.stats.save_fails += 1
            return False
        self.stats.saves += 1
        return True

    def load_overlay(self, base_fingerprint: str) -> dict | None:
        """The persisted overlay record for this base graph, or None
        (counted) — feed it to `DeltaOverlay.from_record` to resume."""
        path = self._overlay_path(base_fingerprint)
        if not os.path.exists(path):
            self.stats.misses += 1
            return None
        try:
            with open(path) as f:
                rec = json.load(f)
        except (OSError, json.JSONDecodeError):
            self.stats.reject("overlay_corrupt")
            return None
        reason = self._check_overlay(rec, base_fingerprint)
        if reason is not None:
            self.stats.reject(reason)
            return None
        self.stats.loads += 1
        return rec

    # -------------------------------------------------------------- fsck
    def fsck(self) -> dict:
        """Re-prove every on-disk record sound; quarantine what fails.

        Runs the analysis soundness pass (`verify_plan`) over each plan
        record and structural validation over each stats and live-overlay
        record, MOVING failures into `<vdir>/quarantine/` so they stop
        being served but stay inspectable.  Counted, never raised — fsck
        on a damaged store must report, not crash (same policy as load).
        Returns {"checked", "quarantined", "stats_checked",
        "overlays_checked", "findings"} with `findings` keyed by digest.
        """
        from ..analysis.findings import ERROR, Finding, has_errors
        from ..analysis.soundness import verify_plan

        report = {"checked": 0, "quarantined": 0, "stats_checked": 0,
                  "overlays_checked": 0, "findings": {}}
        with get_tracer().span("store.fsck", root=self.root) as fsp:
            for version, vdir in self._version_dirs():
                for fname in sorted(os.listdir(vdir)):
                    if not fname.endswith(".json"):
                        continue
                    digest = fname[: -len(".json")]
                    findings: list[Finding] = []
                    if fname.startswith("stats-"):
                        if version != SCHEMA_VERSION:
                            continue    # legacy stats: stale, not unsound
                        report["stats_checked"] += 1
                        fp = fname[len("stats-"): -len(".json")]
                        if self.load_graph_stats(fp) is None:
                            findings.append(Finding(
                                ERROR, "stats-record", digest,
                                "stats record is corrupt or its fingerprint "
                                "does not match its filename"))
                    elif fname.startswith("live-"):
                        if version != SCHEMA_VERSION:
                            continue  # legacy overlay: stale, not unsound
                        report["overlays_checked"] += 1
                        fp = fname[len("live-"): -len(".json")]
                        if self.load_overlay(fp) is None:
                            findings.append(Finding(
                                ERROR, "overlay-record", digest,
                                "live-overlay record is corrupt, claims "
                                "unnormalized/overlapping edge sets, or "
                                "its base fingerprint does not match its "
                                "filename"))
                    else:
                        report["checked"] += 1
                        findings = self._fsck_record(
                            digest, verify_plan, version=version, vdir=vdir)
                    if has_errors(findings):
                        report["findings"][digest] = findings
                        if self._quarantine(digest, vdir):
                            report["quarantined"] += 1
            fsp.set(checked=report["checked"],
                    quarantined=report["quarantined"])
        return report

    def _fsck_record(self, digest: str, verify_plan, *,
                     version: int = SCHEMA_VERSION,
                     vdir: str | None = None) -> list:
        from ..analysis.findings import ERROR, WARNING, Finding

        json_path, _ = self._paths(digest, vdir)
        try:
            with open(json_path) as f:
                rec = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            return [Finding(ERROR, "record-corrupt", digest,
                            f"unreadable record: {e}")]
        if version != SCHEMA_VERSION and self._record_labeled(rec):
            return [Finding(
                ERROR, "record-version-labeled", digest,
                f"schema v{version} record claims v2 label fields; no "
                f"v{version} writer could have produced it")]
        try:
            pattern = Pattern.from_dict(rec["pattern"])
            plan = plan_from_dict(rec["plan"])
        except (KeyError, TypeError, ValueError) as e:
            return [Finding(ERROR, "record-corrupt", digest,
                            f"pattern/plan does not round-trip: {e}")]
        out = verify_plan(plan, mode=str(rec.get("mode", "graphpi")),
                          location=digest)
        # the key↔pattern check is what pins labels to the slot: a
        # label flip can leave the plan internally sound (verify_plan
        # green) while the record now answers a DIFFERENT typed query
        # than the digest it is filed under
        if self._key_mismatch(rec, pattern, plan.pattern):
            out.append(Finding(
                ERROR, "key-pattern-mismatch", digest,
                "stored pattern/plan does not canonicalize to the "
                "record's own key: the record would serve another "
                "isomorphism class's (or label assignment's) slot"))
        reason = self._check_header(rec, expect_version=version)
        if reason is not None:
            # stale ≠ unsound: the loader already rejects these, so fsck
            # only reports them (re-warming overwrites in place)
            out.append(Finding(
                WARNING, "record-stale", digest,
                f"header mismatch ({reason}); record is skipped by the "
                f"loader until re-warmed"))
        return out

    def _quarantine(self, digest: str, vdir: str | None = None) -> bool:
        vdir = vdir or self.vdir
        qdir = os.path.join(vdir, "quarantine")
        json_path, exec_path = self._paths(digest, vdir)
        try:
            os.makedirs(qdir, exist_ok=True)
            os.replace(json_path,
                       os.path.join(qdir, os.path.basename(json_path)))
            if os.path.exists(exec_path):
                os.replace(exec_path,
                           os.path.join(qdir, os.path.basename(exec_path)))
        except OSError:
            self.stats.reject("quarantine_fail")
            return False
        return True
