"""QueryEngine — the batched pattern-count request path.

Loads a dataset ONCE: the CSR is uploaded to device memory a single
time (shared by every cached matcher via ``arrays=``), graph statistics
are computed once at startup, and when the process has multiple JAX
devices the graph stays resident on the mesh with the executor's
fine-grained outer-loop striping (`ShardedMatcher`).  Requests then
stream through the `PlanCache`: the first query of an isomorphism
class pays configuration search + JIT, repeats replay the warmed
program.

Request surface (DESIGN.md §5).  The engine is asynchronous-by-default
so the serving Gateway can schedule it against other mesh tenants:

  * ``plan(request)``    — cache/plan resolution only (search + JIT on
                           a miss); never executes a count.
  * ``enqueue(request)`` — admit a request, returning a :class:`Ticket`
                           that resolves later (raises
                           :class:`AdmissionRejected` past the
                           per-tenant depth bound; ``try_enqueue``
                           returns the :class:`Rejection` instead).
  * ``run_pending(limit)`` — execute up to ``limit`` queued tickets as
                           one round, COALESCING tickets of the same
                           isomorphism class (× mode × use_iep) into a
                           single plan execution: N bursty duplicates
                           cost one kernel dispatch, and the N−1
                           riders are accounted as cache hits.

MULTI-TENANCY.  Every request carries a ``tenant`` id; queued tickets
live in per-tenant FIFO queues drained by deterministic weighted
round-robin (``tenant_shares``), each tenant's depth bounded by
``tenant_depth`` (admission control: reject-with-reason, counted).
PREEMPTION.  With ``preempt_dispatches=k`` a round issues at most `k`
kernel dispatches: a class whose chunked outer loop is mid-flight
checkpoints its span stack (`CountState`) and resumes NEXT round —
rotated behind any other waiting class, so one huge query cannot
monopolize the device.  A preempted-and-resumed count is bit-identical
to an uninterrupted one (the state is the exact work stack + raw
totals).

``submit()``/``serve()`` remain as deprecated synchronous shims (one
request per round — the exact pre-Gateway behaviour).  Per-query wall
latency is recorded; `summary()` reports p50/p99 plus the cache
counters that prove hits never re-search or re-compile;
``tenant_report()`` adds per-tenant p50/p99 and admission counters.
"""
from __future__ import annotations

import warnings
from collections import deque
from dataclasses import dataclass

import numpy as np

from ..core.executor import (ExecutorConfig, Matcher, ShardedMatcher,
                             compute_stats, device_graph)
from ..core.pattern import Pattern
from ..core.perf_model import GraphStats
from ..graph.csr import GraphCSR
from ..live import (CompactionPolicy, CountMaintainer, DeltaOverlay,
                    EpochStamp, maybe_compact, stats_drifted)
from ..obs import MetricsRegistry, get_tracer, latency_summary, timer
from .cache import DEFAULT_MAX_ENTRIES, CacheEntry, PlanCache
from .canon import canonical_key


DEFAULT_TENANT = "default"


@dataclass(frozen=True)
class QueryRequest:
    """One pattern-count request (per-request options ride along)."""

    pattern: Pattern
    use_iep: bool = False
    verify: bool = False          # check against the pure-python oracle
    mode: str = "graphpi"
    tenant: str = DEFAULT_TENANT  # multi-tenant queue / fairness id


@dataclass
class QueryResult:
    pattern_name: str
    canon_key: str
    count: int
    latency_s: float              # wall time incl. cache miss costs
    cache_hit: bool
    mode: str
    use_iep: bool
    order: tuple
    res_set: tuple
    iep_k: int
    search_seconds: float         # 0.0 on a hit
    compile_seconds: float        # 0.0 on a hit
    overflowed: bool
    max_needed: int
    expected: int | None = None   # oracle count when verified
    verified: bool | None = None  # None = not requested
    coalesced: bool = False       # resolved by another ticket's execution

    def line(self) -> str:
        """One human-readable serving-log line."""
        v = ("" if self.verified is None
             else ("  verify=OK" if self.verified else "  verify=MISMATCH"))
        o = "  OVERFLOWED" if self.overflowed else ""
        how = "HIT " if self.cache_hit else "MISS"
        if self.coalesced:
            how = "COAL"
        return (f"{self.pattern_name:<16} count={self.count:<12} "
                f"{how} "
                f"lat={self.latency_s * 1e3:8.1f}ms "
                f"(search={self.search_seconds:.3f}s "
                f"compile={self.compile_seconds:.3f}s){v}{o}")


@dataclass(frozen=True)
class PlannedQuery:
    """What ``plan()`` resolves: the warmed cache entry plus whether the
    resolution was a cache hit (misses paid search/JIT just now)."""

    entry: CacheEntry
    cache_hit: bool


@dataclass(frozen=True)
class Rejection:
    """Why admission control refused a request (deterministic, counted)."""

    tenant: str
    reason: str
    depth: int                    # tenant's queue depth at rejection time
    limit: int                    # the configured bound it hit


class AdmissionRejected(RuntimeError):
    """Raised by :meth:`QueryEngine.enqueue` when a tenant's queue is at
    its depth bound; carries the structured :class:`Rejection`."""

    def __init__(self, rejection: Rejection):
        super().__init__(
            f"tenant {rejection.tenant!r} rejected: {rejection.reason} "
            f"(depth={rejection.depth}, limit={rejection.limit})")
        self.rejection = rejection


@dataclass
class Ticket:
    """Handle for an enqueued request; resolves when a round executes it
    (``QueryEngine.run_pending`` or the Gateway's graph workload)."""

    request: QueryRequest
    seq: int
    _result: QueryResult | None = None
    cancelled: bool = False

    @property
    def done(self) -> bool:
        return self._result is not None

    @property
    def result(self) -> QueryResult:
        if self._result is None:
            raise RuntimeError(
                f"ticket #{self.seq} not resolved yet — run the engine's "
                f"pending queue (run_pending) or schedule it via the Gateway")
        return self._result


@dataclass
class _InFlight:
    """One isomorphism-class group mid-round: its tickets, the resolved
    plan (lazy), and the resumable count checkpoint (`CountState`) when a
    preemption budget suspended it between kernel dispatches."""

    key: tuple
    tickets: list
    planned: PlannedQuery | None = None
    state: object | None = None   # core.executor.CountState when started
    seconds: float = 0.0          # accumulated plan + execute wall time


class QueryEngine:
    """Serve pattern-count queries over one resident graph.

    Parameters
    ----------
    graph:   the data graph, loaded once.
    cfg:     executor configuration shared by every cached matcher
             (part of the cache key).
    mesh:    optional JAX mesh; when given, counting runs sharded over
             `axis` with the CSR resident mesh-wide.
    chunk:   vertex-chunk striping of the outer loop — smaller chunks
             bound frontier memory and give the overflow bisection finer
             grain at the price of more kernel dispatches per query
             (latency/footprint trade-off, DESIGN.md §5).
    tenant_depth:  admission bound — max queued (unresolved, uncancelled)
             tickets per tenant; ``None`` (default) admits everything.
    tenant_shares: tickets drained per tenant per take-cycle of the
             weighted round-robin (missing tenants weigh 1).
    preempt_dispatches: default per-round kernel-dispatch budget; a class
             still mid-count when the budget runs out is checkpointed and
             rotated behind other waiting classes.  ``None`` = run every
             class in the round to completion (pre-preemption behaviour).
    live:    ``True`` (or a prebuilt `DeltaOverlay`) serves over a
             MUTABLE graph: `request_mutation` queues insert/delete/
             compact verbs that apply atomically at round boundaries
             (src/repro/live/) — plans/AOT survive mutations via the
             stats-epoch plan key, counts memoize/invalidate on the
             edge-epoch key, and a `CountMaintainer` refreshes only
             dirty root spans.
    compaction_policy: live-mode thresholds (`live.CompactionPolicy`).
    """

    def __init__(self, graph: GraphCSR, *, cfg: ExecutorConfig | None = None,
                 mesh=None, axis: str = "data", chunk: int | None = None,
                 cache: PlanCache | None = None,
                 store=None,
                 stats: GraphStats | None = None,
                 metrics: MetricsRegistry | None = None,
                 tenant_depth: int | None = None,
                 tenant_shares: dict[str, int] | None = None,
                 preempt_dispatches: int | None = None,
                 live=None,
                 compaction_policy: CompactionPolicy | None = None):
        if live is True:
            live = DeltaOverlay(graph)
        elif live is not None and not isinstance(live, DeltaOverlay):
            raise TypeError(
                f"live must be True or a DeltaOverlay, got {type(live)!r}")
        self.live = live
        if live is not None:
            graph = live.view              # executor-facing adjacency
        self.graph = graph
        self.cfg = cfg or ExecutorConfig()
        self.mesh = mesh
        self.axis = axis
        self.chunk = chunk
        if cache is None:
            cache = PlanCache(max_entries=DEFAULT_MAX_ENTRIES, store=store)
        elif store is not None and cache.store is None:
            cache.store = store             # attach persistence to the
        self.cache = cache                  # caller-provided cache
        self._arrays = device_graph(graph)     # ONE resident CSR upload
        with timer() as t:
            if stats is None:
                # a restarted engine skips the startup triangle count when
                # the attached store has a stats record for this exact graph
                # (content fingerprint); compute-and-persist otherwise
                if self.cache.store is not None:
                    stats = self.cache.store.load_graph_stats(
                        graph.fingerprint)
                if stats is None:
                    stats = compute_stats(graph, self.cfg)
                    if self.cache.store is not None:
                        self.cache.store.save_graph_stats(
                            graph.fingerprint, stats)
        self.stats = stats
        self.stats_seconds = t.seconds
        # round-boundary epoch identity: serving code carries THIS
        # stamp, never raw fingerprints (`no-stale-fingerprint`)
        self._epoch = (EpochStamp.for_live(live, stats) if live is not None
                       else EpochStamp.legacy(graph, stats))
        self._maintainer = (CountMaintainer(live) if live is not None
                            else None)
        self.compaction_policy = compaction_policy or CompactionPolicy()
        self._mutations: deque = deque()       # queued (verb, edges) batches
        self.mutations_applied = 0             # effective edge changes
        self.last_round_mutations = 0          # batches applied last round
        self.matcher_rebinds = 0               # zero-recompile epoch swaps
        self.matcher_rebuilds = 0              # shape-growth rebuilds
        # registries are per-engine (benchmarks/run.py executes several
        # benchmark mains in one process; each needs a clean window) —
        # launchers that want one pane pass a shared instance
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._lat_hist = self.metrics.histogram("engine.query_latency_ms")
        self.metrics.register_collector(self._collect)
        self._edges = None                     # lazy, for oracle verification
        self._oracle: dict[str, int] = {}      # canon_key -> oracle count
        self._queues: dict[str, deque] = {}    # tenant -> FIFO of Tickets
        self._inflight: deque = deque()        # _InFlight groups, mid-round
        self._seq = 0
        self.tenant_depth = tenant_depth
        self.tenant_shares = dict(tenant_shares or {})
        self.preempt_dispatches = preempt_dispatches
        # round-execution counters (the coalescing/preemption evidence)
        self.requests_resolved = 0
        self.executions = 0                    # completed class executions
        self.coalesced = 0                     # tickets riding an execution
        self.preemptions = 0                   # groups suspended mid-count
        self.last_round_dispatches = 0         # kernel dispatches last round
        self.rejections: dict[str, int] = {}   # tenant -> admission rejects
        self._resolved_by_tenant: dict[str, int] = {}

    def _collect(self) -> dict:
        """Engine/cache/store counters for `metrics.snapshot()` — the
        dataclass stats objects stay the storage; this merges them into
        the one `subsystem.metric` pane."""
        out = {
            "engine.requests_resolved": self.requests_resolved,
            "engine.executions": self.executions,
            "engine.coalesced": self.coalesced,
            "engine.pending": self.pending(),
            "engine.inflight": self.inflight(),
            "engine.preemptions": self.preemptions,
            "engine.admission_rejected": sum(self.rejections.values()),
            "engine.cache_entries": len(self.cache),
        }
        for k, v in self.cache.stats.as_dict().items():
            out[f"cache.{k}"] = v
        if self.cache.store is not None:
            for k, v in self.cache.store.stats.as_dict().items():
                out[f"store.{k}"] = v
        if self.live is not None:
            out.update({
                "live.epoch": self.live.edge_epoch,
                "live.stats_epoch": self.live.stats_epoch,
                "live.overlay_edges": self.live.overlay_edges(),
                "live.compactions": self.live.compactions,
                "live.mutations_applied": self.mutations_applied,
                "live.pending_mutations": len(self._mutations),
                "live.matcher_rebinds": self.matcher_rebinds,
                "live.matcher_rebuilds": self.matcher_rebuilds,
            })
            for k, v in self._maintainer.counters().items():
                out[f"live.{k}"] = v
        return out

    # ------------------------------------------------------ async serving
    def plan(self, request: QueryRequest) -> PlannedQuery:
        """Cache/plan resolution ONLY — search + plan build + JIT warmup
        on a miss, pure lookup on a hit.  Never executes a count."""
        with get_tracer().span(
                "engine.plan", pattern=request.pattern.name or "anon",
                mode=request.mode) as sp:
            entry, hit = self.cache.get_or_build(
                request.pattern, self.graph, self.stats,
                cfg=self.cfg, mesh=self.mesh, axis=self.axis,
                mode=request.mode, use_iep=request.use_iep,
                chunk=self.chunk, arrays=self._arrays,
                graph_fp=self._epoch.plan_key,
            )
            sp.set(cache_hit=hit, canon_key=entry.canon_key)
        return PlannedQuery(entry=entry, cache_hit=hit)

    def try_enqueue(self, request: QueryRequest) -> Ticket | Rejection:
        """Admission-controlled enqueue: returns a :class:`Ticket`, or a
        :class:`Rejection` when the request's tenant already has
        ``tenant_depth`` tickets queued.  Rejections are deterministic
        (a pure function of the queue depth at call time) and counted
        per tenant (``rejections`` / ``engine.admission_rejected``)."""
        tenant = request.tenant
        q = self._queues.setdefault(tenant, deque())
        if self.tenant_depth is not None and len(q) >= self.tenant_depth:
            self.rejections[tenant] = self.rejections.get(tenant, 0) + 1
            self.metrics.counter("engine.admission_rejected",
                                 tenant=tenant).inc()
            return Rejection(tenant=tenant, reason="queue depth bound",
                             depth=len(q), limit=self.tenant_depth)
        ticket = Ticket(request=request, seq=self._seq)
        self._seq += 1
        q.append(ticket)
        return ticket

    def enqueue(self, request: QueryRequest) -> Ticket:
        """Admit a request; the returned ticket resolves when a round
        executes it (:meth:`run_pending`, or the Gateway's scheduler).
        Raises :class:`AdmissionRejected` past the tenant depth bound."""
        out = self.try_enqueue(request)
        if isinstance(out, Rejection):
            raise AdmissionRejected(out)
        return out

    def cancel(self, ticket: Ticket) -> bool:
        """Withdraw a still-queued ticket (marks it ``cancelled`` and
        removes it from its tenant queue).  Returns False when the ticket
        already resolved, was cancelled before, or is mid-execution in an
        in-flight group (a dispatched count is not torn down)."""
        if ticket.done or ticket.cancelled:
            return False
        q = self._queues.get(ticket.request.tenant)
        if q is None or ticket not in q:
            return False
        q.remove(ticket)
        ticket.cancelled = True
        return True

    def pending(self, tenant: str | None = None) -> int:
        """Queued (not yet taken into a round) ticket count — one tenant
        or all."""
        if tenant is not None:
            return len(self._queues.get(tenant, ()))
        return sum(len(q) for q in self._queues.values())

    def inflight(self) -> int:
        """Tickets taken into a round whose class is still mid-count
        (checkpointed by the preemption budget, resumes next round)."""
        return sum(len(f.tickets) for f in self._inflight)

    # --------------------------------------------------------- mutation
    def mutations_pending(self) -> int:
        """Queued mutation batches (0 for non-live engines — safe for
        schedulers to poll unconditionally)."""
        return len(self._mutations)

    def request_mutation(self, verb: str, edges=None) -> dict:
        """Queue one mutation batch (`insert_edges` / `delete_edges` /
        `compact`).  Batches apply atomically at the START of the next
        round — never under an in-flight `CountState` — so a query
        submitted after this call is answered on the post-mutation
        epoch.  Returns an ack with the queue depth and current epoch."""
        if self.live is None:
            raise RuntimeError(
                "engine is not live: construct QueryEngine(..., live=True) "
                "to serve mutate verbs")
        from ..live import MUTATION_VERBS

        if verb not in MUTATION_VERBS:
            raise ValueError(
                f"unknown mutation verb {verb!r}; have {MUTATION_VERBS}")
        batch = None
        if verb != "compact":
            batch = [(int(e[0]), int(e[1])) for e in (edges or ())]
        self._mutations.append((verb, batch))
        return {
            "verb": verb,
            "queued_edges": 0 if batch is None else len(batch),
            "pending_batches": len(self._mutations),
            "edge_epoch": self.live.edge_epoch,
        }

    def _apply_mutations(self) -> int:
        """Drain the mutation queue at a round boundary.

        In-flight groups are cleanly RE-ENQUEUED (their tickets return
        to the head of their tenant queues in admission order and the
        partial `CountState`s are dropped): a preempted count never
        resumes across an epoch, so every resolved count is computed
        entirely on one epoch's adjacency."""
        live = self.live
        batches = len(self._mutations)
        requeue = [t for fl in self._inflight for t in fl.tickets]
        with get_tracer().span("engine.mutate", batches=batches,
                               requeued=len(requeue)):
            self._inflight.clear()
            for t in sorted(requeue, key=lambda t: t.seq, reverse=True):
                self._queues.setdefault(t.request.tenant,
                                        deque()).appendleft(t)
            applied = 0
            while self._mutations:
                verb, batch = self._mutations.popleft()
                applied += live.apply(verb, batch)
            maybe_compact(live, self.compaction_policy)
            if stats_drifted(live, self.stats, self.compaction_policy):
                # |E| moved materially: plans stay valid but their
                # perf-model ranking is stale — bump the stats epoch so
                # the next plan() re-searches under fresh statistics
                live.stats_epoch += 1
                self.stats = compute_stats(live.view, self.cfg)
                if self.cache.store is not None:
                    self.cache.store.save_graph_stats(
                        live.view.fingerprint, self.stats)
            self._refresh_live()
        self.mutations_applied += applied
        self.last_round_mutations = batches
        if self.cache.store is not None:
            self.cache.store.save_overlay(live.to_record())
        return applied

    def _refresh_live(self) -> None:
        """Swap the new epoch's view/device arrays into the engine and
        every cached matcher.  Fixed overlay shapes make this a rebind
        (zero recompiles); genuine growth rebuilds matchers honestly
        (counted in `cache.stats.n_compiles` / `matcher_rebuilds`)."""
        live = self.live
        view = live.view
        arrays = device_graph(view)
        for entry in self.cache.entries():
            try:
                entry.matcher.rebind(arrays, graph=view)
                self.matcher_rebinds += 1
            except ValueError:
                if entry.sharded:
                    matcher = ShardedMatcher(
                        view, entry.plan, self.mesh, axis=self.axis,
                        cfg=self.cfg, chunk=self.chunk, arrays=arrays)
                    matcher.warmup()
                else:
                    matcher = Matcher(view, entry.plan, self.cfg,
                                      arrays=arrays)
                    matcher.warmup(chunk=self.chunk)
                self.cache.stats.n_compiles += 1
                entry.matcher.release()
                entry.matcher = matcher
                self.matcher_rebuilds += 1
        self.graph = view
        self._arrays = arrays
        self._epoch = EpochStamp.for_live(live, self.stats)
        # oracle memos are content-addressed to the old epoch
        self._oracle.clear()
        self._edges = None

    @staticmethod
    def _group_key(request: QueryRequest) -> tuple:
        # mirrors PlanCache.entry_key normalization: naive ignores
        # use_iep, so the flag must not split one round group either
        use_iep = bool(request.use_iep) and request.mode != "naive"
        return (canonical_key(request.pattern), request.mode, use_iep)

    def _take_tickets(self, limit: int | None) -> list[Ticket]:
        """Drain up to ``limit`` tickets across tenant queues by
        deterministic weighted round-robin: tenants are visited in
        first-seen order, each yielding up to ``tenant_shares[tenant]``
        (default 1) tickets per cycle, until the limit or every queue is
        empty.  A single tenant degenerates to exact FIFO."""
        out: list[Ticket] = []
        while limit is None or len(out) < limit:
            progressed = False
            for tenant, q in self._queues.items():
                share = max(int(self.tenant_shares.get(tenant, 1)), 1)
                for _ in range(share):
                    if not q or (limit is not None and len(out) >= limit):
                        break
                    out.append(q.popleft())
                    progressed = True
            if not progressed:
                break
        return out

    def run_pending(self, limit: int | None = None, *,
                    max_dispatches: int | None = None) -> list[Ticket]:
        """Execute up to ``limit`` queued tickets as ONE round.

        Tickets whose requests fall in the same isomorphism class (and
        mode/use_iep) are coalesced: the class is planned and executed
        once, and every rider ticket resolves with that count — riders
        are accounted as cache hits (they never search, compile, or
        dispatch).  Distinct classes in the round are micro-batched
        back-to-back against the warmed resident graph.

        With a dispatch budget (``max_dispatches`` here, or the engine's
        ``preempt_dispatches`` default) the round is PREEMPTIVE: once the
        budget is spent, the mid-count class checkpoints its chunk stack
        and rotates to the back of the in-flight queue; the next round
        resumes it after any other waiting classes.  Tickets of a
        suspended class resolve in the round that completes it.

        Returns the tickets resolved THIS round, in admission order.
        """
        if limit is not None and limit < 0:
            # a negative slice would silently drop the newest tickets
            raise ValueError(f"limit must be >= 0, got {limit}")
        budget_n = (self.preempt_dispatches if max_dispatches is None
                    else max_dispatches)
        remaining = None if budget_n is None else max(int(budget_n), 1)
        self.last_round_dispatches = 0
        self.last_round_mutations = 0
        if self._mutations:
            # round boundary: apply queued mutations BEFORE taking
            # tickets, so everything executed below runs on one epoch
            self._apply_mutations()
        take = self._take_tickets(limit)
        fresh = 0
        for t in take:
            key = self._group_key(t.request)
            fl = next((f for f in self._inflight if f.key == key), None)
            if fl is not None:
                # same class already mid-round: ride its execution
                fl.tickets.append(t)
            else:
                self._inflight.append(_InFlight(key=key, tickets=[t]))
                fresh += 1
        if not self._inflight:
            return []
        resolved: list[Ticket] = []
        with get_tracer().span("engine.round", tickets=len(take),
                               groups=fresh,
                               coalesced=len(take) - fresh,
                               budget=-1 if remaining is None else remaining):
            while self._inflight:
                if remaining is not None and remaining <= 0:
                    break
                fl = self._inflight.popleft()
                done, used = self._run_group(fl, remaining)
                self.last_round_dispatches += used
                if remaining is not None:
                    remaining -= used
                if done:
                    resolved.extend(fl.tickets)
                else:
                    # suspended mid-count: rotate BEHIND other waiting
                    # classes so they complete between this one's quanta
                    self.preemptions += 1
                    self._inflight.append(fl)
        resolved.sort(key=lambda t: t.seq)
        return resolved

    def _run_group(self, fl: _InFlight,
                   remaining: int | None) -> tuple[bool, int]:
        """Start or resume one class group under a dispatch budget.
        Returns (completed, dispatches_used); on completion every ticket
        in the group is resolved with the (bit-identical) final count."""
        lead = fl.tickets[0].request
        if fl.planned is None:
            with timer() as t_plan:
                fl.planned = self.plan(lead)
            fl.seconds += t_plan.seconds
        entry, hit = fl.planned.entry, fl.planned.cache_hit
        before = 0 if fl.state is None else fl.state.dispatches
        with get_tracer().span(
                "engine.execute", pattern=lead.pattern.name or "anon",
                canon_key=entry.canon_key, cache_hit=hit,
                riders=len(fl.tickets) - 1,
                resumed=fl.state is not None):
            with timer() as t_run:
                if self._maintainer is not None:
                    fl.state, out = self._maintainer.count_partial(
                        fl.key, entry, fl.state, chunk=self.chunk,
                        max_dispatches=remaining)
                else:
                    fl.state, out = entry.count_partial(
                        fl.state, chunk=self.chunk, max_dispatches=remaining)
            fl.seconds += t_run.seconds
        # sharded counts report no per-dispatch state (one logical unit)
        used = (1 if fl.state is None
                else max(fl.state.dispatches - before, 0))
        if out is None:
            return False, used
        entry.executions += 1
        self.executions += 1
        latency = fl.seconds

        expected = None
        if any(t.request.verify for t in fl.tickets):
            with get_tracer().span("engine.verify",
                                   canon_key=entry.canon_key):
                expected = self._oracle_count(entry.canon_key,
                                              lead.pattern)
        for j, t in enumerate(fl.tickets):
            self._lat_hist.observe(latency * 1e3)
            self.metrics.histogram("engine.query_latency_ms",
                                   tenant=t.request.tenant).observe(
                                       latency * 1e3)
            self.requests_resolved += 1
            self._resolved_by_tenant[t.request.tenant] = (
                self._resolved_by_tenant.get(t.request.tenant, 0) + 1)
            if j > 0:
                # a coalesced rider is a logical cache hit: it was served
                # without a search, a compile, or its own dispatch
                self.cache.stats.hits += 1
                entry.hits += 1
                self.coalesced += 1
            verified = (expected == out.count
                        if t.request.verify and expected is not None else None)
            t._result = QueryResult(
                pattern_name=t.request.pattern.name or "anon",
                canon_key=entry.canon_key,
                count=out.count,
                latency_s=latency,
                cache_hit=hit if j == 0 else True,
                mode=t.request.mode,
                use_iep=t.request.use_iep,
                order=entry.config.order,
                res_set=entry.plan.res_set,
                iep_k=entry.config.iep_k,
                search_seconds=0.0 if (hit or j > 0) else entry.search_seconds,
                compile_seconds=0.0 if (hit or j > 0)
                else entry.compile_seconds,
                overflowed=out.overflowed,
                max_needed=out.max_needed,
                expected=expected if t.request.verify else None,
                verified=verified,
                coalesced=j > 0,
            )
        return True, used

    def _oracle_count(self, canon_key: str, pattern: Pattern) -> int:
        # oracle counts are (label-)isomorphism-invariant — memoize per
        # class; the canonical key already separates label variants
        if canon_key not in self._oracle:
            from ..core.oracle import count_embeddings_oracle

            if self._edges is None:
                self._edges = self.graph.edge_array()
            self._oracle[canon_key] = count_embeddings_oracle(
                self.graph.n, self._edges, pattern,
                labels=self.graph.labels)
        return self._oracle[canon_key]

    # ------------------------------------------- deprecated sync serving
    def submit(self, request: QueryRequest) -> QueryResult:
        """Deprecated synchronous path: one request, one round.

        Thin wrapper over :meth:`enqueue` + :meth:`run_pending(limit=1)`
        — bit-identical counts and identical cache accounting to the
        pre-Gateway implementation (no coalescing at round size 1)."""
        warnings.warn(
            "QueryEngine.submit() is deprecated; use plan()/enqueue() with "
            "run_pending(), or schedule the engine through "
            "repro.serve.gateway.Gateway",
            DeprecationWarning, stacklevel=2)
        ticket = self.enqueue(request)
        # the queue is FIFO: earlier enqueue()d tickets (if any) resolve
        # first, one per round, until ours does
        while not ticket.done and (self.pending() or self.inflight()):
            self.run_pending(limit=1)
        return ticket.result

    def serve(self, requests) -> list[QueryResult]:
        """Deprecated synchronous path: each request is its own round
        (sequential, no coalescing — the pre-Gateway behaviour)."""
        warnings.warn(
            "QueryEngine.serve() is deprecated; enqueue() tickets and "
            "schedule them via repro.serve.gateway.Gateway",
            DeprecationWarning, stacklevel=2)
        out = []
        for r in requests:
            ticket = self.enqueue(r)
            while not ticket.done and (self.pending() or self.inflight()):
                self.run_pending(limit=1)
            out.append(ticket.result)
        return out

    def warm_from_disk(self) -> int:
        """Preload every persisted plan compatible with this engine's
        (graph, executor, layout) before the first request arrives, so a
        restarted replica serves warm from query one.  Returns the
        number of entries installed (0 without an attached store)."""
        return self.cache.preload(
            self.graph, self.stats, cfg=self.cfg, mesh=self.mesh,
            axis=self.axis, chunk=self.chunk, arrays=self._arrays,
            graph_fp=self._epoch.plan_key)

    # ------------------------------------------------------------- reporting
    def reset_window(self) -> None:
        """Start a fresh measurement window (e.g. between benchmark
        warmup and measured phases): registry histograms and counters
        zero; cache/store state and the dataclass counters (which
        describe the whole process lifetime) are untouched.  The Gateway
        exposes the same method on its registry, so both sides of a
        serving benchmark reset identically."""
        self.metrics.reset_window()

    def reset_latencies(self) -> None:
        """Deprecated spelling of :meth:`reset_window` (kept for the
        benchmark harness)."""
        self.reset_window()

    def latency_percentiles(self, tenant: str | None = None) -> dict:
        """Per-query wall-latency summary from the registry histogram
        (`engine.query_latency_ms`, optionally the per-tenant labelled
        series) — same keys as the Gateway's per-turn summaries:
        n / p50_ms / p95_ms / p99_ms / mean_ms."""
        if tenant is None:
            return latency_summary(self._lat_hist)
        return latency_summary(
            self.metrics.histogram("engine.query_latency_ms", tenant=tenant))

    def tenant_report(self) -> dict:
        """Per-tenant serving report: resolved / rejected / queued depths
        plus the tenant's own latency percentiles (the gateway report and
        `benchmarks/gateway_mix.py` read p99 from here)."""
        tenants = sorted(set(self._queues)
                         | set(self._resolved_by_tenant)
                         | set(self.rejections))
        out = {}
        for t in tenants:
            out[t] = {
                "resolved": self._resolved_by_tenant.get(t, 0),
                "rejected": self.rejections.get(t, 0),
                "pending": self.pending(t),
                "share": max(int(self.tenant_shares.get(t, 1)), 1),
                "latency": self.latency_percentiles(t),
            }
        return out

    def summary(self) -> dict:
        out = {
            "graph": self.graph.name,
            "devices": 1 if self.mesh is None else int(
                np.prod(list(self.mesh.shape.values()))),
            "stats_seconds": self.stats_seconds,
            "latency": self.latency_percentiles(),
            "cache": self.cache.stats.as_dict(),
            "cache_entries": len(self.cache),
            "requests_resolved": self.requests_resolved,
            "executions": self.executions,
            "coalesced": self.coalesced,
            "preemptions": self.preemptions,
            "rejections": sum(self.rejections.values()),
            "tenants": self.tenant_report(),
        }
        if self.cache.store is not None:
            out["store"] = self.cache.store.stats.as_dict()
        if self.live is not None:
            out["live"] = {
                "edge_epoch": self.live.edge_epoch,
                "stats_epoch": self.live.stats_epoch,
                "overlay_edges": self.live.overlay_edges(),
                "compactions": self.live.compactions,
                "mutations_applied": self.mutations_applied,
                "matcher_rebinds": self.matcher_rebinds,
                "matcher_rebuilds": self.matcher_rebuilds,
                **self._maintainer.counters(),
            }
        return out
