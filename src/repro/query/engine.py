"""QueryEngine — the batched pattern-count request path.

Loads a dataset ONCE: the CSR is uploaded to device memory a single
time (shared by every cached matcher via ``arrays=``), graph statistics
are computed once at startup, and when the process has multiple JAX
devices the graph stays resident on the mesh with the executor's
fine-grained outer-loop striping (`ShardedMatcher`).  Requests then
stream through the `PlanCache`: the first query of an isomorphism
class pays configuration search + JIT, repeats replay the warmed
program.

Request surface (DESIGN.md §5).  The engine is asynchronous-by-default
so the serving Gateway can schedule it against other mesh tenants:

  * ``plan(request)``    — cache/plan resolution only (search + JIT on
                           a miss); never executes a count.
  * ``enqueue(request)`` — admit a request, returning a :class:`Ticket`
                           that resolves later.
  * ``run_pending(limit)`` — execute up to ``limit`` queued tickets as
                           one round, COALESCING tickets of the same
                           isomorphism class (× mode × use_iep) into a
                           single plan execution: N bursty duplicates
                           cost one kernel dispatch, and the N−1
                           riders are accounted as cache hits.

``submit()``/``serve()`` remain as deprecated synchronous shims (one
request per round — the exact pre-Gateway behaviour).  Per-query wall
latency is recorded; `summary()` reports p50/p99 plus the cache
counters that prove hits never re-search or re-compile.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from ..core.executor import ExecutorConfig, compute_stats, device_graph
from ..core.pattern import Pattern
from ..core.perf_model import GraphStats
from ..graph.csr import GraphCSR
from ..obs import MetricsRegistry, get_tracer, latency_summary, timer
from .cache import DEFAULT_MAX_ENTRIES, CacheEntry, PlanCache
from .canon import canonical_key


@dataclass(frozen=True)
class QueryRequest:
    """One pattern-count request (per-request options ride along)."""

    pattern: Pattern
    use_iep: bool = False
    verify: bool = False          # check against the pure-python oracle
    mode: str = "graphpi"


@dataclass
class QueryResult:
    pattern_name: str
    canon_key: str
    count: int
    latency_s: float              # wall time incl. cache miss costs
    cache_hit: bool
    mode: str
    use_iep: bool
    order: tuple
    res_set: tuple
    iep_k: int
    search_seconds: float         # 0.0 on a hit
    compile_seconds: float        # 0.0 on a hit
    overflowed: bool
    max_needed: int
    expected: int | None = None   # oracle count when verified
    verified: bool | None = None  # None = not requested
    coalesced: bool = False       # resolved by another ticket's execution

    def line(self) -> str:
        """One human-readable serving-log line."""
        v = ("" if self.verified is None
             else ("  verify=OK" if self.verified else "  verify=MISMATCH"))
        o = "  OVERFLOWED" if self.overflowed else ""
        how = "HIT " if self.cache_hit else "MISS"
        if self.coalesced:
            how = "COAL"
        return (f"{self.pattern_name:<16} count={self.count:<12} "
                f"{how} "
                f"lat={self.latency_s * 1e3:8.1f}ms "
                f"(search={self.search_seconds:.3f}s "
                f"compile={self.compile_seconds:.3f}s){v}{o}")


@dataclass(frozen=True)
class PlannedQuery:
    """What ``plan()`` resolves: the warmed cache entry plus whether the
    resolution was a cache hit (misses paid search/JIT just now)."""

    entry: CacheEntry
    cache_hit: bool


@dataclass
class Ticket:
    """Handle for an enqueued request; resolves when a round executes it
    (``QueryEngine.run_pending`` or the Gateway's graph workload)."""

    request: QueryRequest
    seq: int
    _result: QueryResult | None = None

    @property
    def done(self) -> bool:
        return self._result is not None

    @property
    def result(self) -> QueryResult:
        if self._result is None:
            raise RuntimeError(
                f"ticket #{self.seq} not resolved yet — run the engine's "
                f"pending queue (run_pending) or schedule it via the Gateway")
        return self._result


class QueryEngine:
    """Serve pattern-count queries over one resident graph.

    Parameters
    ----------
    graph:   the data graph, loaded once.
    cfg:     executor configuration shared by every cached matcher
             (part of the cache key).
    mesh:    optional JAX mesh; when given, counting runs sharded over
             `axis` with the CSR resident mesh-wide.
    chunk:   vertex-chunk striping of the outer loop — smaller chunks
             bound frontier memory and give the overflow bisection finer
             grain at the price of more kernel dispatches per query
             (latency/footprint trade-off, DESIGN.md §5).
    """

    def __init__(self, graph: GraphCSR, *, cfg: ExecutorConfig | None = None,
                 mesh=None, axis: str = "data", chunk: int | None = None,
                 cache: PlanCache | None = None,
                 store=None,
                 stats: GraphStats | None = None,
                 metrics: MetricsRegistry | None = None):
        self.graph = graph
        self.cfg = cfg or ExecutorConfig()
        self.mesh = mesh
        self.axis = axis
        self.chunk = chunk
        if cache is None:
            cache = PlanCache(max_entries=DEFAULT_MAX_ENTRIES, store=store)
        elif store is not None and cache.store is None:
            cache.store = store             # attach persistence to the
        self.cache = cache                  # caller-provided cache
        self._arrays = device_graph(graph)     # ONE resident CSR upload
        with timer() as t:
            if stats is None:
                # a restarted engine skips the startup triangle count when
                # the attached store has a stats record for this exact graph
                # (content fingerprint); compute-and-persist otherwise
                if self.cache.store is not None:
                    stats = self.cache.store.load_graph_stats(
                        graph.fingerprint)
                if stats is None:
                    stats = compute_stats(graph, self.cfg)
                    if self.cache.store is not None:
                        self.cache.store.save_graph_stats(
                            graph.fingerprint, stats)
        self.stats = stats
        self.stats_seconds = t.seconds
        # registries are per-engine (benchmarks/run.py executes several
        # benchmark mains in one process; each needs a clean window) —
        # launchers that want one pane pass a shared instance
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._lat_hist = self.metrics.histogram("engine.query_latency_ms")
        self.metrics.register_collector(self._collect)
        self._edges = None                     # lazy, for oracle verification
        self._oracle: dict[str, int] = {}      # canon_key -> oracle count
        self._pending: list[Ticket] = []
        self._seq = 0
        # round-execution counters (the coalescing evidence)
        self.requests_resolved = 0
        self.executions = 0                    # entry.count() dispatches
        self.coalesced = 0                     # tickets riding an execution

    def _collect(self) -> dict:
        """Engine/cache/store counters for `metrics.snapshot()` — the
        dataclass stats objects stay the storage; this merges them into
        the one `subsystem.metric` pane."""
        out = {
            "engine.requests_resolved": self.requests_resolved,
            "engine.executions": self.executions,
            "engine.coalesced": self.coalesced,
            "engine.pending": len(self._pending),
            "engine.cache_entries": len(self.cache),
        }
        for k, v in self.cache.stats.as_dict().items():
            out[f"cache.{k}"] = v
        if self.cache.store is not None:
            for k, v in self.cache.store.stats.as_dict().items():
                out[f"store.{k}"] = v
        return out

    # ------------------------------------------------------ async serving
    def plan(self, request: QueryRequest) -> PlannedQuery:
        """Cache/plan resolution ONLY — search + plan build + JIT warmup
        on a miss, pure lookup on a hit.  Never executes a count."""
        with get_tracer().span(
                "engine.plan", pattern=request.pattern.name or "anon",
                mode=request.mode) as sp:
            entry, hit = self.cache.get_or_build(
                request.pattern, self.graph, self.stats,
                cfg=self.cfg, mesh=self.mesh, axis=self.axis,
                mode=request.mode, use_iep=request.use_iep,
                chunk=self.chunk, arrays=self._arrays,
            )
            sp.set(cache_hit=hit, canon_key=entry.canon_key)
        return PlannedQuery(entry=entry, cache_hit=hit)

    def enqueue(self, request: QueryRequest) -> Ticket:
        """Admit a request; the returned ticket resolves when a round
        executes it (:meth:`run_pending`, or the Gateway's scheduler)."""
        ticket = Ticket(request=request, seq=self._seq)
        self._seq += 1
        self._pending.append(ticket)
        return ticket

    def pending(self) -> int:
        return len(self._pending)

    @staticmethod
    def _group_key(request: QueryRequest) -> tuple:
        # mirrors PlanCache.entry_key normalization: naive ignores
        # use_iep, so the flag must not split one round group either
        use_iep = bool(request.use_iep) and request.mode != "naive"
        return (canonical_key(request.pattern), request.mode, use_iep)

    def run_pending(self, limit: int | None = None) -> list[Ticket]:
        """Execute up to ``limit`` queued tickets as ONE round.

        Tickets whose requests fall in the same isomorphism class (and
        mode/use_iep) are coalesced: the class is planned and executed
        once, and every rider ticket resolves with that count — riders
        are accounted as cache hits (they never search, compile, or
        dispatch).  Distinct classes in the round are micro-batched
        back-to-back against the warmed resident graph.  Returns the
        resolved tickets in admission order.
        """
        if limit is not None and limit < 0:
            # a negative slice would silently drop the newest tickets
            raise ValueError(f"limit must be >= 0, got {limit}")
        take = self._pending if limit is None else self._pending[:limit]
        take = list(take)
        del self._pending[:len(take)]
        if not take:
            return []
        groups: dict[tuple, list[Ticket]] = {}
        for t in take:
            groups.setdefault(self._group_key(t.request), []).append(t)
        with get_tracer().span("engine.round", tickets=len(take),
                               groups=len(groups),
                               coalesced=len(take) - len(groups)):
            for tickets in groups.values():
                self._execute_group(tickets)
        return take

    def _execute_group(self, tickets: list[Ticket]) -> None:
        lead = tickets[0].request
        with timer() as t_all:
            planned = self.plan(lead)
            entry, hit = planned.entry, planned.cache_hit
            with get_tracer().span(
                    "engine.execute", pattern=lead.pattern.name or "anon",
                    canon_key=entry.canon_key, cache_hit=hit,
                    riders=len(tickets) - 1):
                out = entry.count(chunk=self.chunk)
            entry.executions += 1
            self.executions += 1
        latency = t_all.seconds

        expected = None
        if any(t.request.verify for t in tickets):
            with get_tracer().span("engine.verify",
                                   canon_key=entry.canon_key):
                expected = self._oracle_count(entry.canon_key,
                                              lead.pattern)
        for j, t in enumerate(tickets):
            self._lat_hist.observe(latency * 1e3)
            self.requests_resolved += 1
            if j > 0:
                # a coalesced rider is a logical cache hit: it was served
                # without a search, a compile, or its own dispatch
                self.cache.stats.hits += 1
                entry.hits += 1
                self.coalesced += 1
            verified = (expected == out.count
                        if t.request.verify and expected is not None else None)
            t._result = QueryResult(
                pattern_name=t.request.pattern.name or "anon",
                canon_key=entry.canon_key,
                count=out.count,
                latency_s=latency,
                cache_hit=hit if j == 0 else True,
                mode=t.request.mode,
                use_iep=t.request.use_iep,
                order=entry.config.order,
                res_set=entry.plan.res_set,
                iep_k=entry.config.iep_k,
                search_seconds=0.0 if (hit or j > 0) else entry.search_seconds,
                compile_seconds=0.0 if (hit or j > 0)
                else entry.compile_seconds,
                overflowed=out.overflowed,
                max_needed=out.max_needed,
                expected=expected if t.request.verify else None,
                verified=verified,
                coalesced=j > 0,
            )

    def _oracle_count(self, canon_key: str, pattern: Pattern) -> int:
        # oracle counts are (label-)isomorphism-invariant — memoize per
        # class; the canonical key already separates label variants
        if canon_key not in self._oracle:
            from ..core.oracle import count_embeddings_oracle

            if self._edges is None:
                self._edges = self.graph.edge_array()
            self._oracle[canon_key] = count_embeddings_oracle(
                self.graph.n, self._edges, pattern,
                labels=self.graph.labels)
        return self._oracle[canon_key]

    # ------------------------------------------- deprecated sync serving
    def submit(self, request: QueryRequest) -> QueryResult:
        """Deprecated synchronous path: one request, one round.

        Thin wrapper over :meth:`enqueue` + :meth:`run_pending(limit=1)`
        — bit-identical counts and identical cache accounting to the
        pre-Gateway implementation (no coalescing at round size 1)."""
        warnings.warn(
            "QueryEngine.submit() is deprecated; use plan()/enqueue() with "
            "run_pending(), or schedule the engine through "
            "repro.serve.gateway.Gateway",
            DeprecationWarning, stacklevel=2)
        ticket = self.enqueue(request)
        # the queue is FIFO: earlier enqueue()d tickets (if any) resolve
        # first, one per round, until ours does
        while not ticket.done and self.pending():
            self.run_pending(limit=1)
        return ticket.result

    def serve(self, requests) -> list[QueryResult]:
        """Deprecated synchronous path: each request is its own round
        (sequential, no coalescing — the pre-Gateway behaviour)."""
        warnings.warn(
            "QueryEngine.serve() is deprecated; enqueue() tickets and "
            "schedule them via repro.serve.gateway.Gateway",
            DeprecationWarning, stacklevel=2)
        out = []
        for r in requests:
            ticket = self.enqueue(r)
            while not ticket.done and self.pending():
                self.run_pending(limit=1)
            out.append(ticket.result)
        return out

    def warm_from_disk(self) -> int:
        """Preload every persisted plan compatible with this engine's
        (graph, executor, layout) before the first request arrives, so a
        restarted replica serves warm from query one.  Returns the
        number of entries installed (0 without an attached store)."""
        return self.cache.preload(
            self.graph, self.stats, cfg=self.cfg, mesh=self.mesh,
            axis=self.axis, chunk=self.chunk, arrays=self._arrays)

    # ------------------------------------------------------------- reporting
    def reset_window(self) -> None:
        """Start a fresh measurement window (e.g. between benchmark
        warmup and measured phases): registry histograms and counters
        zero; cache/store state and the dataclass counters (which
        describe the whole process lifetime) are untouched.  The Gateway
        exposes the same method on its registry, so both sides of a
        serving benchmark reset identically."""
        self.metrics.reset_window()

    def reset_latencies(self) -> None:
        """Deprecated spelling of :meth:`reset_window` (kept for the
        benchmark harness)."""
        self.reset_window()

    def latency_percentiles(self) -> dict:
        """Per-query wall-latency summary from the registry histogram
        (`engine.query_latency_ms`) — same keys as the Gateway's
        per-turn summaries: n / p50_ms / p95_ms / p99_ms / mean_ms."""
        return latency_summary(self._lat_hist)

    def summary(self) -> dict:
        out = {
            "graph": self.graph.name,
            "devices": 1 if self.mesh is None else int(
                np.prod(list(self.mesh.shape.values()))),
            "stats_seconds": self.stats_seconds,
            "latency": self.latency_percentiles(),
            "cache": self.cache.stats.as_dict(),
            "cache_entries": len(self.cache),
            "requests_resolved": self.requests_resolved,
            "executions": self.executions,
            "coalesced": self.coalesced,
        }
        if self.cache.store is not None:
            out["store"] = self.cache.store.stats.as_dict()
        return out
