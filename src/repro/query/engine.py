"""QueryEngine — the batched pattern-count request path.

Loads a dataset ONCE: the CSR is uploaded to device memory a single
time (shared by every cached matcher via ``arrays=``), graph statistics
are computed once at startup, and when the process has multiple JAX
devices the graph stays resident on the mesh with the executor's
fine-grained outer-loop striping (`ShardedMatcher`).  Requests then
stream through the `PlanCache`: the first query of an isomorphism
class pays configuration search + JIT, repeats replay the warmed
program.  Per-query wall latency is recorded; `summary()` reports
p50/p99 plus the cache counters that prove hits never re-search or
re-compile.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ..core.executor import ExecutorConfig, compute_stats, device_graph
from ..core.pattern import Pattern
from ..core.perf_model import GraphStats
from ..graph.csr import GraphCSR
from .cache import DEFAULT_MAX_ENTRIES, PlanCache


@dataclass(frozen=True)
class QueryRequest:
    """One pattern-count request (per-request options ride along)."""

    pattern: Pattern
    use_iep: bool = False
    verify: bool = False          # check against the pure-python oracle
    mode: str = "graphpi"


@dataclass
class QueryResult:
    pattern_name: str
    canon_key: str
    count: int
    latency_s: float              # wall time incl. cache miss costs
    cache_hit: bool
    mode: str
    use_iep: bool
    order: tuple
    res_set: tuple
    iep_k: int
    search_seconds: float         # 0.0 on a hit
    compile_seconds: float        # 0.0 on a hit
    overflowed: bool
    max_needed: int
    expected: int | None = None   # oracle count when verified
    verified: bool | None = None  # None = not requested

    def line(self) -> str:
        """One human-readable serving-log line."""
        v = ("" if self.verified is None
             else ("  verify=OK" if self.verified else "  verify=MISMATCH"))
        o = "  OVERFLOWED" if self.overflowed else ""
        return (f"{self.pattern_name:<16} count={self.count:<12} "
                f"{'HIT ' if self.cache_hit else 'MISS'} "
                f"lat={self.latency_s * 1e3:8.1f}ms "
                f"(search={self.search_seconds:.3f}s "
                f"compile={self.compile_seconds:.3f}s){v}{o}")


class QueryEngine:
    """Serve pattern-count queries over one resident graph.

    Parameters
    ----------
    graph:   the data graph, loaded once.
    cfg:     executor configuration shared by every cached matcher
             (part of the cache key).
    mesh:    optional JAX mesh; when given, counting runs sharded over
             `axis` with the CSR resident mesh-wide.
    chunk:   vertex-chunk striping of the outer loop — smaller chunks
             bound frontier memory and give the overflow bisection finer
             grain at the price of more kernel dispatches per query
             (latency/footprint trade-off, DESIGN.md §5).
    """

    def __init__(self, graph: GraphCSR, *, cfg: ExecutorConfig | None = None,
                 mesh=None, axis: str = "data", chunk: int | None = None,
                 cache: PlanCache | None = None,
                 store=None,
                 stats: GraphStats | None = None):
        self.graph = graph
        self.cfg = cfg or ExecutorConfig()
        self.mesh = mesh
        self.axis = axis
        self.chunk = chunk
        if cache is None:
            cache = PlanCache(max_entries=DEFAULT_MAX_ENTRIES, store=store)
        elif store is not None and cache.store is None:
            cache.store = store             # attach persistence to the
        self.cache = cache                  # caller-provided cache
        self._arrays = device_graph(graph)     # ONE resident CSR upload
        t0 = time.perf_counter()
        self.stats = stats if stats is not None else compute_stats(
            graph, self.cfg)
        self.stats_seconds = time.perf_counter() - t0
        self._latencies: list[float] = []
        self._edges = None                     # lazy, for oracle verification
        self._oracle: dict[str, int] = {}      # canon_key -> oracle count

    # ------------------------------------------------------------- serving
    def submit(self, request: QueryRequest) -> QueryResult:
        t0 = time.perf_counter()
        entry, hit = self.cache.get_or_build(
            request.pattern, self.graph, self.stats,
            cfg=self.cfg, mesh=self.mesh, axis=self.axis,
            mode=request.mode, use_iep=request.use_iep,
            chunk=self.chunk, arrays=self._arrays,
        )
        out = entry.count(chunk=self.chunk)
        latency = time.perf_counter() - t0
        self._latencies.append(latency)

        expected = verified = None
        if request.verify:
            # oracle counts are isomorphism-invariant — memoize per class
            if entry.canon_key not in self._oracle:
                from ..core.oracle import count_embeddings_oracle

                if self._edges is None:
                    self._edges = self.graph.edge_array()
                self._oracle[entry.canon_key] = count_embeddings_oracle(
                    self.graph.n, self._edges, request.pattern)
            expected = self._oracle[entry.canon_key]
            verified = expected == out.count
        return QueryResult(
            pattern_name=request.pattern.name or "anon",
            canon_key=entry.canon_key,
            count=out.count,
            latency_s=latency,
            cache_hit=hit,
            mode=request.mode,
            use_iep=request.use_iep,
            order=entry.config.order,
            res_set=entry.plan.res_set,
            iep_k=entry.config.iep_k,
            search_seconds=0.0 if hit else entry.search_seconds,
            compile_seconds=0.0 if hit else entry.compile_seconds,
            overflowed=out.overflowed,
            max_needed=out.max_needed,
            expected=expected,
            verified=verified,
        )

    def serve(self, requests) -> list[QueryResult]:
        return [self.submit(r) for r in requests]

    def warm_from_disk(self) -> int:
        """Preload every persisted plan compatible with this engine's
        (graph, executor, layout) before the first request arrives, so a
        restarted replica serves warm from query one.  Returns the
        number of entries installed (0 without an attached store)."""
        return self.cache.preload(
            self.graph, self.stats, cfg=self.cfg, mesh=self.mesh,
            axis=self.axis, chunk=self.chunk, arrays=self._arrays)

    # ------------------------------------------------------------- reporting
    def reset_latencies(self) -> None:
        """Start a fresh latency window (e.g. between benchmark phases);
        cache state and counters are untouched."""
        self._latencies.clear()

    def latency_percentiles(self) -> dict:
        lat = np.asarray(self._latencies, dtype=float)
        if lat.size == 0:
            return {"n": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
        return {
            "n": int(lat.size),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "mean_ms": float(lat.mean() * 1e3),
        }

    def summary(self) -> dict:
        out = {
            "graph": self.graph.name,
            "devices": 1 if self.mesh is None else int(
                np.prod(list(self.mesh.shape.values()))),
            "stats_seconds": self.stats_seconds,
            "latency": self.latency_percentiles(),
            "cache": self.cache.stats.as_dict(),
            "cache_entries": len(self.cache),
        }
        if self.cache.store is not None:
            out["store"] = self.cache.store.stats.as_dict()
        return out
