"""Canonical labeling and stable hashing for query patterns.

Two isomorphic patterns submitted as queries must resolve to ONE plan
cache entry: embedding counts are isomorphism-invariant, and the
configuration search + JIT warmup are the expensive part of a cold
query, so identity must be decided structurally, not by label.

Canonical form = the vertex relabeling whose sorted edge tuple is
lexicographically minimal.  The search runs over label permutations
compatible with 1-WL color refinement (colors are rank-normalized, so
the cell structure and cell ORDER are isomorphism-invariant); within
that restriction minimality is still a complete invariant — equal
canonical edge tuples literally describe the same graph, so
key(G1) == key(G2)  ⟺  G1 ≅ G2.  Pattern sizes are tiny (n ≤ 8), and
refinement usually cuts the n! enumeration to a few hundred candidates;
the result is lru-cached per Pattern anyway.

The stable hash is sha256 over (n, canonical edges) — stable across
processes and Python hash randomization, safe to persist or ship
between serving replicas.

Vertex labels join the scheme as INITIAL 1-WL cells: labeled vertices
seed refinement with (label, degree) instead of degree alone, so cells
never mix labels and the canonical search only ranges over
label-preserving relabelings.  The key payload appends the canonical
label tuple, so a labeled pattern and its unlabeled skeleton — or two
different label assignments of one skeleton — can never collide on a
cache entry or store digest.  Unlabeled patterns take the exact
pre-label code path and keep their historical digests.
"""
from __future__ import annotations

import functools
import hashlib
import itertools
import math

import numpy as np

from ..core.pattern import Pattern, Perm


def _wl_cells(pattern: Pattern) -> list[tuple[int, ...]]:
    """1-WL color-refinement cells, ordered by (rank-normalized) color.

    Rank normalization — replacing each round's signature by its rank
    among the round's sorted distinct signatures — keeps colors
    isomorphism-invariant while staying cheap to compare."""
    n = pattern.n
    adj = pattern.adjacency()
    nbrs = [tuple(int(u) for u in np.nonzero(adj[v])[0]) for v in range(n)]
    if pattern.labels is None:
        colors = [len(nbrs[v]) for v in range(n)]
    else:
        # Labels seed the initial partition: cells never mix labels, and
        # ordering by actual label VALUE (wildcards first) keeps the cell
        # order invariant across label-isomorphic presentations.
        sigs0 = [
            ((-1 if pattern.labels[v] is None else pattern.labels[v]),
             len(nbrs[v]))
            for v in range(n)
        ]
        ranks0 = {s: i for i, s in enumerate(sorted(set(sigs0)))}
        colors = [ranks0[sigs0[v]] for v in range(n)]
    for _ in range(n):
        sigs = [
            (colors[v], tuple(sorted(colors[u] for u in nbrs[v])))
            for v in range(n)
        ]
        ranks = {s: i for i, s in enumerate(sorted(set(sigs)))}
        new = [ranks[sigs[v]] for v in range(n)]
        if new == colors:
            break
        colors = new
    return [
        tuple(v for v in range(n) if colors[v] == c)
        for c in sorted(set(colors))
    ]


# Candidate-permutation budget for the canonical search.  Pattern sizes
# are n <= 8 in this system (worst case 8! = 40320), but query_serve
# accepts arbitrary inline patterns — a large single-cell pattern (big
# cycle/clique) would degenerate to n! and hang the request stream, so
# refuse it up front instead.
_MAX_CANDIDATES = 1_000_000


@functools.lru_cache(maxsize=4096)
def _canonical_order(pattern: Pattern) -> Perm:
    """order[i] = original vertex placed at canonical position i."""
    cells = _wl_cells(pattern)
    n_candidates = 1
    for cell in cells:
        n_candidates *= math.factorial(len(cell))
    if n_candidates > _MAX_CANDIDATES:
        raise ValueError(
            f"pattern {pattern.name or 'anon'} (n={pattern.n}) needs "
            f"{n_candidates} candidate labelings to canonicalize "
            f"(budget {_MAX_CANDIDATES}); patterns this symmetric are "
            f"not servable"
        )
    best_key: tuple | None = None
    best: Perm | None = None
    for parts in itertools.product(
        *(itertools.permutations(cell) for cell in cells)
    ):
        order = tuple(v for part in parts for v in part)
        pos = {v: i for i, v in enumerate(order)}
        key = tuple(sorted(
            (min(pos[u], pos[v]), max(pos[u], pos[v]))
            for u, v in pattern.edges
        ))
        if best_key is None or key < best_key:
            best_key, best = key, order
    assert best is not None
    return best


def canonical_form(pattern: Pattern) -> Pattern:
    """The canonically relabeled pattern (name preserved for reporting)."""
    return pattern.relabel(_canonical_order(pattern))


def canonical_key(pattern: Pattern) -> str:
    """Stable hex digest identifying the pattern's (label-)isomorphism class.

    Labeled patterns append their canonical label tuple to the hashed
    payload ("*" marks a wildcard position); unlabeled patterns hash the
    historical (n, edges) payload unchanged, so every pre-label digest —
    and thus every v1 store record — stays valid.
    """
    form = canonical_form(pattern)
    payload = f"{form.n}|" + ";".join(f"{u},{v}" for u, v in form.edges)
    if form.labels is not None:
        payload += "|L:" + ",".join(
            "*" if lab is None else str(lab) for lab in form.labels
        )
    return hashlib.sha256(payload.encode()).hexdigest()


def relabeled_variant(pattern: Pattern, seed: int = 0) -> Pattern:
    """A random isomorphic variant (shuffled vertex labels).  Edge order
    and endpoint orientation are not varied because Pattern itself
    normalizes both at construction — relabeling is the only edge
    presentation a caller can actually observe.  Used by tests and the
    synthetic serving workloads to exercise cache hits on re-queries."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(pattern.n)
    edges = tuple((int(perm[u]), int(perm[v])) for u, v in pattern.edges)
    labels = None
    if pattern.labels is not None:
        out: list[int | None] = [None] * pattern.n
        for v, lab in enumerate(pattern.labels):
            out[int(perm[v])] = lab
        labels = tuple(out)
    return Pattern(pattern.n, edges,
                   name=f"{pattern.name or 'anon'}-iso{seed}",
                   labels=labels)
