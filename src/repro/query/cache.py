"""PlanCache — memoized compilation pipeline for pattern queries.

A cold pattern query pays three plan-time costs the batch CLI used to
re-pay on every invocation: the configuration search (schedules ×
restriction sets × IEP ranked by the perf model), the MatchingPlan
build, and the executor JIT.  The cache pays them once per *isomorphism
class* and replays the warmed matcher afterwards.

Cache key (DESIGN.md §5):
  (canonical pattern key,
   graph-stats fingerprint   — CSR content hash + (|V|, |E|, tri_cnt),
   executor fingerprint      — capacity, dynamic_base, resolved pallas
                               path, bucket layout, sharded?,
   mode, use_iep)
Anything that changes the searched configuration or the compiled
program invalidates the entry by construction; eviction beyond
`max_entries` is LRU, and evicted matchers are `release()`d so their
compiled executables and device arrays actually free HBM in long-lived
serving processes.

With a `PlanStore` attached (query/store.py) the cache becomes
load-through / write-behind: an in-memory miss first consults the
on-disk index — a persisted entry skips the configuration search
entirely and, when an AOT executable is present and accepted, skips
Python re-tracing too (`persist_hits` / `aot_loads` counters); a full
miss writes the searched result back after warmup (`export_fails`
counts AOT serialization failures — the entry still persists plan-only).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, replace as dc_replace

from ..core.config_search import (
    Configuration, graphzero_configuration, search_configuration,
)
from ..core.executor import (
    CountResult, ExecutorConfig, Matcher, ShardedMatcher,
)
from ..core.pattern import Pattern
from ..core.perf_model import GraphStats
from ..core.plan import MatchingPlan, build_plan
from ..graph.csr import GraphCSR
from ..obs import get_tracer, timer
from .canon import canonical_form, canonical_key

MODES = ("graphpi", "graphzero", "naive")

# Default LRU bound for serving engines: each entry pins a warmed jitted
# executable (plus stripe arrays when sharded), so an unbounded cache on
# an arbitrary request stream is a memory leak.  Evicted classes just
# pay cold cost again.
DEFAULT_MAX_ENTRIES = 256


def executor_fingerprint(cfg: ExecutorConfig) -> str:
    """The ExecutorConfig facets baked into a jitted count program, as
    the stable string `ExecutorConfig.fingerprint()` — safe to persist
    (the on-disk store digests the whole entry key)."""
    return cfg.fingerprint()


def layout_fingerprint(mesh, axis, chunk: int | None,
                       cfg: ExecutorConfig) -> tuple:
    """Execution-layout part of the cache key: the facets a compiled
    program bakes in beyond ExecutorConfig.  Sharded, that is the mesh
    devices, collective axis, and stripe chunk; single-device it is the
    outer-loop chunk width (the warmed trace's v0 shape).  `chunk` is
    resolved exactly like the matchers resolve it, so chunk=None and an
    explicitly-passed default don't alias into two entries for one
    identical program."""
    if mesh is None:
        return ("single", min(chunk or cfg.capacity, cfg.capacity))
    return (
        "sharded",
        axis if isinstance(axis, str) else tuple(axis),
        int(chunk or max(64, cfg.capacity // 16)),
        tuple((str(k), int(v)) for k, v in mesh.shape.items()),
        tuple(str(d) for d in mesh.devices.flat),
    )


def graph_fingerprint(graph: GraphCSR, stats: GraphStats) -> tuple:
    return (graph.fingerprint, stats.n_vertices, stats.n_edges,
            stats.tri_cnt)


@dataclass
class CacheEntry:
    canon_key: str
    pattern: Pattern            # canonical labeling (name = first requester)
    config: Configuration
    plan: MatchingPlan
    matcher: object             # warmed Matcher | ShardedMatcher
    sharded: bool
    mode: str
    search_seconds: float
    compile_seconds: float
    hits: int = 0
    executions: int = 0         # count() dispatches (coalescing evidence:
                                # N same-class tickets in one round → +1)

    def count(self, *, chunk: int | None = None) -> CountResult:
        """Execute the cached program.  `chunk` stripes the outer vertex
        loop on the single-device path (the sharded matcher fixed its
        stripe layout at build time)."""
        if self.sharded:
            out = self.matcher.count()
        else:
            out = self.matcher.count(chunk=chunk)
        return self._finish(out)

    def count_partial(self, state=None, *, chunk: int | None = None,
                      max_dispatches: int | None = None):
        """Preemptible execution: run up to `max_dispatches` kernel
        dispatches and return ``(state, result)`` — result None while
        work remains (pass state back in to resume; the completed count
        is bit-identical to :meth:`count`).  Sharded programs fix their
        stripe layout in one scanned dispatch, so they ignore the budget
        and always complete (state stays None)."""
        if self.sharded:
            return None, self._finish(self.matcher.count())
        state, out = self.matcher.count_partial(
            state, chunk=chunk, max_dispatches=max_dispatches)
        return state, (None if out is None else self._finish(out))

    def _finish(self, out: CountResult) -> CountResult:
        if self.mode == "naive":
            # no restrictions compiled in: every embedding found |Aut| times
            out = dc_replace(out, count=out.count // self.pattern.aut_count())
        return out


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0              # in-memory misses (incl. persist hits)
    n_searches: int = 0          # configuration searches actually run
    n_compiles: int = 0          # fresh JIT traces (warmup compiles)
    evictions: int = 0
    persist_hits: int = 0        # misses served from the on-disk store
    preloads: int = 0            # entries installed by warm-from-disk
    aot_loads: int = 0           # store loads whose AOT executable loaded
    aot_load_fails: int = 0      # AOT blob rejected -> re-JIT fallback
    export_fails: int = 0        # write-behind AOT export failures
    search_seconds: float = 0.0
    compile_seconds: float = 0.0
    aot_load_seconds: float = 0.0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class PlanCache:
    """LRU cache of warmed (Configuration, MatchingPlan, Matcher) triples,
    optionally backed by a persistent on-disk `PlanStore`."""

    def __init__(self, *, max_entries: int | None = None, store=None):
        self.max_entries = max_entries
        self.store = store                    # PlanStore | None
        self.stats = CacheStats()
        self._entries: OrderedDict[tuple, CacheEntry] = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def entries(self) -> list[CacheEntry]:
        return list(self._entries.values())

    @staticmethod
    def entry_key(pattern: Pattern, graph_fp: tuple, cfg: ExecutorConfig,
                  *, mode: str = "graphpi", use_iep: bool = False,
                  layout_fp: tuple | None = None) -> tuple:
        if layout_fp is None:
            layout_fp = layout_fingerprint(None, "data", None, cfg)
        # naive ignores use_iep (it always searches without IEP), so the
        # flag must not split one compiled program into two entries
        use_iep = bool(use_iep) and mode != "naive"
        return (canonical_key(pattern), graph_fp,
                executor_fingerprint(cfg), mode, use_iep, layout_fp)

    def get_or_build(
        self,
        pattern: Pattern,
        graph: GraphCSR,
        stats: GraphStats,
        *,
        cfg: ExecutorConfig | None = None,
        mesh=None,
        axis: str = "data",
        mode: str = "graphpi",
        use_iep: bool = False,
        chunk: int | None = None,
        arrays=None,
        warm: bool = True,
        graph_fp: tuple | None = None,
    ) -> tuple[CacheEntry, bool]:
        """Return (entry, was_hit).  Misses run the configuration search,
        build the plan, and (when `warm`) JIT-compile the matcher before
        the entry becomes visible — a hit NEVER searches or compiles.

        `graph_fp` overrides the graph facet of the entry key: live
        engines pass their `EpochStamp.plan_key` (stable across edge
        mutations) so plans and AOT executables survive churn; when
        omitted the legacy content-fingerprint tuple is derived here."""
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; have {MODES}")
        cfg = cfg or ExecutorConfig()
        key = self.entry_key(
            pattern,
            graph_fp if graph_fp is not None
            else graph_fingerprint(graph, stats),
            cfg, mode=mode, use_iep=use_iep,
            layout_fp=layout_fingerprint(mesh, axis, chunk, cfg),
        )
        entry = self._entries.get(key)
        if entry is not None:
            self.stats.hits += 1
            entry.hits += 1
            self._entries.move_to_end(key)
            return entry, True

        self.stats.misses += 1
        # load-through: a persisted entry skips the configuration search
        # (and, when its AOT executable is accepted, the JIT trace too)
        if self.store is not None:
            rec = self.store.load(key)
            if rec is not None:
                self.stats.persist_hits += 1
                entry = self._install_record(
                    rec, key, graph, cfg=cfg, mesh=mesh, axis=axis,
                    chunk=chunk, arrays=arrays, warm=warm)
                self._insert(key, entry)
                return entry, False

        canon = canonical_form(pattern)
        with get_tracer().span("cache.search", canon_key=key[0],
                               mode=mode), timer() as t:
            if mode == "graphpi":
                config = search_configuration(
                    canon, stats, use_iep=use_iep).best
            elif mode == "graphzero":
                config = graphzero_configuration(
                    canon, stats, use_iep=use_iep)
            else:  # naive: no restrictions; entry.count divides by |Aut|
                config = search_configuration(canon, stats,
                                              use_iep=False).best
        search_s = t.seconds
        self.stats.n_searches += 1
        self.stats.search_seconds += search_s

        res_set = () if mode == "naive" else config.res_set
        plan = build_plan(canon, config.order, res_set, iep_k=config.iep_k)
        if mesh is not None:
            matcher = ShardedMatcher(graph, plan, mesh, axis=axis, cfg=cfg,
                                     chunk=chunk, arrays=arrays)
        else:
            matcher = Matcher(graph, plan, cfg, arrays=arrays)
        compile_s = 0.0
        exec_bytes = None
        if warm:
            with get_tracer().span("cache.compile", canon_key=key[0],
                                   mode=mode), timer() as t:
                if mesh is None and self.store is not None:
                    # AOT export BEFORE warmup: export traces/lowers the
                    # program once and install makes warmup compile that
                    # exact lowering — one trace total instead of
                    # trace-compile-retrace, and local serving runs the
                    # same bytes a restarted replica will load
                    try:
                        exec_bytes = matcher.export_bytes(chunk=chunk)
                        matcher.install_exported(exec_bytes, chunk=chunk)
                    except Exception:
                        self.stats.export_fails += 1
                        exec_bytes = None
                if mesh is not None:
                    matcher.warmup()      # chunk is baked into the stripes
                else:
                    matcher.warmup(chunk=chunk)
            compile_s = t.seconds
            self.stats.n_compiles += 1
            self.stats.compile_seconds += compile_s

        entry = CacheEntry(
            canon_key=key[0], pattern=canon, config=config, plan=plan,
            matcher=matcher, sharded=mesh is not None, mode=mode,
            search_seconds=search_s, compile_seconds=compile_s,
        )
        # write-behind: persist the searched result (+ the AOT executable
        # exported above on the single-device path; sharded programs bake
        # in mesh/device state, so they persist plan-only and re-JIT on
        # restart)
        if self.store is not None:
            self.store.save(
                key, pattern=canon, config=config, plan=plan,
                exec_bytes=exec_bytes, search_seconds=search_s,
                compile_seconds=compile_s)
        self._insert(key, entry)
        return entry, False

    # -------------------------------------------------------- persistence
    def _install_record(self, rec, key: tuple, graph: GraphCSR, *,
                        cfg: ExecutorConfig, mesh, axis: str,
                        chunk: int | None, arrays, warm: bool) -> CacheEntry:
        """Turn a loaded StoreRecord into a live warmed entry — no
        configuration search; no JIT trace either when the record's AOT
        executable installs cleanly (else fall back to a fresh warmup)."""
        if mesh is not None:
            matcher = ShardedMatcher(graph, rec.plan, mesh, axis=axis,
                                     cfg=cfg, chunk=chunk, arrays=arrays)
        else:
            matcher = Matcher(graph, rec.plan, cfg, arrays=arrays)
        compile_s = 0.0
        if warm:
            installed = False
            if rec.exec_bytes is not None and mesh is None:
                try:
                    matcher.install_exported(rec.exec_bytes, chunk=chunk)
                    installed = True
                except Exception:
                    self.stats.aot_load_fails += 1
            with get_tracer().span("cache.warm", canon_key=key[0],
                                   aot=installed), timer() as t:
                if mesh is not None:
                    matcher.warmup()
                else:
                    matcher.warmup(chunk=chunk)
            dt = t.seconds
            if installed:
                self.stats.aot_loads += 1
                self.stats.aot_load_seconds += dt
            else:
                compile_s = dt
                self.stats.n_compiles += 1
                self.stats.compile_seconds += dt
        return CacheEntry(
            canon_key=key[0], pattern=rec.pattern, config=rec.config,
            plan=rec.plan, matcher=matcher, sharded=mesh is not None,
            mode=rec.mode, search_seconds=0.0, compile_seconds=compile_s,
        )

    def preload(self, graph: GraphCSR, stats: GraphStats, *,
                cfg: ExecutorConfig | None = None, mesh=None,
                axis: str = "data", chunk: int | None = None,
                arrays=None, warm: bool = True,
                graph_fp: tuple | None = None) -> int:
        """Warm-from-disk: install every store record compatible with the
        current serving context (same graph/executor/layout fingerprints
        — checked by re-deriving each record's key digest) before the
        first request arrives.  Returns the number of entries installed.
        `graph_fp` as in :meth:`get_or_build` (live epoch plan keys)."""
        if self.store is None:
            return 0
        from .store import key_digest

        cfg = cfg or ExecutorConfig()
        gfp = (graph_fp if graph_fp is not None
               else graph_fingerprint(graph, stats))
        lfp = layout_fingerprint(mesh, axis, chunk, cfg)
        installed = 0
        for rec in self.store.records():
            key = self.entry_key(rec.pattern, gfp, cfg, mode=rec.mode,
                                 use_iep=rec.use_iep, layout_fp=lfp)
            if key_digest(key) != rec.digest or key in self._entries:
                continue
            self.stats.preloads += 1
            self._insert(key, self._install_record(
                rec, key, graph, cfg=cfg, mesh=mesh, axis=axis,
                chunk=chunk, arrays=arrays, warm=warm))
            installed += 1
        return installed

    # ------------------------------------------------------------ eviction
    def _insert(self, key: tuple, entry: CacheEntry) -> None:
        self._entries[key] = entry
        if self.max_entries is not None:
            while len(self._entries) > self.max_entries:
                _, evicted = self._entries.popitem(last=False)
                # explicitly drop the warmed matcher's compiled
                # executables + device-array references: entries in a
                # long-lived serving process must release HBM on
                # eviction, not whenever GC gets around to the cycle.
                # (max_entries=0 pops `entry` itself — the caller is
                # about to count on it, so it must stay live.)
                if evicted is not entry:
                    evicted.matcher.release()
                self.stats.evictions += 1
