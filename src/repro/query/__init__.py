# Pattern-query serving subsystem (DESIGN.md §5): canonical pattern
# identity (canon), plan/matcher memoization (cache), and the batched
# request engine over a resident graph (engine).
from .canon import canonical_form, canonical_key, relabeled_variant
from .cache import CacheEntry, PlanCache
from .engine import QueryEngine, QueryRequest, QueryResult

__all__ = [
    "CacheEntry",
    "PlanCache",
    "QueryEngine",
    "QueryRequest",
    "QueryResult",
    "canonical_form",
    "canonical_key",
    "relabeled_variant",
]
