# Pattern-query serving subsystem (DESIGN.md §5): canonical pattern
# identity (canon), plan/matcher memoization (cache), the persistent
# on-disk plan index + AOT executables (store), and the batched request
# engine over a resident graph (engine).
from .canon import canonical_form, canonical_key, relabeled_variant
from .cache import CacheEntry, PlanCache
from .engine import (
    AdmissionRejected, PlannedQuery, QueryEngine, QueryRequest, QueryResult,
    Rejection, Ticket,
)
from .store import PlanStore, StoreRecord

__all__ = [
    "AdmissionRejected",
    "CacheEntry",
    "PlanCache",
    "PlanStore",
    "PlannedQuery",
    "QueryEngine",
    "QueryRequest",
    "QueryResult",
    "Rejection",
    "StoreRecord",
    "Ticket",
    "canonical_form",
    "canonical_key",
    "relabeled_variant",
]
