"""Versioned delta overlay over a resident padded CSR.

`DeltaOverlay` keeps the immutable base `GraphCSR` device layout intact
and materializes mutations as PATCHED ROWS in a reserved region of the
flat neighbor array:

    indices = [ base flat (nnz) | patch region | window sentinel pad ]
               ^ clean rows      ^ dirty rows    ^ gather overhang

A vertex whose neighborhood changed gets its fully-merged, sorted row
written into the patch region and its row start (`indptr[v]`) repointed
there; untouched rows keep their base offsets.  The executor reads every
row as ``[indptr[v], indptr[v] + degrees[v])`` — gather windows, the
vectorized binary-search membership test, and the fused kernel's per-row
DMAs all consume (start, len) pairs — so counts over `base ⊕ delta` are
exact on both the portable and fused paths without rebuilding the CSR,
and the two stay bit-identical for free.

Shape stability is the load-bearing invariant: `flat_capacity` (and the
gather `window`) are FIXED at construction, so every epoch's device
arrays have identical shapes and a mutation swap is `Matcher.rebind` —
zero re-searches, zero recompiles.  Compaction folds the delta into a
fresh base CSR laid out in the same fixed-capacity flat array, so even
the compacted swap replays the compiled programs; only genuine growth
(a merged row outrunning the window, or the patch region filling up)
forces new shapes, and that path compacts + rebuilds honestly.

Two deltas are tracked: the *current-base* delta (drives the view; reset
by compaction) and the *cumulative* delta vs the epoch-0 base (drives
`edge_key`, the content digest count memos key on — compaction leaves it
untouched, so memoized counts survive compaction).
"""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from ..graph.csr import GraphCSR, GraphView
from .epoch import edge_delta_digest

# Keep enough mutation batches to answer "what changed since the memo's
# epoch" for any plausibly-live memo; older memos fall back to a full
# recount (correct, just not incremental).
_MUTATION_LOG_LIMIT = 128

MUTATION_VERBS = ("insert_edges", "delete_edges", "compact")


class OverlayOverflow(RuntimeError):
    """A merged row outgrew the gather window, or the patch region is
    full.  `apply` handles this internally by compacting (growing the
    fixed shapes when it must); seeing it escape means a bug."""


def _normalize_edges(n: int, edges) -> list[tuple[int, int]]:
    out = []
    for e in edges:
        u, v = int(e[0]), int(e[1])
        if u == v:
            raise ValueError(f"self-loop ({u},{u}) is not a valid edge")
        if not (0 <= u < n and 0 <= v < n):
            raise ValueError(
                f"edge ({u},{v}) outside vertex range [0, {n}) — the live "
                "subsystem mutates edges over a fixed vertex set")
        out.append((min(u, v), max(u, v)))
    return out


class DeltaOverlay:
    """A live (mutable) graph: base CSR + versioned edge delta.

    The engine constructs one per resident graph, reads `.view` for the
    executor-facing adjacency, and calls `.apply()` only at round
    boundaries (query/engine.py) so no in-flight `CountState` ever
    straddles an epoch.
    """

    def __init__(self, base: GraphCSR, *, window_headroom: int = 8,
                 patch_capacity: int | None = None):
        if base.labels is not None:
            raise NotImplementedError(
                "live overlays cover unlabeled graphs; labeled mutation "
                "needs per-label segment patching (future work)")
        self.base0 = base
        self.base = base
        self.n = base.n
        self.name = base.name
        self.window_headroom = int(window_headroom)
        # Static gather width for every epoch: base max degree plus
        # headroom for rows that grow under inserts.
        self.window = max(base.max_degree, 1) + self.window_headroom
        nnz0 = int(base.indptr[-1])
        self.patch_capacity = (int(patch_capacity) if patch_capacity
                               else max(4 * self.window, 256))
        self.flat_capacity = nnz0 + self.patch_capacity + self.window
        # base0.fingerprint is a cached_property: hash once here, O(1)
        # reads forever after (satellite: no per-round re-hashing).
        self.base0_fingerprint = base.fingerprint
        # Current-base delta (view); cumulative delta vs base0 (edge_key).
        self.inserts: set[tuple[int, int]] = set()
        self.deletes: set[tuple[int, int]] = set()
        self._ins0: set[tuple[int, int]] = set()
        self._del0: set[tuple[int, int]] = set()
        self.edge_epoch = 0
        self.stats_epoch = 0
        self.compactions = 0
        self.resizes = 0
        # (edge_key before batch, edge_key after batch, touched vertices)
        self.mutation_log: list[tuple[str, str, frozenset[int]]] = []
        self._edge_key_cache: dict[int, str] = {}
        self._edge_key_computes = 0     # memoization evidence for tests
        self._view: GraphView | None = None

    # ------------------------------------------------------------ keys
    @property
    def edge_key(self) -> str:
        """Content digest of base ⊕ delta, memoized per edge epoch so
        per-round identity checks are O(1) (recomputed only when a
        mutation actually lands; compaction reuses the memo because the
        cumulative delta — hence the content — is unchanged)."""
        key = self._edge_key_cache.get(self.edge_epoch)
        if key is None:
            self._edge_key_computes += 1
            key = edge_delta_digest(self.base0_fingerprint,
                                    self._ins0, self._del0)
            self._edge_key_cache = {self.edge_epoch: key}
        return key

    def overlay_edges(self) -> int:
        """Current-base delta size (what compaction thresholds watch)."""
        return len(self.inserts) + len(self.deletes)

    def dirty_vertices(self) -> set[int]:
        out: set[int] = set()
        for u, v in self.inserts:
            out.add(u); out.add(v)
        for u, v in self.deletes:
            out.add(u); out.add(v)
        return out

    # ------------------------------------------------------------ mutate
    def apply(self, verb: str, edges=None) -> int:
        """Apply one mutation batch; returns the number of EFFECTIVE
        edge changes (no-ops — inserting a present edge, deleting an
        absent one — don't bump the epoch).  Always succeeds: overflow
        of the fixed patch/window triggers an internal compaction (and,
        if the graph genuinely outgrew its shapes, a resize)."""
        if verb == "compact":
            self.compact()
            return 0
        if verb not in ("insert_edges", "delete_edges"):
            raise ValueError(
                f"unknown mutation verb {verb!r}; expected one of "
                f"{MUTATION_VERBS}")
        pairs = _normalize_edges(self.n, edges or ())
        prev_key = self.edge_key
        touched: set[int] = set()
        changed = 0
        for uv in pairs:
            if verb == "insert_edges":
                if uv in self.deletes:
                    self.deletes.discard(uv)
                elif uv not in self.inserts and not self.base.has_edge(*uv):
                    self.inserts.add(uv)
                else:
                    continue
                # cumulative mirror vs base0
                if uv in self._del0:
                    self._del0.discard(uv)
                elif not self.base0.has_edge(*uv):
                    self._ins0.add(uv)
            else:
                if uv in self.inserts:
                    self.inserts.discard(uv)
                elif uv not in self.deletes and self.base.has_edge(*uv):
                    self.deletes.add(uv)
                else:
                    continue
                if uv in self._ins0:
                    self._ins0.discard(uv)
                elif self.base0.has_edge(*uv):
                    self._del0.add(uv)
            changed += 1
            touched.add(uv[0]); touched.add(uv[1])
        if not changed:
            return 0
        self.edge_epoch += 1
        self._view = None
        self.mutation_log.append((prev_key, self.edge_key,
                                  frozenset(touched)))
        del self.mutation_log[:-_MUTATION_LOG_LIMIT]
        try:
            self._view = self._build_view()
        except OverlayOverflow:
            self.compact()
        return changed

    def compact(self) -> None:
        """Fold the current delta into a fresh base CSR.  Content (hence
        `edge_key` and every count memo) is unchanged; the resident
        arrays are relaid.  Fixed shapes are kept whenever the new base
        fits, so the post-compaction swap is still rebind-only."""
        new_base = GraphCSR.from_edges(self.n, self.materialize_edges(),
                                       name=self.name)
        self.base = new_base
        self.inserts.clear()
        self.deletes.clear()
        self.compactions += 1
        self._view = None
        grew = False
        if new_base.max_degree > self.window:
            self.window = new_base.max_degree + self.window_headroom
            grew = True
        nnz = int(new_base.indptr[-1])
        # Keep the fixed flat_capacity whenever the relaid base still
        # leaves room for at least one window-wide patch row — the view
        # bounds its patch region by (flat_capacity - window), not by
        # patch_capacity, so the post-compaction swap stays rebind-only.
        # Only genuine growth (patch squeezed below one row) re-lays out
        # to the full patch budget and pays the matcher rebuild.
        if nnz + 2 * self.window > self.flat_capacity:
            self.flat_capacity = nnz + self.patch_capacity + self.window
            grew = True
        if grew:
            self.resizes += 1

    def materialize_edges(self) -> np.ndarray:
        """Undirected [E, 2] edge array of base ⊕ current delta (u < v),
        built directly from the base CSR + delta sets (valid even when
        the patched view itself cannot be built for want of space)."""
        base = self.base
        nnz = int(base.indptr[-1])
        dst = base.indices[:nnz].astype(np.int64)
        src = np.repeat(np.arange(self.n, dtype=np.int64), base.degrees)
        fwd = dst > src
        keys = src[fwd] * self.n + dst[fwd]
        if self.deletes:
            drop = np.asarray(
                [u * self.n + v for u, v in self.deletes], dtype=np.int64)
            keys = keys[~np.isin(keys, drop)]
        if self.inserts:
            keys = np.concatenate([keys, np.asarray(
                [u * self.n + v for u, v in self.inserts], dtype=np.int64)])
        return np.stack([keys // self.n, keys % self.n], axis=1)

    # ------------------------------------------------------------ view
    @property
    def view(self) -> GraphView:
        if self._view is None:
            self._view = self._build_view()
        return self._view

    def _build_view(self) -> GraphView:
        base = self.base
        nnz = int(base.indptr[-1])
        flat = np.full(self.flat_capacity, self.n, dtype=np.int32)
        flat[:nnz] = base.indices[:nnz]
        # Row starts: [n+1] so the executor's valid-row test
        # (v0 < indptr.shape[0]-1) and sentinel-row indexing still work;
        # the final entry is a degree-0 row parked at nnz.
        starts = np.empty(self.n + 1, dtype=np.int32)
        starts[:-1] = base.indptr[:-1]
        starts[-1] = nnz
        degrees = base.degrees.copy()
        ins_p: dict[int, list[int]] = defaultdict(list)
        del_p: dict[int, set[int]] = defaultdict(set)
        for u, v in self.inserts:
            ins_p[u].append(v)
            ins_p[v].append(u)
        for u, v in self.deletes:
            del_p[u].add(v)
            del_p[v].add(u)
        off = nnz
        patch_end = self.flat_capacity - self.window
        for v in sorted(set(ins_p) | set(del_p)):
            row = sorted((set(base.neighbors(v).tolist()) - del_p[v])
                         | set(ins_p[v]))
            if len(row) > self.window:
                raise OverlayOverflow(
                    f"row {v} merged to {len(row)} > window {self.window}")
            if off + len(row) > patch_end:
                raise OverlayOverflow(
                    f"patch region full at vertex {v} "
                    f"(off={off}, patch_end={patch_end})")
            flat[off:off + len(row)] = np.asarray(row, dtype=np.int32)
            starts[v] = off
            degrees[v] = len(row)
            off += len(row)
        m = int(degrees.sum()) // 2
        return GraphView(n=self.n, m=m, indptr=starts, indices=flat,
                         degrees=degrees, window=self.window,
                         fingerprint=self.edge_key, name=self.name)

    # ------------------------------------------------------- maintenance
    def dirty_roots_since(self, edge_key: str, depth: int):
        """Vertices whose depth-`depth` pattern embeddings may have
        changed since the epoch identified by `edge_key`; None when the
        epoch is unknown (log evicted / different lineage) and the
        caller must fall back to a full recount.

        Roots = all vertices touched by mutation batches since that
        epoch, expanded `depth - 1` hops over the CURRENT adjacency.
        BFS over the current graph suffices: any edge on a path that
        existed at some epoch in the window but not now was deleted
        inside the window, so its endpoints are themselves touched —
        walking back from the last touched vertex on any old-epoch path
        leaves a suffix of current edges of length ≤ depth - 1.
        """
        if edge_key == self.edge_key:
            return set()
        touched: set[int] = set()
        found = False
        for prev_key, _new_key, tv in reversed(self.mutation_log):
            touched |= tv
            if prev_key == edge_key:
                found = True
                break
        if not found:
            return None
        view = self.view
        seen = set(touched)
        frontier = touched
        for _ in range(max(int(depth) - 1, 0)):
            nxt: set[int] = set()
            for v in frontier:
                for u in view.neighbors(v).tolist():
                    if u not in seen:
                        seen.add(u)
                        nxt.add(u)
            if not nxt:
                break
            frontier = nxt
        return seen

    # ------------------------------------------------------- persistence
    def to_record(self) -> dict:
        """Overlay store record (query/store.py `live-<base0 fp>.json`):
        the cumulative delta vs base0, enough to rehydrate this epoch's
        edge content next to the plans it shares a store with."""
        return {
            "base_fingerprint": self.base0_fingerprint,
            "edge_epoch": int(self.edge_epoch),
            "stats_epoch": int(self.stats_epoch),
            "compactions": int(self.compactions),
            "inserts": sorted([int(u), int(v)] for u, v in self._ins0),
            "deletes": sorted([int(u), int(v)] for u, v in self._del0),
        }

    @staticmethod
    def from_record(base: GraphCSR, record: dict, **kwargs) -> "DeltaOverlay":
        """Rehydrate an overlay onto its epoch-0 base from a store
        record.  Edge content (and hence `edge_key`) matches the saved
        epoch; epoch COUNTERS restart from the replayed batches."""
        live = DeltaOverlay(base, **kwargs)
        if record.get("base_fingerprint") != live.base0_fingerprint:
            raise ValueError("overlay record does not match this base graph")
        live.apply("insert_edges", record.get("inserts", []))
        live.apply("delete_edges", record.get("deletes", []))
        live.stats_epoch = int(record.get("stats_epoch", 0))
        return live
