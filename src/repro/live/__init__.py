"""Live-graph subsystem: serve pattern counts while the graph mutates.

    overlay.py     DeltaOverlay — versioned insert/delete buffers merged
                   as patched rows beside the padded CSR; fixed shapes
                   so epoch swaps are rebind-only (no recompiles).
    epoch.py       EpochStamp — two-level cache keys: plans/AOT key on
                   the stats epoch (survive mutations), memoized counts
                   key on the edge epoch (invalidate precisely).
    compaction.py  when to fold the overlay into a fresh CSR and when
                   stats drift warrants a plan re-search.
    maintain.py    CountMaintainer — per-span raw memos + dirty-root
                   incremental recount with full-recount break-even.

The engine (query/engine.py) owns the round-boundary discipline: queued
mutations apply between rounds, never under an in-flight CountState.
"""
from .compaction import (CompactionPolicy, maybe_compact, overlay_budget,
                         should_compact, stats_drifted)
from .epoch import EpochStamp, edge_delta_digest
from .maintain import CountMaintainer, MaintState
from .overlay import MUTATION_VERBS, DeltaOverlay, OverlayOverflow

__all__ = [
    "CompactionPolicy", "CountMaintainer", "DeltaOverlay", "EpochStamp",
    "MaintState", "MUTATION_VERBS", "OverlayOverflow", "edge_delta_digest",
    "maybe_compact", "overlay_budget", "should_compact", "stats_drifted",
]
