"""Epoch fingerprints — the two-level cache key for mutable graphs.

The immutable stack keys EVERYTHING on `GraphCSR.fingerprint`: one edge
insert moves the content hash, so every plan, AOT executable, and
memoized count dies together.  But those artifacts depend on different
facets of the graph:

  * plans / AOT executables depend on the graph *statistics* the
    configuration search consumed (|V|, |E|, triangle count feed the
    perf model) and on array SHAPES — not on exact edge content.  A
    handful of edge flips leaves the searched configuration and the
    compiled program exactly as valid as before.
  * memoized counts depend on exact edge content: any single flip can
    change a count.

`EpochStamp` splits the key accordingly:

  plan_key  — what `PlanCache`/`PlanStore` entries key on.  Live graphs
              use ("live", base fingerprint, stats_epoch): stable across
              edge mutations AND compactions, bumped only when the
              serving layer decides the stats drifted far enough that
              re-searching plans is worth it.  Non-live engines keep the
              legacy (content fingerprint, |V|, |E|, tri) tuple —
              byte-compatible with every persisted store.
  edge_key  — what memoized counts key on: a content digest of
              (epoch-0 base fingerprint, cumulative inserts, cumulative
              deletes).  It is *content-stable*: two mutation paths that
              reach the same edge set produce the same key, and a
              compaction (which changes the resident arrays but not the
              edge set) leaves it untouched — so count memos survive
              compaction and invalidate on exactly the mutations that
              can change a count.

Stamps are frozen value objects.  Serving code (serve/, query/) holds
THESE across round boundaries, never raw `fingerprint()` results — the
`no-stale-fingerprint` lint rule (analysis/lint.py) enforces it.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass


def edge_delta_digest(base_fingerprint: str, inserts, deletes) -> str:
    """Content digest of `base ⊕ delta` without materializing the edge
    set: sha256 over the epoch-0 base fingerprint plus the SORTED
    cumulative insert/delete lists (normalized u < v pairs).  O(|delta|
    log |delta|) per call — the LiveGraph memoizes it per edge epoch so
    per-round checks are O(1)."""
    h = hashlib.sha256()
    h.update(base_fingerprint.encode())
    for tag, edges in (("+", inserts), ("-", deletes)):
        h.update(tag.encode())
        for u, v in sorted(edges):
            h.update(f"{u},{v};".encode())
    return h.hexdigest()


@dataclass(frozen=True)
class EpochStamp:
    """One round's view identity: carry THIS across rounds, not a raw
    fingerprint.  `plan_key` slots into the PlanCache entry key where
    the legacy graph fingerprint tuple went; `edge_key` keys count
    memos (live/maintain.py)."""

    stats_epoch: int
    edge_epoch: int
    plan_key: tuple
    edge_key: str

    @staticmethod
    def legacy(graph, stats) -> "EpochStamp":
        """Immutable-graph stamp: plan_key is byte-identical to the
        historical `query.cache.graph_fingerprint` tuple, so persisted
        plan stores keep warm-loading across this refactor."""
        return EpochStamp(
            stats_epoch=0,
            edge_epoch=0,
            plan_key=(graph.fingerprint, stats.n_vertices, stats.n_edges,
                      stats.tri_cnt),
            edge_key=graph.fingerprint,
        )

    @staticmethod
    def for_live(live, stats) -> "EpochStamp":
        """Mutable-graph stamp for the current epoch of a `LiveGraph`.
        plan_key survives mutations and compactions (until the live
        graph bumps its stats epoch); edge_key moves with every
        effective mutation and ONLY with effective mutations."""
        return EpochStamp(
            stats_epoch=live.stats_epoch,
            edge_epoch=live.edge_epoch,
            plan_key=("live", live.base0_fingerprint, live.stats_epoch,
                      stats.n_vertices, stats.n_edges, stats.tri_cnt),
            edge_key=live.edge_key,
        )
