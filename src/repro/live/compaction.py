"""Compaction policy: when to fold the delta overlay back into a fresh
padded CSR, and when graph statistics have drifted far enough that the
plan search itself should rerun.

Read-path cost of the overlay is ~zero for clean rows (same gather, same
membership test) and one extra merged row per dirty vertex, so the
trigger is overlay SIZE, not read amplification: past a threshold the
patch region risks overflow and the per-mutation view rebuild (O(dirty ·
window)) starts to rival a full relayout.  `overlay_budget` turns the
graph's stats into that threshold — a crude perf-model stand-in with the
same shape as core/perf_model.py's cost accounting: compaction costs one
O(m) relayout, the overlay costs O(delta) per batch, so budget scales
with m and breaks even around m/8.

Compaction itself lives on `DeltaOverlay.compact()` (it must also run on
overflow, policy or not); the engine calls `maybe_compact` between
rounds so the swap is atomic w.r.t. in-flight counts — paired with
`stats_drifted`, which bumps the stats epoch (new plan_key → fresh
config search) when |E| has moved materially from what the searched
configurations assumed.
"""
from __future__ import annotations

from dataclasses import dataclass


def overlay_budget(n_edges: int) -> int:
    """Overlay size past which compaction beats carrying the delta."""
    return max(256, int(n_edges) // 8)


@dataclass(frozen=True)
class CompactionPolicy:
    max_overlay_edges: int = 4096       # hard cap, any graph size
    max_overlay_fraction: float = 0.25  # delta / base edges
    stats_drift: float = 0.5            # relative |E| drift → re-search
    use_model: bool = True              # also apply overlay_budget(m)


def should_compact(live, policy: CompactionPolicy) -> str | None:
    """Reason to compact now, or None."""
    delta = live.overlay_edges()
    if not delta:
        return None
    if delta >= policy.max_overlay_edges:
        return f"overlay {delta} >= cap {policy.max_overlay_edges}"
    base_m = max(live.base.m, 1)
    if delta / base_m >= policy.max_overlay_fraction:
        return f"overlay {delta} >= {policy.max_overlay_fraction:.0%} of base"
    if policy.use_model and delta >= overlay_budget(base_m):
        return f"overlay {delta} >= model budget {overlay_budget(base_m)}"
    return None


def stats_drifted(live, stats, policy: CompactionPolicy) -> bool:
    """Has |E| moved far enough from the stats the plan search used that
    searched configurations (perf-model ranked on |V|, |E|, tri) are
    stale?  Plans stay VALID either way — this gates re-SEARCH."""
    assumed = max(int(stats.n_edges), 1)
    return abs(live.view.m - assumed) / assumed > policy.stats_drift


def maybe_compact(live, policy: CompactionPolicy) -> str | None:
    """Engine hook: compact if the policy says so; returns the reason
    when a compaction ran."""
    reason = should_compact(live, policy)
    if reason is not None:
        live.compact()
    return reason
