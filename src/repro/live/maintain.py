"""Incremental count maintenance over a delta overlay.

Pattern counts decompose over the engine's fixed root-vertex grid: the
raw embedding total is a sum of per-span raws (the same spans
`Matcher.count_partial` walks), and a mutation can only change the raw
of a span containing a vertex within `depth - 1` hops of a touched
vertex (every embedding is connected and rooted at its span's v0).  So
the maintainer memoizes per-span raw totals keyed on the overlay's
`edge_key` and, after a mutation, re-expands ONLY the spans holding
dirty roots — provably the full set of spans whose raw can have moved —
splicing the rest from the memo.  When the dirty spans exceed a
break-even fraction of the grid it falls back to a full recount (the
incremental walk would do most of the work anyway and the memo
bookkeeping is pure overhead).

Division order is preserved exactly: per-span RAWS are summed, then the
plan's IEP divisor and (naive mode) |Aut| divide ONCE at the end —
mirroring `Matcher.count_partial` + `CacheEntry._finish` — so the
maintained count is bit-identical to an uninterrupted fresh count.

The maintainer sits between the engine's group loop and the cache
entry: `count_partial(key, entry, state, ...)` has the same
(state, result) preemption contract as `CacheEntry.count_partial`, and
`MaintState.dispatches` feeds the engine's quantum accounting the same
way `CountState.dispatches` does.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..core.executor import CountResult, CountState

DEFAULT_BREAK_EVEN = 0.5


@dataclass
class _Memo:
    edge_key: str                  # overlay content at memoization time
    chunk: int | None              # grid the span totals decompose over
    span_totals: dict | None       # span start -> raw total (None: sharded)
    result: CountResult
    max_needed: int = 0


@dataclass
class MaintState:
    """Resumable incremental/full recount: pending grid spans plus the
    raw per-span totals gathered so far (carried-from-memo + fresh)."""

    spans: list                    # [(start, end)] pending, LIFO
    chunk: int
    edge_key: str                  # epoch this recount is FOR
    span_totals: dict = field(default_factory=dict)
    inner: CountState | None = None   # span in progress (budget cut)
    inner_span: tuple | None = None
    dispatches: int = 0            # engine quantum accounting
    overflowed: bool = False
    max_needed: int = 0


class CountMaintainer:
    """Per-engine memo of counts (and their per-span raws) keyed on the
    group key + overlay edge_key, with dirty-root incremental refresh."""

    def __init__(self, live, *, break_even: float = DEFAULT_BREAK_EVEN):
        self.live = live
        self.break_even = float(break_even)
        self._memos: dict = {}        # engine group key -> _Memo
        self.memo_hits = 0            # served straight from memo
        self.incremental_hits = 0     # dirty-span refresh chosen
        self.full_recounts = 0        # stale memo, full refresh chosen
        self.invalidations = 0        # stale memos encountered
        self.spans_reused = 0         # grid spans spliced from memo
        self.spans_recomputed = 0     # grid spans re-expanded

    def counters(self) -> dict:
        return {
            "memo_hits": self.memo_hits,
            "incremental_hits": self.incremental_hits,
            "full_recounts": self.full_recounts,
            "memo_invalidations": self.invalidations,
            "spans_reused": self.spans_reused,
            "spans_recomputed": self.spans_recomputed,
        }

    def forget(self) -> None:
        """Drop every memo (e.g. the maintainer's overlay was replaced)."""
        self._memos.clear()

    # ------------------------------------------------------------ count
    def count_partial(self, key, entry, state, *, chunk=None,
                      max_dispatches=None):
        """Same contract as `CacheEntry.count_partial`, plus memo/
        incremental routing.  `key` is the engine's coalescing group key
        (canonical pattern class + mode) — one memo per group."""
        edge_key = self.live.edge_key
        if entry.sharded:
            # Sharded programs fix their stripe layout in one scanned
            # dispatch and ignore budgets; memo-or-full, no spans.
            memo = self._memos.get(key)
            if memo is not None:
                if memo.edge_key == edge_key:
                    self.memo_hits += 1
                    return None, memo.result
                self.invalidations += 1
                self.full_recounts += 1
            st, out = entry.count_partial(state, chunk=chunk,
                                          max_dispatches=max_dispatches)
            if out is not None and not out.overflowed:
                self._memos[key] = _Memo(edge_key=edge_key, chunk=None,
                                         span_totals=None, result=out,
                                         max_needed=out.max_needed)
            return st, out

        matcher = entry.matcher
        cfg = matcher.cfg
        if state is None:
            width = min(chunk or cfg.capacity, cfg.capacity)
            memo = self._memos.get(key)
            if (memo is not None and memo.edge_key == edge_key
                    and memo.chunk == width):
                self.memo_hits += 1
                return (MaintState(spans=[], chunk=width, edge_key=edge_key),
                        memo.result)
            state = self._fresh_state(key, memo, edge_key, width, entry)

        budget = (None if max_dispatches is None
                  else max(int(max_dispatches), 1))
        used = 0
        while ((state.inner is not None or state.spans)
               and (budget is None or used < budget)):
            if state.inner is None:
                s, e = state.spans.pop()
                state.inner_span = (s, e)
                state.inner = CountState(
                    spans=[(s, e, cfg.capacity)], chunk=state.chunk)
            before = state.inner.dispatches
            inner, out = matcher.count_partial(
                state.inner, chunk=state.chunk,
                max_dispatches=None if budget is None else budget - used)
            step = max(inner.dispatches - before, 0)
            used += step
            state.dispatches += step
            state.inner = inner
            if out is None:
                break                      # budget exhausted mid-span
            state.span_totals[state.inner_span[0]] = inner.total
            state.overflowed |= inner.overflowed
            state.max_needed = max(state.max_needed, inner.max_needed)
            self.spans_recomputed += 1
            state.inner = None
            state.inner_span = None
        if state.inner is not None or state.spans:
            return state, None

        raw = sum(state.span_totals.values())
        count = raw // entry.plan.iep_divisor
        if entry.mode == "naive":
            count //= entry.pattern.aut_count()
        result = CountResult(count=count, overflowed=state.overflowed,
                             max_needed=state.max_needed)
        if not state.overflowed and state.edge_key == self.live.edge_key:
            self._memos[key] = _Memo(
                edge_key=state.edge_key, chunk=state.chunk,
                span_totals=dict(state.span_totals), result=result,
                max_needed=state.max_needed)
        return state, result

    # ------------------------------------------------------------ routing
    def _fresh_state(self, key, memo, edge_key, width, entry) -> MaintState:
        n = self.live.n
        grid = [(s, min(s + width, n)) for s in range(0, n, width)]
        if memo is not None and memo.chunk == width:
            self.invalidations += 1
            dirty = self.live.dirty_roots_since(memo.edge_key,
                                                entry.plan.depth)
            if dirty is not None:
                idxs = sorted({v // width for v in dirty})
                affected = [grid[i] for i in idxs if i < len(grid)]
                if len(affected) <= self.break_even * len(grid):
                    self.incremental_hits += 1
                    carried = {s: memo.span_totals[s] for s, _ in grid
                               if s not in {a for a, _ in affected}}
                    self.spans_reused += len(carried)
                    return MaintState(
                        spans=list(reversed(affected)), chunk=width,
                        edge_key=edge_key, span_totals=carried,
                        max_needed=memo.max_needed)
            self.full_recounts += 1
        return MaintState(spans=list(reversed(grid)), chunk=width,
                          edge_key=edge_key)
