"""Architecture registry: one module per assigned architecture.

`get_config(arch)` returns the full (paper-exact) ModelConfig;
`get_smoke_config(arch)` returns the reduced same-family variant used by
CPU smoke tests; `input_specs(cfg, shape, mesh=None)` builds the
ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeConfig

ARCHS = [
    "whisper-base",
    "granite-moe-1b-a400m",
    "moonshot-v1-16b-a3b",
    "minitron-4b",
    "granite-34b",
    "qwen3-4b",
    "qwen3-1.7b",
    "jamba-v0.1-52b",
    "mamba2-370m",
    "qwen2-vl-72b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCHS}")
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.SMOKE


def supported_shapes(arch: str) -> list[str]:
    """Shape cells this arch runs; long_500k only for sub-quadratic
    families (DESIGN.md §4)."""
    cfg = get_config(arch)
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):
        shapes.append("long_500k")
    return shapes


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, batch=None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns (batch_pytree, kind).  No device allocation (dry-run safe)."""
    import jax
    import jax.numpy as jnp

    B = batch if batch is not None else shape.global_batch
    S = shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind in ("train", "prefill"):
        batch_d = {}
        if cfg.stub_frontend and cfg.family == "vlm":
            batch_d["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
            batch_d["positions3"] = jax.ShapeDtypeStruct((B, 3, S), i32)
        else:
            batch_d["tokens"] = tok(B, S)
        if cfg.family == "encdec":
            batch_d["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.bfloat16
            )
        if shape.kind == "train":
            batch_d["labels"] = tok(B, S)
        return batch_d
    # decode: one new token against a seq_len cache
    return {"tokens": tok(B, 1)}
