"""qwen3-1.7b [dense]: qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab=151936,
    qk_norm=True,
    head_dim=128,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16,
)
