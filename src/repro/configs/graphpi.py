"""GraphPi workload configuration: evaluation patterns and datasets.

The paper's Fig. 7 shows six patterns P1..P6 but only as an image; the
text pins down P1/P2 (the two GraphZero patterns — the House and the
Pentagon) and says P4's top 4 vertices form a rectangle (Fig. 11
discussion).  We reconstruct the rest as a representative spread of
sizes 5-7 with |Aut| from 2 to 48 — the properties the paper's
evaluation stresses (symmetry-heavy patterns, non-trivial independent
sets for IEP, schedule spaces large enough that selection matters).

Dataset stand-ins are synthetic RMAT/ER graphs scaled like Table I
(the container is offline); `graph.datasets.load_edge_list` accepts the
real SNAP files unchanged.
"""
from __future__ import annotations

from ..core.pattern import Pattern, clique, cycle, house
from ..graph.datasets import named_dataset

# --------------------------------------------------------------------------
# P1..P6 (reconstruction documented above; |Aut| verified by tests)
# --------------------------------------------------------------------------
PATTERNS: dict[str, Pattern] = {
    # P1: House — rectangle + roof apex (GraphZero pattern).      |Aut| = 2
    "P1": house(),
    # P2: Pentagon — 5-cycle (GraphZero pattern).                 |Aut| = 10
    "P2": cycle(5, "pentagon"),
    # P3: Hexagon — 6-cycle.                                      |Aut| = 12
    "P3": cycle(6, "hexagon"),
    # P4: Rectangle + apex on a diagonal (top 4 vertices form a
    #     rectangle, as the Fig. 11 discussion requires).         |Aut| = 4
    "P4": Pattern(5, ((0, 1), (1, 2), (2, 3), (0, 3), (0, 4), (2, 4)),
                  name="rect-diag-apex"),
    # P5: Prism — two triangles joined by a perfect matching.     |Aut| = 12
    "P5": Pattern(6, ((0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5),
                      (0, 3), (1, 4), (2, 5)), name="prism"),
    # P6: Hexagon + center (wheel W6) — 7 vertices, high symmetry,
    #     independent-set tail of size 3 for IEP.                 |Aut| = 12
    "P6": Pattern(7, ((0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (0, 5),
                      (6, 0), (6, 1), (6, 2), (6, 3), (6, 4), (6, 5)),
                  name="wheel6"),
}

# Extra patterns used by tests / IEP ablations.
EXTRA_PATTERNS: dict[str, Pattern] = {
    "triangle": clique(3),
    "rectangle": cycle(4, "rectangle"),
    "clique4": clique(4),
    "clique5": clique(5),
    # The paper's Fig. 6 motif: D, E, F pairwise non-adjacent (k = 3),
    # each attached to two vertices of the triangle A-B-C.
    "fig6": Pattern(6, ((0, 1), (1, 2), (0, 2), (0, 3), (1, 3),
                        (1, 4), (2, 4), (0, 5), (2, 5)), name="fig6"),
}


def get_pattern(name: str) -> Pattern:
    if name in PATTERNS:
        return PATTERNS[name]
    if name in EXTRA_PATTERNS:
        return EXTRA_PATTERNS[name]
    raise KeyError(
        f"unknown pattern {name!r}; have {sorted(PATTERNS) + sorted(EXTRA_PATTERNS)}"
    )


# --------------------------------------------------------------------------
# dataset tiers for the benchmarks (paper Table I stand-ins)
# --------------------------------------------------------------------------
QUICK_DATASETS = ["tiny-er", "small-rmat"]          # seconds on CPU
FULL_DATASETS = ["wiki-vote-syn", "mico-syn"]       # minutes on CPU
SCALE_DATASETS = ["patents-syn"]                    # dry-run / scaling only


def get_dataset(name: str):
    return named_dataset(name)
