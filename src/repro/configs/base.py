"""Model / run configuration dataclasses shared by every architecture."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0              # 0 for attention-free families
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0             # 0 → d_model // n_heads
    qk_norm: bool = False
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0             # expert hidden width (d_ff used if 0)
    moe_every: int = 1            # MoE replaces MLP every k-th layer
    capacity_factor: float = 1.25

    # --- SSM / hybrid (mamba2, jamba) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    attn_every: int = 0           # attention layer every k-th layer (jamba 1:8)

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0

    # --- VLM ---
    mrope: bool = False           # 3-component M-RoPE (qwen2-vl)
    stub_frontend: bool = False   # modality frontend stubbed: embeds as input

    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"       # compute dtype; params are fp32 masters

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and not self.d_expert:
            object.__setattr__(self, "d_expert", self.d_ff)

    @property
    def d_inner(self) -> int:     # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced variant for smoke tests (same family/topology knobs)."""
        return replace(self, **kw)

    # ------------------------------------------------------- param counting
    def param_count(self) -> int:
        """Approximate total parameters (embedding included)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        H, K, hd = self.n_heads, self.n_kv_heads, self.head_dim
        total = V * d  # embed
        if not self.tie_embeddings:
            total += V * d
        def attn() -> int:
            return d * H * hd + 2 * d * K * hd + H * hd * d
        def dense_mlp() -> int:
            return 3 * d * ff
        def moe_mlp() -> int:
            return self.n_experts * 3 * d * self.d_expert + d * self.n_experts
        def mamba() -> int:
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            inp = d * (2 * di + 2 * ds + nh)
            conv = self.conv_width * (di + 2 * ds)
            out = di * d
            return inp + conv + out + 2 * nh + di
        for i in range(L):
            is_attn = (
                self.family in ("dense", "moe", "encdec", "vlm")
                or (self.attn_every and (i % self.attn_every == self.attn_every - 1))
            )
            total += attn() if is_attn else (mamba() if self.ssm_state else attn())
            if self.n_experts and (i % self.moe_every == self.moe_every - 1):
                total += moe_mlp()
            elif ff:
                total += dense_mlp()
        if self.enc_layers:
            total += self.enc_layers * (attn() + dense_mlp())
            total += L * attn()  # decoder cross-attention
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        moe_layers = len(
            [i for i in range(L) if i % self.moe_every == self.moe_every - 1]
        )
        all_experts = moe_layers * self.n_experts * 3 * d * self.d_expert
        active = moe_layers * self.top_k * 3 * d * self.d_expert
        return full - all_experts + active


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
