"""granite-moe-1b-a400m [moe]: 32 experts top-8, d_expert=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,              # per-expert hidden width
    vocab=49155,
    n_experts=32,
    top_k=8,
    d_expert=512,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32, d_expert=32,
    vocab=128, n_experts=4, top_k=2,
)
