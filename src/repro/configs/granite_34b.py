"""granite-34b [dense]: llama-arch code model, MQA (kv=1).
[arXiv:2405.04324; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,          # multi-query attention
    d_ff=24576,
    vocab=49152,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=1, d_ff=256, vocab=512,
)
