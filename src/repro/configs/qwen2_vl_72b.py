"""qwen2-vl-72b [vlm]: M-RoPE, dynamic resolution; vision frontend STUB —
backbone receives precomputed patch embeddings. [arXiv:2409.12191; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    mrope=True,
    stub_frontend=True,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
)
