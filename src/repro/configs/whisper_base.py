"""whisper-base [audio]: enc-dec transformer backbone, conv frontend STUB.
[arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,            # decoder layers
    enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,          # assignment: GQA kv=8 (== MHA at 8 heads)
    d_ff=2048,
    vocab=51865,
    stub_frontend=True,    # input_specs provides frame embeddings
    rope_theta=10_000.0,
)

SMOKE = CONFIG.scaled(
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=128,
)
