"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave, 16-expert MoE
every other layer. [arXiv:2403.19887; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,          # 1 attention : 7 mamba
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
)

SMOKE = CONFIG.scaled(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, d_expert=128,
    vocab=256, n_experts=4, top_k=2, ssm_state=8, ssm_head_dim=16,
    ssm_chunk=16,
)
