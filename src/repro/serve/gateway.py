"""The serving front door: one Gateway, one mesh, many workloads.

GraphPi's end state is a serving system — the plan search and the
asymmetric restrictions only pay off amortized across request streams,
and the LM stack already shares the repo's mesh machinery.  The Gateway
is the single object that owns the process's devices and co-schedules
heterogeneous tenants on them:

    gw = Gateway(mesh=mesh)
    graph = gw.add(GraphQueryWorkload(engine, requests),
                   Share(quantum=4))
    lm = gw.add(LMDecodeWorkload(LMSession("qwen3-1.7b", smoke=True)),
                Share(quantum=2, weight=2))
    gw.run()
    print(gw.report())

Workloads implement the `Workload` protocol (scheduler.py): warmup(),
ready(), step(quantum), metrics().  The two shipped implementations:

  * `GraphQueryWorkload` — wraps a `QueryEngine`'s ticket queue; each
    step executes one coalescing round (`run_pending`): same-class
    duplicate queries in the round cost ONE kernel dispatch, distinct
    classes micro-batch back-to-back on the warmed resident graph.
  * `LMDecodeWorkload` — wraps an `LMSession`; each step runs `quantum`
    greedy decode steps (resumable via the session's checkpoints).

The gateway's report includes, per workload, the scheduler-level turn
latencies split into *solo* (no other workload was ready that round)
vs *contended* (another tenant was hot) — the interference evidence the
mixed-traffic benchmark (`benchmarks/gateway_mix.py`) asserts on.

Every launcher is a thin client of this module: `launch/gateway.py`
runs mixed traffic, `launch/query_serve.py` schedules a single graph
workload (bit-identical counts to direct engine rounds — only the
scheduling differs), and `launch/serve.py` schedules a single LM
workload.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..obs import (
    Histogram, MetricsRegistry, get_tracer, latency_summary, timer,
)
from .scheduler import RoundScheduler, Share, StepReport, Workload

__all__ = [
    "Gateway",
    "GraphQueryWorkload",
    "LMDecodeWorkload",
    "RoundScheduler",
    "Share",
    "StepReport",
    "Workload",
]


class GraphQueryWorkload:
    """Pattern-query tenant: a `QueryEngine` ticket queue as a Workload.

    `prewarm=True` resolves every distinct isomorphism class in the
    initial queue during warmup() (search + JIT, no counting), so
    scheduled rounds measure steady-state execution — benchmarks want
    this; serving CLIs keep the default and pay cold costs in-round.
    """

    def __init__(self, engine, requests=(), *, name: str = "graph",
                 prewarm: bool = False):
        self.engine = engine
        self.name = name
        self.prewarm = prewarm
        self.tickets = [engine.enqueue(r) for r in requests]

    def add(self, request):
        ticket = self.engine.enqueue(request)
        self.tickets.append(ticket)
        return ticket

    def warmup(self) -> None:
        if not self.prewarm:
            return
        seen = set()
        for t in self.tickets:
            if t.done:
                continue
            key = self.engine._group_key(t.request)
            if key not in seen:
                seen.add(key)
                self.engine.plan(t.request)

    def ready(self) -> bool:
        # a preempted class (taken off the queue, mid-count) still needs
        # rounds to finish — inflight work keeps the workload hot; so do
        # queued mutations (live engines apply them at round boundaries)
        return (self.engine.pending() > 0 or self.engine.inflight() > 0
                or self.engine.mutations_pending() > 0)

    def step(self, quantum: int) -> StepReport:
        with timer() as t:
            resolved = self.engine.run_pending(limit=quantum)
        # a fully-preempted quantum resolves zero tickets but dispatched
        # real kernels — and a mutation-only round made real progress
        # too: report it so the scheduler keeps rounds coming
        # (StepReport.progressed, scheduler stall-break)
        return StepReport(
            items=len(resolved), seconds=t.seconds,
            progressed=bool(resolved)
            or self.engine.last_round_dispatches > 0
            or self.engine.last_round_mutations > 0)

    def results(self):
        """Resolved results in admission order (unresolved tickets are
        skipped — drain the queue first via Gateway.run)."""
        return [t.result for t in self.tickets if t.done]

    def metrics(self) -> dict:
        eng = self.engine
        return {
            "requests": eng.requests_resolved,
            "executions": eng.executions,
            "coalesced": eng.coalesced,
            "pending": eng.pending(),
            "inflight": eng.inflight(),
            "preemptions": eng.preemptions,
            "rejections": sum(eng.rejections.values()),
            "latency": eng.latency_percentiles(),
            "tenants": eng.tenant_report(),
            "cache_hits": eng.cache.stats.hits,
            "cache_misses": eng.cache.stats.misses,
        }


class LMDecodeWorkload:
    """LM tenant: an `LMSession`'s decode loop as a Workload.  One work
    item = one greedy decode step; prefill (or checkpoint restore, with
    `resume=True`) happens in warmup()."""

    def __init__(self, session, *, name: str = "lm", resume: bool = False):
        self.session = session
        self.name = name
        self.resume = resume

    def warmup(self) -> None:
        self.session.start(resume=self.resume)

    def ready(self) -> bool:
        return self.session.remaining > 0

    def step(self, quantum: int) -> StepReport:
        with timer() as t:
            n = self.session.decode_steps(quantum)
        return StepReport(items=n, seconds=t.seconds)

    def metrics(self) -> dict:
        return self.session.metrics()


def _turn_summary(per_item_seconds: list[float]) -> dict:
    """Per-item turn latencies → the unified percentile dict (same keys
    as `QueryEngine.latency_percentiles`; the old hand-rolled `_pcts`
    here and the engine's numpy twin had drifted — one carried
    `mean_ms`, the other didn't)."""
    h = Histogram()
    for s in per_item_seconds:
        h.observe(s * 1e3)
    return latency_summary(h)


@dataclass
class Gateway:
    """Owns the process mesh and schedules registered workloads on it.

    The mesh is *advisory glue*: workloads that need it (the engine's
    ShardedMatcher, the LM session) are constructed against
    `Gateway.mesh`, so there is exactly one device pool per process and
    the scheduler is the only interleaving authority."""

    mesh: object = None
    scheduler: RoundScheduler = field(default_factory=RoundScheduler)
    workloads: list = field(default_factory=list)
    trace: object = None
    metrics: MetricsRegistry = field(default_factory=MetricsRegistry)
    _warmed: bool = field(default=False, repr=False)

    def add(self, workload: Workload, share: Share | None = None):
        if any(w.name == workload.name for w in self.workloads):
            raise ValueError(f"duplicate workload name {workload.name!r}")
        if share is not None:
            self.scheduler.shares[workload.name] = share
        self.workloads.append(workload)
        return workload

    def warmup(self) -> None:
        with get_tracer().span("gateway.warmup",
                               workloads=len(self.workloads)):
            for w in self.workloads:
                w.warmup()
        self._warmed = True

    def run_round(self) -> tuple[int, bool] | None:
        """Drive exactly ONE scheduler round (warm first, once).  The
        async RPC server's event loop calls this between socket reads so
        N remote clients share the resident graph without threads.
        Returns None when no workload is ready, else (items, progressed)
        — the trace accumulates across calls."""
        if not self._warmed:
            self.warmup()
        if self.trace is None:
            from .scheduler import ScheduleTrace
            self.trace = ScheduleTrace()
        return self.scheduler.run_round(self.workloads, self.trace,
                                        metrics=self.metrics)

    def run(self, *, max_rounds: int | None = None, warmup: bool = True):
        """Warm every workload, then drive scheduler rounds until all
        are drained (or `max_rounds`).  Returns the ScheduleTrace."""
        with get_tracer().span(
                "gateway.run", workloads=len(self.workloads)) as sp:
            if warmup:
                self.warmup()
            self.trace = self.scheduler.run(self.workloads,
                                            max_rounds=max_rounds,
                                            metrics=self.metrics)
            sp.set(rounds=self.trace.rounds, turns=len(self.trace.turns))
        return self.trace

    def reset_window(self) -> None:
        """Reset the registry's measurement window — the same method the
        engine exposes, so benchmark phases reset both tenants' windows
        identically (and exactly once when they share a registry)."""
        self.metrics.reset_window()

    def report(self) -> dict:
        """Per-workload metrics plus the interference evidence: turn
        latency (seconds per work item) split solo vs contended."""
        out = {"rounds": 0, "workloads": {}}
        turns = self.trace.turns if self.trace is not None else []
        if self.trace is not None:
            out["rounds"] = self.trace.rounds
        for w in self.workloads:
            mine = [t for t in turns if t.name == w.name and t.items > 0]
            solo = [t.seconds / t.items for t in mine if not t.contended]
            cont = [t.seconds / t.items for t in mine if t.contended]
            solo_s, cont_s = _turn_summary(solo), _turn_summary(cont)
            rep = {
                "items": sum(t.items for t in mine),
                "turns": len(mine),
                "turn_item_ms": {"solo": solo_s, "contended": cont_s},
                "metrics": w.metrics(),
            }
            if solo and cont:
                s50, c50 = solo_s["p50_ms"], cont_s["p50_ms"]
                rep["interference_x"] = c50 / s50 if s50 > 0 else float("inf")
            out["workloads"][w.name] = rep
        return out
