"""LMSession — the LM serving loop as a reusable, resumable object.

`launch/serve.py`'s monolithic main() owned the whole prefill → decode
pipeline inline, which made the loop unschedulable (nothing else could
run between decode steps) and non-restartable (checkpoints were written
but never read).  LMSession splits it into explicit phases:

    session = LMSession("qwen3-1.7b", smoke=True, batch=4,
                        prompt_len=64, gen=32,
                        ckpt_dir=d, ckpt_every=8)
    session.start(resume=True)       # prefill — or restore mid-decode
    while session.remaining:
        session.decode_steps(4)      # any step granularity
    tokens = session.tokens_out()

so the Gateway can interleave decode steps with graph-query rounds on
the shared mesh (`LMDecodeWorkload` in gateway.py), and a preempted
serving process restarts from the last `--ckpt-every` checkpoint
(`start(resume=True)` reloads cache + tokens + step and continues
decoding — the restore path the checkpoint hooks always promised).

CONTINUOUS BATCHING (DESIGN.md §5): the decode program takes a per-row
position vector, so the padded batch's slots need not be in lockstep —
`admit()` prefills ONE new sequence (a lazily-built batch-1 prefill
program) and scatters its cache row into a free slot mid-decode, and
`evict(slot)` frees the row and returns its tokens.  Slot occupancy is
surfaced through `repro.obs` metrics (`lm.slots_active`, `lm.admitted`,
`lm.evicted`) when a registry is attached.

The checkpoint is {"cache", "tokens"} under step k via train.checkpoint
(atomic rename + LATEST pointer); k is the number of decode steps
already applied, so resumed decoding continues at position S + k
(checkpoints cover the uniform lockstep mode; per-slot admission state
is process-local).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import get_tracer, timer


def fake_prompts(cfg, B, S, key):
    """Synthetic prompt batch matching the config family's input spec."""
    if cfg.stub_frontend and cfg.family == "vlm":
        return {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16),
            "positions3": jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (B, 3, S)
            ),
        }
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, S, cfg.d_model), jnp.bfloat16
        )
    return batch


def seed_cache(cache, prefill_cache, S):
    """Copy prefill K/V (length S) into the front of the decode cache."""

    def put(dst, src):
        if dst.ndim >= 2 and src.ndim == dst.ndim and src.shape != dst.shape:
            # K/V: [..., S, K, hd] into [..., max_seq, K, hd]
            ax = next(
                i for i in range(dst.ndim) if src.shape[i] != dst.shape[i]
            )
            idx = [slice(None)] * dst.ndim
            idx[ax] = slice(0, src.shape[ax])
            return dst.at[tuple(idx)].set(src.astype(dst.dtype))
        return src.astype(dst.dtype) if src.shape == dst.shape else dst

    if "blocks" in prefill_cache:
        new_blocks = jax.tree.map(put, cache["blocks"], prefill_cache["blocks"])
        cache = {**cache, "blocks": new_blocks}
    if "cross_kv" in prefill_cache:
        cache = {**cache, "cross_kv": put(cache["cross_kv"],
                                          prefill_cache["cross_kv"])}
    return cache


def _scatter_row(dst, src, b: int):
    """Write a batch-1 cache leaf into row `b` of the live batch-B leaf
    (continuous-batching admission).  The batch axis is located
    structurally: the unique axis where src is 1 and dst is B; every
    other axis matches because both are decode-shaped (same max_seq)."""
    if src.shape == dst.shape:          # B == 1: the row IS the cache
        return src.astype(dst.dtype)
    ax = next(i for i in range(dst.ndim)
              if src.shape[i] == 1 and dst.shape[i] != 1)
    idx = [slice(None)] * dst.ndim
    idx[ax] = b
    return dst.at[tuple(idx)].set(
        jnp.squeeze(src, axis=ax).astype(dst.dtype))


class LMSession:
    """One batched generation: prefill once, then stepwise greedy decode.

    Parameters mirror `launch/serve.py`'s CLI.  `mesh=None` builds the
    host mesh; pass the Gateway's mesh to co-schedule with other
    workloads on the same devices.
    """

    def __init__(self, arch: str, *, smoke: bool = False, batch: int = 4,
                 prompt_len: int = 64, gen: int = 32, max_seq: int = 0,
                 mesh=None, model_axis: int = 1, seed: int = 0,
                 ckpt_dir: str = "", ckpt_every: int = 0,
                 metrics=None):
        from ..configs import get_config, get_smoke_config
        from ..launch.mesh import make_host_mesh

        self.arch = arch
        self.cfg = get_smoke_config(arch) if smoke else get_config(arch)
        self.mesh = mesh if mesh is not None else make_host_mesh(
            model=model_axis)
        self.B = batch
        self.S = prompt_len
        self.gen = gen
        self.max_seq = max_seq or (prompt_len + gen)
        self.seed = seed
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self._metrics = metrics         # optional obs.MetricsRegistry
        self._params = None
        self._decode = None
        self._cache = None
        self._tokens = None
        self._generated: list[np.ndarray] = []
        self.step_i = 0                 # decode steps already applied
        self.resumed_from: int | None = None
        self.prefill_seconds = 0.0
        self.decode_seconds = 0.0
        # continuous-batching slot state (uniform lockstep until the
        # first admit()/evict() call perturbs it)
        self._pos = None                # np int32 [B]: next write position
        self._active = [False] * batch  # admitted & not evicted
        self._budget = [0] * batch      # decode steps granted per slot
        self._taken = [0] * batch       # decode steps consumed per slot
        self._slot_tokens = {}          # slot -> [int] generated tokens
        self._prefill1 = None           # lazy batch-1 admission prefill
        self._cache1_sh = None
        self.admitted = 0
        self.evicted = 0

    # ----------------------------------------------------------- lifecycle
    def start(self, *, resume: bool = False) -> int | None:
        """Prefill — or, with `resume=True` and a checkpoint present,
        restore cache/tokens/step and skip the prefill entirely.
        Returns the restored step (None for a fresh start)."""
        from ..compat import set_mesh
        from ..models import transformer as T
        from .serve_step import make_decode

        key = jax.random.PRNGKey(self.seed)
        with set_mesh(self.mesh):
            # param init + decode-program build dominate cold start; a
            # leaf span keeps warmup time attributable in traces
            with get_tracer().span("lm.init", arch=self.arch,
                                   batch=self.B):
                self._params = jax.block_until_ready(
                    jax.jit(lambda k: T.init(self.cfg, k))(key))
                self._decode, _, c_sh, self._cache_shape = make_decode(
                    self.cfg, self.mesh, batch=self.B,
                    max_seq=self.max_seq
                )
            restored = self._try_restore() if resume else None
            if restored is None:
                self._prefill(key, c_sh)
            else:
                self.resumed_from = self.step_i = restored
            self._init_slots(self.step_i)
        return self.resumed_from

    def _init_slots(self, at_step: int) -> None:
        """Every row starts occupied, in lockstep at position S+step —
        the legacy uniform batch; admit()/evict() diverge from here."""
        self._pos = np.full(self.B, self.S + at_step, np.int32)
        self._active = [True] * self.B
        self._budget = [self.gen] * self.B
        self._taken = [at_step] * self.B
        toks = np.asarray(self._tokens)
        self._slot_tokens = {b: [int(toks[b, 0])] for b in range(self.B)}
        self._slots_gauge()

    def _slots_gauge(self) -> None:
        if self._metrics is not None:
            self._metrics.gauge("lm.slots_active").set(sum(self._active))

    def _prefill(self, key, c_sh) -> None:
        from ..configs import input_specs
        from ..configs.base import ShapeConfig
        from ..models import transformer as T
        from .serve_step import make_prefill

        with get_tracer().span("lm.build", batch=self.B,
                               prompt_len=self.S):
            shape = ShapeConfig("serve", self.S, self.B, "prefill")
            batch = fake_prompts(self.cfg, self.B, self.S, key)
            prefill, _, _ = make_prefill(
                self.cfg, self.mesh, input_specs(self.cfg, shape),
                q_chunk=0)
        with get_tracer().span("lm.prefill", arch=self.arch, batch=self.B,
                               prompt_len=self.S), timer() as t:
            logits, prefill_cache = jax.block_until_ready(
                prefill(self._params, batch))
        self.prefill_seconds = t.seconds
        with get_tracer().span("lm.cache_init", batch=self.B,
                               max_seq=self.max_seq):
            cache = jax.jit(
                lambda: T.init_cache(self.cfg, self.B, self.max_seq),
                out_shardings=c_sh,
            )()
            self._cache = jax.block_until_ready(
                seed_cache(cache, prefill_cache, self.S))
        self._tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        self._generated = [np.asarray(self._tokens)]

    def _try_restore(self) -> int | None:
        from ..train import checkpoint as ckpt

        if not self.ckpt_dir:
            return None
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return None
        tree_like = {
            "cache": self._cache_shape,
            "tokens": jax.ShapeDtypeStruct((self.B, 1), jnp.int32),
        }
        tree, step = ckpt.restore(self.ckpt_dir, tree_like, step=step)
        self._cache = tree["cache"]
        self._tokens = tree["tokens"]
        # generation up to `step` happened in the previous process;
        # tokens_out() covers the resumed suffix only
        self._generated = [np.asarray(self._tokens)]
        return step

    # -------------------------------------------------------------- decode
    @property
    def remaining(self) -> int:
        """Decode steps still owed to the hungriest live slot (the
        legacy ``gen - step_i`` until admissions diverge budgets)."""
        live = [self._budget[b] - self._taken[b]
                for b in range(self.B)
                if self._active[b] and self._taken[b] < self._budget[b]]
        if self._pos is None:           # start() not called yet
            return max(self.gen - self.step_i, 0)
        return max(live, default=0)

    def decode_steps(self, k: int) -> int:
        """Run up to `k` greedy decode steps (bounded by `remaining`);
        checkpoints cache+tokens every `ckpt_every` steps.  Returns the
        number of steps actually run, blocking on the last one so the
        caller's timing covers real device work.

        Every step advances the WHOLE padded batch one token at each
        row's own position (rows past their budget still compute — that
        is the price of a static batch shape — but their tokens are not
        recorded, and their cache rows are re-seeded on admit())."""
        if self._decode is None:
            raise RuntimeError("LMSession.start() must run first")
        from ..compat import set_mesh
        from ..train import checkpoint as ckpt

        n = min(max(k, 0), self.remaining)
        if n == 0:
            return 0
        with get_tracer().span("lm.decode", arch=self.arch, steps=n,
                               at_step=self.step_i), timer() as t:
            with set_mesh(self.mesh):
                for _ in range(n):
                    i = self.step_i
                    pos = jnp.asarray(self._pos)
                    logits, self._cache = self._decode(
                        self._params, self._tokens, self._cache, pos)
                    self._tokens = jnp.argmax(
                        logits, axis=-1).astype(jnp.int32)[:, None]
                    toks = np.asarray(self._tokens)
                    self._generated.append(toks)
                    for b in range(self.B):
                        if self._active[b] and self._taken[b] < self._budget[b]:
                            self._slot_tokens[b].append(int(toks[b, 0]))
                            self._taken[b] += 1
                    # dead rows park at the last cache cell (their writes
                    # are discarded on the next admission)
                    self._pos = np.minimum(self._pos + 1, self.max_seq - 1)
                    self.step_i = i + 1
                    if (self.ckpt_dir and self.ckpt_every
                            and self.step_i % self.ckpt_every == 0):
                        ckpt.save(
                            self.ckpt_dir, self.step_i,
                            {"cache": self._cache, "tokens": self._tokens})
                jax.block_until_ready(self._tokens)
        self.decode_seconds += t.seconds
        return n

    # ------------------------------------------------ continuous batching
    def slots(self) -> dict:
        """Occupancy snapshot: slot -> {active, pos, taken, budget}."""
        return {b: {"active": self._active[b],
                    "pos": None if self._pos is None else int(self._pos[b]),
                    "taken": self._taken[b],
                    "budget": self._budget[b]}
                for b in range(self.B)}

    def admit(self, *, seed: int | None = None,
              gen: int | None = None) -> int:
        """Join ONE new sequence to the running batch: prefill it with a
        lazily-built batch-1 program, scatter its KV/state rows into the
        first free slot's cache rows, and start it at position S — the
        other slots' tokens are untouched (their rows are never
        written).  Returns the slot index; raises when no slot is free.
        """
        if self._decode is None:
            raise RuntimeError("LMSession.start() must run first")
        free = [b for b in range(self.B) if not self._active[b]]
        if not free:
            raise RuntimeError(
                f"no free slot (batch={self.B} all active) — evict first")
        slot = free[0]
        if seed is None:
            seed = self.seed + 1009 * (self.admitted + 1)
        from ..compat import set_mesh

        with get_tracer().span("lm.admit", slot=slot, seed=seed), \
                set_mesh(self.mesh), timer() as t:
            row_cache, token = self._prefill_one(seed)
            self._cache = jax.block_until_ready(jax.tree.map(
                lambda dst, src: _scatter_row(dst, src, slot),
                self._cache, row_cache))
            tokens = np.asarray(self._tokens).copy()
            tokens[slot, 0] = token
            self._tokens = jnp.asarray(tokens)
        self.prefill_seconds += t.seconds
        self._pos[slot] = self.S
        self._active[slot] = True
        self._budget[slot] = self.gen if gen is None else max(int(gen), 0)
        self._taken[slot] = 0
        self._slot_tokens[slot] = [int(token)]
        self.admitted += 1
        if self._metrics is not None:
            self._metrics.counter("lm.admitted").inc()
        self._slots_gauge()
        return slot

    def evict(self, slot: int) -> np.ndarray:
        """Free a slot and return its generated tokens (prefill argmax
        first, then one per recorded decode step)."""
        if not (0 <= slot < self.B) or not self._active[slot]:
            raise ValueError(f"slot {slot} is not active")
        out = np.asarray(self._slot_tokens[slot], np.int32)
        self._active[slot] = False
        self.evicted += 1
        if self._metrics is not None:
            self._metrics.counter("lm.evicted").inc()
        self._slots_gauge()
        return out

    def _prefill_one(self, seed: int):
        """Batch-1 prefill for admissions: returns (decode-shaped cache
        with batch 1, first generated token).  The program and cache
        shapes are built once and reused for every admission."""
        from ..configs import input_specs
        from ..configs.base import ShapeConfig
        from ..models import transformer as T
        from .serve_step import make_decode, make_prefill

        if self._prefill1 is None:
            shape = ShapeConfig("serve", self.S, 1, "prefill")
            self._prefill1, _, _ = make_prefill(
                self.cfg, self.mesh, input_specs(self.cfg, shape), q_chunk=0)
            # batch-1 decode-shaped cache shardings (for seed_cache)
            _, _, self._cache1_sh, _ = make_decode(
                self.cfg, self.mesh, batch=1, max_seq=self.max_seq)
        key = jax.random.PRNGKey(seed)
        batch = fake_prompts(self.cfg, 1, self.S, key)
        logits, prefill_cache = jax.block_until_ready(
            self._prefill1(self._params, batch))
        cache1 = jax.jit(
            lambda: T.init_cache(self.cfg, 1, self.max_seq),
            out_shardings=self._cache1_sh,
        )()
        cache1 = seed_cache(cache1, prefill_cache, self.S)
        token = int(np.asarray(jnp.argmax(logits, axis=-1))[0])
        return cache1, token

    # ----------------------------------------------------------- reporting
    def tokens_out(self) -> np.ndarray:
        """[B, steps+1] generated tokens (since resume, when resumed)."""
        return np.concatenate(self._generated, axis=1)

    def metrics(self) -> dict:
        steps = self.step_i - (self.resumed_from or 0)
        tok_s = (steps * self.B / self.decode_seconds
                 if self.decode_seconds > 0 else 0.0)
        return {
            "arch": self.arch,
            "batch": self.B,
            "prompt_len": self.S,
            "steps_done": self.step_i,
            "steps_total": self.gen,
            "resumed_from": self.resumed_from,
            "prefill_seconds": self.prefill_seconds,
            "decode_seconds": self.decode_seconds,
            "decode_tok_s": tok_s,
            "ms_per_step": (1e3 * self.decode_seconds / steps
                            if steps else 0.0),
            "admitted": self.admitted,
            "evicted": self.evicted,
            "slots_active": sum(self._active),
        }
