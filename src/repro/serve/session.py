"""LMSession — the LM serving loop as a reusable, resumable object.

`launch/serve.py`'s monolithic main() owned the whole prefill → decode
pipeline inline, which made the loop unschedulable (nothing else could
run between decode steps) and non-restartable (checkpoints were written
but never read).  LMSession splits it into explicit phases:

    session = LMSession("qwen3-1.7b", smoke=True, batch=4,
                        prompt_len=64, gen=32,
                        ckpt_dir=d, ckpt_every=8)
    session.start(resume=True)       # prefill — or restore mid-decode
    while session.remaining:
        session.decode_steps(4)      # any step granularity
    tokens = session.tokens_out()

so the Gateway can interleave decode steps with graph-query rounds on
the shared mesh (`LMDecodeWorkload` in gateway.py), and a preempted
serving process restarts from the last `--ckpt-every` checkpoint
(`start(resume=True)` reloads cache + tokens + step and continues
decoding — the restore path the checkpoint hooks always promised).

The checkpoint is {"cache", "tokens"} under step k via train.checkpoint
(atomic rename + LATEST pointer); k is the number of decode steps
already applied, so resumed decoding continues at position S + k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import get_tracer, timer


def fake_prompts(cfg, B, S, key):
    """Synthetic prompt batch matching the config family's input spec."""
    if cfg.stub_frontend and cfg.family == "vlm":
        return {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16),
            "positions3": jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (B, 3, S)
            ),
        }
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, S, cfg.d_model), jnp.bfloat16
        )
    return batch


def seed_cache(cache, prefill_cache, S):
    """Copy prefill K/V (length S) into the front of the decode cache."""

    def put(dst, src):
        if dst.ndim >= 2 and src.ndim == dst.ndim and src.shape != dst.shape:
            # K/V: [..., S, K, hd] into [..., max_seq, K, hd]
            ax = next(
                i for i in range(dst.ndim) if src.shape[i] != dst.shape[i]
            )
            idx = [slice(None)] * dst.ndim
            idx[ax] = slice(0, src.shape[ax])
            return dst.at[tuple(idx)].set(src.astype(dst.dtype))
        return src.astype(dst.dtype) if src.shape == dst.shape else dst

    if "blocks" in prefill_cache:
        new_blocks = jax.tree.map(put, cache["blocks"], prefill_cache["blocks"])
        cache = {**cache, "blocks": new_blocks}
    if "cross_kv" in prefill_cache:
        cache = {**cache, "cross_kv": put(cache["cross_kv"],
                                          prefill_cache["cross_kv"])}
    return cache


class LMSession:
    """One batched generation: prefill once, then stepwise greedy decode.

    Parameters mirror `launch/serve.py`'s CLI.  `mesh=None` builds the
    host mesh; pass the Gateway's mesh to co-schedule with other
    workloads on the same devices.
    """

    def __init__(self, arch: str, *, smoke: bool = False, batch: int = 4,
                 prompt_len: int = 64, gen: int = 32, max_seq: int = 0,
                 mesh=None, model_axis: int = 1, seed: int = 0,
                 ckpt_dir: str = "", ckpt_every: int = 0):
        from ..configs import get_config, get_smoke_config
        from ..launch.mesh import make_host_mesh

        self.arch = arch
        self.cfg = get_smoke_config(arch) if smoke else get_config(arch)
        self.mesh = mesh if mesh is not None else make_host_mesh(
            model=model_axis)
        self.B = batch
        self.S = prompt_len
        self.gen = gen
        self.max_seq = max_seq or (prompt_len + gen)
        self.seed = seed
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self._params = None
        self._decode = None
        self._cache = None
        self._tokens = None
        self._generated: list[np.ndarray] = []
        self.step_i = 0                 # decode steps already applied
        self.resumed_from: int | None = None
        self.prefill_seconds = 0.0
        self.decode_seconds = 0.0

    # ----------------------------------------------------------- lifecycle
    def start(self, *, resume: bool = False) -> int | None:
        """Prefill — or, with `resume=True` and a checkpoint present,
        restore cache/tokens/step and skip the prefill entirely.
        Returns the restored step (None for a fresh start)."""
        from ..compat import set_mesh
        from ..models import transformer as T
        from .serve_step import make_decode

        key = jax.random.PRNGKey(self.seed)
        with set_mesh(self.mesh):
            # param init + decode-program build dominate cold start; a
            # leaf span keeps warmup time attributable in traces
            with get_tracer().span("lm.init", arch=self.arch,
                                   batch=self.B):
                self._params = jax.block_until_ready(
                    jax.jit(lambda k: T.init(self.cfg, k))(key))
                self._decode, _, c_sh, self._cache_shape = make_decode(
                    self.cfg, self.mesh, batch=self.B,
                    max_seq=self.max_seq
                )
            restored = self._try_restore() if resume else None
            if restored is None:
                self._prefill(key, c_sh)
            else:
                self.resumed_from = self.step_i = restored
        return self.resumed_from

    def _prefill(self, key, c_sh) -> None:
        from ..configs import input_specs
        from ..configs.base import ShapeConfig
        from ..models import transformer as T
        from .serve_step import make_prefill

        with get_tracer().span("lm.build", batch=self.B,
                               prompt_len=self.S):
            shape = ShapeConfig("serve", self.S, self.B, "prefill")
            batch = fake_prompts(self.cfg, self.B, self.S, key)
            prefill, _, _ = make_prefill(
                self.cfg, self.mesh, input_specs(self.cfg, shape),
                q_chunk=0)
        with get_tracer().span("lm.prefill", arch=self.arch, batch=self.B,
                               prompt_len=self.S), timer() as t:
            logits, prefill_cache = jax.block_until_ready(
                prefill(self._params, batch))
        self.prefill_seconds = t.seconds
        with get_tracer().span("lm.cache_init", batch=self.B,
                               max_seq=self.max_seq):
            cache = jax.jit(
                lambda: T.init_cache(self.cfg, self.B, self.max_seq),
                out_shardings=c_sh,
            )()
            self._cache = jax.block_until_ready(
                seed_cache(cache, prefill_cache, self.S))
        self._tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        self._generated = [np.asarray(self._tokens)]

    def _try_restore(self) -> int | None:
        from ..train import checkpoint as ckpt

        if not self.ckpt_dir:
            return None
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return None
        tree_like = {
            "cache": self._cache_shape,
            "tokens": jax.ShapeDtypeStruct((self.B, 1), jnp.int32),
        }
        tree, step = ckpt.restore(self.ckpt_dir, tree_like, step=step)
        self._cache = tree["cache"]
        self._tokens = tree["tokens"]
        # generation up to `step` happened in the previous process;
        # tokens_out() covers the resumed suffix only
        self._generated = [np.asarray(self._tokens)]
        return step

    # -------------------------------------------------------------- decode
    @property
    def remaining(self) -> int:
        return max(self.gen - self.step_i, 0)

    def decode_steps(self, k: int) -> int:
        """Run up to `k` greedy decode steps (bounded by `remaining`);
        checkpoints cache+tokens every `ckpt_every` steps.  Returns the
        number of steps actually run, blocking on the last one so the
        caller's timing covers real device work."""
        if self._decode is None:
            raise RuntimeError("LMSession.start() must run first")
        from ..compat import set_mesh
        from ..train import checkpoint as ckpt

        n = min(max(k, 0), self.remaining)
        if n == 0:
            return 0
        with get_tracer().span("lm.decode", arch=self.arch, steps=n,
                               at_step=self.step_i), timer() as t:
            with set_mesh(self.mesh):
                for _ in range(n):
                    i = self.step_i
                    pos = jnp.asarray(self.S + i, jnp.int32)
                    logits, self._cache = self._decode(
                        self._params, self._tokens, self._cache, pos)
                    self._tokens = jnp.argmax(
                        logits, axis=-1).astype(jnp.int32)[:, None]
                    self._generated.append(np.asarray(self._tokens))
                    self.step_i = i + 1
                    if (self.ckpt_dir and self.ckpt_every
                            and self.step_i % self.ckpt_every == 0):
                        ckpt.save(
                            self.ckpt_dir, self.step_i,
                            {"cache": self._cache, "tokens": self._tokens})
                jax.block_until_ready(self._tokens)
        self.decode_seconds += t.seconds
        return n

    # ----------------------------------------------------------- reporting
    def tokens_out(self) -> np.ndarray:
        """[B, steps+1] generated tokens (since resume, when resumed)."""
        return np.concatenate(self._generated, axis=1)

    def metrics(self) -> dict:
        steps = self.step_i - (self.resumed_from or 0)
        tok_s = (steps * self.B / self.decode_seconds
                 if self.decode_seconds > 0 else 0.0)
        return {
            "arch": self.arch,
            "batch": self.B,
            "prompt_len": self.S,
            "steps_done": self.step_i,
            "steps_total": self.gen,
            "resumed_from": self.resumed_from,
            "prefill_seconds": self.prefill_seconds,
            "decode_seconds": self.decode_seconds,
            "decode_tok_s": tok_s,
            "ms_per_step": (1e3 * self.decode_seconds / steps
                            if steps else 0.0),
        }
