"""Ticket RPC front door — N client processes, ONE resident graph+mesh.

The Gateway is in-process: callers must own the Python object to submit
tickets.  This module puts a thin asyncio socket server in front of it
(`launch/gateway.py --listen PORT`) so independent client processes
drive one warmed engine — plan cache, resident CSR, LM batch and all —
without each paying the model/graph cold start.

Wire format (DESIGN.md §5): every message, both directions, is a FRAME
— a 4-byte big-endian unsigned length prefix followed by that many
bytes of UTF-8 JSON.  One request frame yields exactly one response
frame on the same connection (pipelining is sequential per connection;
run several connections for concurrency).  Operations:

    {"op": "submit", "pattern": "P1" | {"n":3, "edges":[[0,1],...]},
     "use_iep": false, "verify": false, "mode": "graphpi",
     "tenant": "default"}
        -> {"ok": true, "ticket": 7}
        -> {"ok": false, "error": "rejected", "rejection": {...}}
           (admission control: the tenant's queue is at its depth bound)
    {"op": "poll",   "ticket": 7} -> {"ok": true, "done": false,
                                      "cancelled": false}
    {"op": "result", "ticket": 7} -> blocks until resolved;
        -> {"ok": true, "result": {..., "count": N, "line": "..."}}
    {"op": "cancel", "ticket": 7} -> {"ok": true|false}
    {"op": "stats"}               -> {"ok": true, "stats": engine summary}
    {"op": "mutate", "verb": "insert_edges" | "delete_edges" | "compact",
     "edges": [[u, v], ...]}      -> {"ok": true, "verb": ...,
                                      "queued_edges": N,
                                      "pending_batches": B,
                                      "edge_epoch": E}
        Live engines only (`launch/gateway.py --live`).  The batch is
        QUEUED and applies atomically at the next round boundary
        (src/repro/live/), so the ordering is deterministic: any submit
        acked after this mutate ack is answered on the post-mutation
        epoch, and no in-flight count ever straddles epochs.
    {"op": "shutdown"}            -> {"ok": true}  (server exits after)

CONCURRENCY MODEL.  JAX dispatch is per-process serial, so the server
stays single-threaded: the asyncio event loop interleaves socket frames
with `Gateway.run_round()` calls — each round is bounded by the
workloads' quanta (and the engine's preemption budget), so the loop
returns to the sockets promptly even mid-huge-query.  Result waiters
park on an event that pulses once per round.

The counts are BIT-IDENTICAL to the in-process path: the server calls
the same `QueryEngine.run_pending` rounds a local Gateway would
(`scripts/gateway_smoke.sh` replays one trace through both and diffs
every count; tests/test_rpc.py asserts the same in-process).

`python -m repro.serve.rpc --connect HOST:PORT --requests trace.jsonl`
is the reference client: submits every request in the trace, then
prints each result line (in submission order) like the launcher does.
A trace line `{"mutate": "insert_edges", "edges": [[u,v],...]}` drains
outstanding results first (pre-mutation counts print on their admission
epoch), then sends the mutate frame — so a trace interleaving queries
and mutations replays as a deterministic epoch history.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import socket
import struct
import sys
from dataclasses import asdict

from ..query.engine import Rejection

__all__ = [
    "GatewayRPCServer",
    "RPCClient",
    "RPCError",
    "request_from_spec",
    "result_to_wire",
]

_HDR = struct.Struct(">I")
MAX_FRAME = 16 << 20             # 16 MiB: a frame larger than this is a bug


def encode_frame(obj) -> bytes:
    body = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(body) > MAX_FRAME:
        raise ValueError(f"frame too large: {len(body)} bytes")
    return _HDR.pack(len(body)) + body


async def read_frame(reader) -> dict | None:
    """One length-prefixed JSON frame; None on clean EOF."""
    try:
        hdr = await reader.readexactly(_HDR.size)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None
    (n,) = _HDR.unpack(hdr)
    if n > MAX_FRAME:
        raise ValueError(f"frame too large: {n} bytes")
    body = await reader.readexactly(n)
    return json.loads(body.decode("utf-8"))


def request_from_spec(spec: dict, get_pattern=None):
    """One trace-file/wire request spec -> QueryRequest (the same format
    `launch/query_serve.py --requests` reads, plus a `tenant` field)."""
    from ..core.pattern import Pattern
    from ..query import QueryRequest

    pat = spec["pattern"]
    if isinstance(pat, str):
        if get_pattern is None:
            from ..configs.graphpi import get_pattern
        pattern = get_pattern(pat)
    else:
        pattern = Pattern(
            int(pat["n"]),
            tuple((int(u), int(v)) for u, v in pat["edges"]),
            name=pat.get("name", "inline"),
        )
    return QueryRequest(
        pattern,
        use_iep=bool(spec.get("use_iep", False)),
        verify=bool(spec.get("verify", False)),
        mode=spec.get("mode", "graphpi"),
        tenant=str(spec.get("tenant", "default")),
    )


def result_to_wire(result) -> dict:
    """QueryResult -> JSON-safe dict (tuples become lists; the rendered
    serving-log `line` rides along so clients print what the launcher
    prints — `count=N` included, which the smoke diff greps)."""
    out = asdict(result)
    out["order"] = list(out["order"])
    out["res_set"] = [list(r) for r in out["res_set"]]
    out["line"] = result.line()
    return out


class GatewayRPCServer:
    """Asyncio front door over one Gateway + GraphQueryWorkload.

    The server owns the drive loop: whenever any workload is ready it
    calls `gateway.run_round()` (one bounded scheduler round), then
    yields to the sockets; when everything is drained it sleeps on a
    work event that `submit` sets.  `serve_forever()` returns after a
    `shutdown` frame (or `stop()`)."""

    def __init__(self, gateway, workload, *, host: str = "127.0.0.1",
                 port: int = 0, get_pattern=None):
        self.gateway = gateway
        self.workload = workload
        self.engine = workload.engine
        self.host = host
        self.port = port             # 0 = ephemeral; real port set on serve
        self._get_pattern = get_pattern
        self._tickets: dict[int, object] = {}
        self._work: asyncio.Event | None = None
        self._round_ev: asyncio.Event | None = None
        self._stop_ev: asyncio.Event | None = None
        self.rounds = 0
        self.connections = 0

    # ------------------------------------------------------------ lifecycle
    def stop(self) -> None:
        if self._stop_ev is not None:
            self._stop_ev.set()

    def serve_forever(self, *, on_ready=None) -> None:
        """Blocking entry point (runs its own event loop)."""
        asyncio.run(self.serve(on_ready=on_ready))

    async def serve(self, *, on_ready=None) -> None:
        self._work = asyncio.Event()
        self._round_ev = asyncio.Event()
        self._stop_ev = asyncio.Event()
        server = await asyncio.start_server(self._handle, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        if on_ready is not None:
            on_ready(self.host, self.port)
        drive = asyncio.get_event_loop().create_task(self._drive())
        try:
            await self._stop_ev.wait()
        finally:
            drive.cancel()
            self._pulse()            # release any parked result waiters
            server.close()
            await server.wait_closed()

    async def _drive(self) -> None:
        while not self._stop_ev.is_set():
            out = self.gateway.run_round()
            if out is not None:
                self.rounds += 1
                self._pulse()
                await asyncio.sleep(0)   # let socket frames interleave
                continue
            # drained: park until new work (or shutdown) arrives
            self._pulse()
            self._work.clear()
            work = asyncio.ensure_future(self._work.wait())
            stop = asyncio.ensure_future(self._stop_ev.wait())
            try:
                await asyncio.wait({work, stop},
                                   return_when=asyncio.FIRST_COMPLETED)
            finally:
                work.cancel()
                stop.cancel()

    def _pulse(self) -> None:
        """Wake every coroutine waiting on 'a round happened'."""
        ev, self._round_ev = self._round_ev, asyncio.Event()
        ev.set()

    # ------------------------------------------------------------- handlers
    async def _handle(self, reader, writer) -> None:
        self.connections += 1
        try:
            while True:
                msg = await read_frame(reader)
                if msg is None:
                    break
                try:
                    resp = await self._dispatch(msg)
                except Exception as e:   # a bad frame must not kill the loop
                    resp = {"ok": False, "error": f"{type(e).__name__}: {e}"}
                writer.write(encode_frame(resp))
                await writer.drain()
        finally:
            writer.close()

    async def _dispatch(self, msg: dict) -> dict:
        op = msg.get("op")
        if op == "submit":
            return self._submit(msg)
        if op == "poll":
            t = self._tickets.get(msg.get("ticket"))
            if t is None:
                return {"ok": False, "error": "unknown ticket"}
            return {"ok": True, "done": t.done, "cancelled": t.cancelled}
        if op == "result":
            return await self._result(msg.get("ticket"))
        if op == "cancel":
            t = self._tickets.get(msg.get("ticket"))
            if t is None:
                return {"ok": False, "error": "unknown ticket"}
            return {"ok": self.engine.cancel(t)}
        if op == "stats":
            return {"ok": True, "stats": self.engine.summary(),
                    "rounds": self.rounds}
        if op == "mutate":
            ack = self.engine.request_mutation(msg.get("verb"),
                                               msg.get("edges"))
            # the queued batch applies at the next round boundary; wake
            # the drive loop so a drained server still processes it
            self._work.set()
            return {"ok": True, **ack}
        if op == "shutdown":
            self._stop_ev.set()
            return {"ok": True}
        return {"ok": False, "error": f"unknown op {op!r}"}

    def _submit(self, msg: dict) -> dict:
        req = request_from_spec(msg, self._get_pattern)
        out = self.engine.try_enqueue(req)
        if isinstance(out, Rejection):
            return {"ok": False, "error": "rejected",
                    "rejection": asdict(out)}
        self.workload.tickets.append(out)
        self._tickets[out.seq] = out
        self._work.set()
        return {"ok": True, "ticket": out.seq}

    async def _result(self, seq) -> dict:
        t = self._tickets.get(seq)
        if t is None:
            return {"ok": False, "error": "unknown ticket"}
        while not t.done:
            if t.cancelled:
                return {"ok": False, "error": "cancelled"}
            ev = self._round_ev
            self._work.set()
            await ev.wait()
        return {"ok": True, "result": result_to_wire(t.result)}


class RPCError(RuntimeError):
    """A server-side {"ok": false} response, surfaced client-side."""

    def __init__(self, resp: dict):
        super().__init__(resp.get("error", "rpc error"))
        self.resp = resp


class RPCClient:
    """Synchronous stdlib-socket client (one connection, sequential
    frames) — what the CLI below and the smoke/CI scripts use."""

    def __init__(self, host: str, port: int, *, tenant: str = "default",
                 timeout: float = 300.0):
        self.tenant = tenant
        self.sock = socket.create_connection((host, port), timeout=timeout)

    def close(self) -> None:
        self.sock.close()

    def call(self, msg: dict) -> dict:
        self.sock.sendall(encode_frame(msg))
        hdr = self._recv(_HDR.size)
        (n,) = _HDR.unpack(hdr)
        return json.loads(self._recv(n).decode("utf-8"))

    def _recv(self, n: int) -> bytes:
        buf = b""
        while len(buf) < n:
            chunk = self.sock.recv(n - len(buf))
            if not chunk:
                raise ConnectionError("server closed the connection")
            buf += chunk
        return buf

    # ------------------------------------------------------------- verbs
    def submit(self, spec: dict) -> int:
        msg = {"op": "submit", "tenant": self.tenant, **spec}
        resp = self.call(msg)
        if not resp.get("ok"):
            raise RPCError(resp)
        return resp["ticket"]

    def poll(self, ticket: int) -> dict:
        return self.call({"op": "poll", "ticket": ticket})

    def result(self, ticket: int) -> dict:
        resp = self.call({"op": "result", "ticket": ticket})
        if not resp.get("ok"):
            raise RPCError(resp)
        return resp["result"]

    def cancel(self, ticket: int) -> bool:
        return bool(self.call({"op": "cancel", "ticket": ticket}).get("ok"))

    def stats(self) -> dict:
        resp = self.call({"op": "stats"})
        if not resp.get("ok"):
            raise RPCError(resp)
        return resp

    def mutate(self, verb: str, edges=None) -> dict:
        msg = {"op": "mutate", "verb": verb}
        if edges is not None:
            msg["edges"] = [[int(u), int(v)] for u, v in edges]
        resp = self.call(msg)
        if not resp.get("ok"):
            raise RPCError(resp)
        return resp

    def shutdown(self) -> None:
        self.call({"op": "shutdown"})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="RPC client for a --listen'ing launch/gateway.py")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT")
    ap.add_argument("--requests", required=True,
                    help="JSON-lines request trace (query_serve format)")
    ap.add_argument("--tenant", default="default")
    ap.add_argument("--timeout", type=float, default=300.0)
    ap.add_argument("--shutdown", action="store_true",
                    help="ask the server to exit after the last result")
    args = ap.parse_args(argv)

    host, _, port = args.connect.rpartition(":")
    client = RPCClient(host or "127.0.0.1", int(port),
                       tenant=args.tenant, timeout=args.timeout)
    rc = 0
    tickets: list[int] = []

    def flush() -> None:
        """Print results for every outstanding ticket, in order."""
        nonlocal rc
        for tk in tickets:
            try:
                r = client.result(tk)
                print("[rpc]", r["line"])
                if r.get("verified") is False:
                    rc = 1
            except RPCError as e:
                print(f"[rpc] ticket {tk} FAILED: {e}")
                rc = 1
        tickets.clear()

    with open(args.requests) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            spec = json.loads(line)
            if "mutate" in spec:
                # drain first so earlier submits are answered (and
                # printed) on their admission epoch, then mutate — the
                # trace reads as a deterministic epoch history
                flush()
                try:
                    ack = client.mutate(spec["mutate"], spec.get("edges"))
                    print(f"[rpc] mutate {ack['verb']} "
                          f"queued_edges={ack['queued_edges']} "
                          f"edge_epoch={ack['edge_epoch']}")
                except RPCError as e:
                    print(f"[rpc] mutate FAILED: {e}")
                    rc = 1
                continue
            tickets.append(client.submit(spec))
    flush()
    if args.shutdown:
        client.shutdown()
    client.close()
    return rc


if __name__ == "__main__":
    sys.exit(main())
