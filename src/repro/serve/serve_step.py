"""Sharded serving steps: prefill and single-token decode.

Decode KV caches are sharded by `parallel.sharding.choose_kv_spec`:
heads over `model` when divisible, else sequence over `model`
(flash-decoding style partial softmax — required for the MQA/GQA configs
whose kv_heads < |model|)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import transformer as T
from ..parallel.sharding import (
    batch_specs, cache_shardings, param_shardings, pick_layout,
)
from ..train.train_step import abstract_params


def cast_params_for_serving(params, dtype=jnp.bfloat16):
    """Cast fp32 master weights (ndim ≥ 2) to the serving compute dtype.

    §Perf: serving steps were all-gathering FP32 masters and converting
    per layer per step; casting up-front inside the jitted step makes the
    convert local+sharded and halves every parameter all-gather.  The MoE
    router stays fp32 (routing decisions are precision-sensitive)."""

    def one(path, v):
        names = [str(getattr(k, "key", k)) for k in path]
        if "router" in names:
            return v
        if v.dtype == jnp.float32 and v.ndim >= 2:
            return v.astype(dtype)
        return v

    return jax.tree_util.tree_map_with_path(one, params)


def make_prefill(cfg, mesh, batch_shape, *, q_chunk: int = 1024,
                 cast_bf16: bool = True):
    layout = pick_layout(cfg, mesh)
    p_shape = abstract_params(cfg)
    p_sh = param_shardings(p_shape, mesh, layout)
    b_sh = batch_specs(batch_shape, mesh, layout)
    base = T.prefill_fn(cfg, q_chunk=q_chunk)
    dtype = jnp.dtype(cfg.dtype)

    def fn(params, batch):
        if cast_bf16:
            params = cast_params_for_serving(params, dtype)
        return base(params, batch)

    jitted = jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=None)
    return jitted, p_sh, b_sh


def make_decode(cfg, mesh, *, batch: int, max_seq: int,
                cache_dtype=jnp.bfloat16, cast_bf16: bool = True):
    """Returns (jitted_step, shardings...) for one decode step.

    step(params, tokens [B,1], cache, pos) -> (logits [B,V], cache)"""
    layout = pick_layout(cfg, mesh)
    p_shape = abstract_params(cfg)
    p_sh = param_shardings(p_shape, mesh, layout)
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, batch, max_seq, dtype=cache_dtype)
    )
    c_sh = cache_shardings(cfg, cache_shape, batch, max_seq, mesh)
    tok_sh = batch_specs(
        {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}, mesh
    )["tokens"]
    base = T.decode_fn(cfg)
    dtype = jnp.dtype(cfg.dtype)

    def fn(params, tokens, cache, pos):
        if cast_bf16:
            params = cast_params_for_serving(params, dtype)
        return base(params, tokens, cache, pos)

    jitted = jax.jit(
        fn,
        in_shardings=(p_sh, tok_sh, c_sh, NamedSharding(mesh, P())),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )
    return jitted, p_sh, c_sh, cache_shape
