"""Round-based co-scheduler for heterogeneous workloads on one mesh.

The Gateway (gateway.py) owns the process's devices; this module owns
*when each workload runs*.  Scheduling is deliberately cooperative and
deterministic: JAX dispatch is single-threaded per process, so instead
of threads + locks the scheduler runs discrete ROUNDS.  Each round it
visits the registered workloads in a fixed order (priority, then
registration order) and grants every ready workload `weight` turns of
`quantum` work items each.  A workload's `step(quantum)` call is its
entire opportunity for that turn — it must return promptly (quantum
bounds the work, not wall time) so a hot LM decode can never starve a
burst of graph queries, and vice versa.

Determinism is the tested property: two workloads with fixed shares
produce a known interleaving (tests/test_gateway.py), which is what
makes the mixed-traffic acceptance runs reproducible.

Nothing in this module imports JAX — `Workload` is a structural
protocol, so the scheduler is unit-testable with scripted fakes
(repro.obs is stdlib-only by the same contract).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

from ..obs import get_tracer, timer


@dataclass(frozen=True)
class StepReport:
    """What one `step(quantum)` call actually did."""

    items: int                   # work units completed (<= quantum)
    seconds: float               # wall time of the step
    # preemptive workloads can burn a whole turn mid-item (a suspended
    # query resolves zero tickets yet dispatched real kernels): they set
    # `progressed` explicitly so the stall-break doesn't kill the loop.
    # None (the default) keeps the legacy meaning: progress == items > 0.
    progressed: bool | None = None

    @property
    def made_progress(self) -> bool:
        return self.items > 0 if self.progressed is None else self.progressed


@runtime_checkable
class Workload(Protocol):
    """Anything the Gateway can co-schedule.

    name:      stable identifier (used in shares, traces, reports).
    warmup():  pay one-time costs (compile, prefill, plan preloads)
               before the first round, so rounds measure steady state.
    ready():   True while the workload has pending work.
    step(q):   run up to `q` work items, return a StepReport.
    metrics(): workload-specific counters for the gateway report.
    """

    name: str

    def warmup(self) -> None: ...

    def ready(self) -> bool: ...

    def step(self, quantum: int) -> StepReport: ...

    def metrics(self) -> dict: ...


@dataclass(frozen=True)
class Share:
    """Per-workload scheduling share.

    quantum:  work items granted per turn (units are workload-defined:
              decode steps for the LM, query tickets for the graph).
    weight:   turns granted per round — the fair-share knob; a workload
              with weight 2 gets two `step()` calls for every one of a
              weight-1 peer.
    priority: higher-priority workloads take their turns earlier within
              a round (latency preference, not extra capacity).
    """

    quantum: int = 1
    weight: int = 1
    priority: int = 0


@dataclass(frozen=True)
class Turn:
    """One `step()` grant, as recorded in the schedule trace."""

    round: int
    name: str
    items: int
    seconds: float
    contended: bool              # another workload was ready this round


@dataclass
class ScheduleTrace:
    turns: list[Turn] = field(default_factory=list)
    rounds: int = 0

    def interleaving(self) -> list[str]:
        """The turn order as a name sequence (the fairness invariant)."""
        return [t.name for t in self.turns]

    def items_of(self, name: str) -> int:
        return sum(t.items for t in self.turns if t.name == name)


class RoundScheduler:
    """Deterministic weighted round-robin over cooperative workloads.

    Every round: sort registered workloads by (-priority, registration
    order); each ready one receives `weight` consecutive `step(quantum)`
    calls.  A workload that goes idle mid-round simply stops receiving
    turns; the loop ends when no workload is ready (or `max_rounds`).
    """

    def __init__(self, shares: dict[str, Share] | None = None,
                 *, default: Share = Share()):
        self.shares = dict(shares or {})
        self.default = default

    def share_of(self, name: str) -> Share:
        return self.shares.get(name, self.default)

    def run(self, workloads: list[Workload],
            *, max_rounds: int | None = None,
            metrics=None) -> ScheduleTrace:
        """Drive rounds until no workload is ready (or `max_rounds`).

        With a `MetricsRegistry` passed as `metrics`, every productive
        turn also lands in `scheduler.turn_item_ms{workload=,phase=}`
        histograms (phase solo|contended) — the same split the Gateway
        report derives from the trace, but windowed/resettable.
        """
        trace = ScheduleTrace()
        while max_rounds is None or trace.rounds < max_rounds:
            out = self.run_round(workloads, trace, metrics=metrics)
            if out is None:
                break
            _, progressed = out
            if not progressed:
                # every ready workload declined to make progress — a
                # buggy tenant must not spin the gateway forever
                break
        return trace

    def run_round(self, workloads: list[Workload], trace: ScheduleTrace,
                  *, metrics=None) -> tuple[int, bool] | None:
        """Drive exactly ONE round (the unit the async RPC front door
        interleaves with socket traffic).  Returns ``None`` when no
        workload is ready, else ``(items, progressed)`` — `progressed`
        aggregates :attr:`StepReport.made_progress` so a preempted query
        quantum (zero tickets resolved, real kernels dispatched) still
        counts as forward motion."""
        tr = get_tracer()
        order = sorted(
            range(len(workloads)),
            key=lambda i: (-self.share_of(workloads[i].name).priority, i),
        )
        ready = [i for i in order if workloads[i].ready()]
        if not ready:
            return None
        rnd = trace.rounds
        contended = len(ready) > 1
        round_items = 0
        round_progress = False
        with tr.span("scheduler.round", round=rnd,
                     ready=len(ready)) as rsp:
            for i in ready:
                w = workloads[i]
                share = self.share_of(w.name)
                for _ in range(max(share.weight, 1)):
                    if not w.ready():
                        break
                    with tr.span("scheduler.turn", workload=w.name,
                                 round=rnd,
                                 contended=contended) as tsp, \
                            timer() as t:
                        rep = w.step(max(share.quantum, 1))
                        tsp.set(items=rep.items)
                    dt = t.seconds
                    round_items += rep.items
                    round_progress = round_progress or rep.made_progress
                    seconds = rep.seconds if rep.seconds > 0 else dt
                    trace.turns.append(Turn(
                        round=rnd, name=w.name, items=rep.items,
                        seconds=seconds, contended=contended,
                    ))
                    if metrics is not None and rep.items > 0:
                        metrics.histogram(
                            "scheduler.turn_item_ms", workload=w.name,
                            phase="contended" if contended else "solo",
                        ).observe(seconds / rep.items * 1e3)
            rsp.set(items=round_items)
        trace.rounds += 1
        return round_items, round_progress
