from .csr import GraphCSR
from .datasets import complete_graph, erdos_renyi, rmat, load_edge_list, named_dataset
