"""Graph generators and loaders.

The container is offline, so the paper's SNAP graphs are stood in for by
synthetic generators matched to their |V|/|E| scale (DESIGN.md §9).  The
edge-list loader accepts the exact SNAP format, so the real datasets plug
in unchanged on a connected machine.
"""
from __future__ import annotations

import numpy as np

from .csr import GraphCSR


def complete_graph(n: int) -> GraphCSR:
    iu = np.triu_indices(n, k=1)
    edges = np.stack([iu[0], iu[1]], axis=1)
    return GraphCSR.from_edges(n, edges, name=f"K{n}")


def erdos_renyi(n: int, m: int, seed: int = 0, name: str = "") -> GraphCSR:
    """~m undirected edges sampled uniformly (dedup may shave a few)."""
    rng = np.random.default_rng(seed)
    # oversample to survive dedup/self-loop removal
    k = int(m * 1.2) + 16
    e = rng.integers(0, n, size=(k, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]][:m]
    return GraphCSR.from_edges(n, e, name=name or f"ER({n},{m})")


def rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    name: str = "",
    relabel_by_degree: bool = True,
) -> GraphCSR:
    """R-MAT power-law generator (Graph500 parameters by default).

    Produces the heavy-tailed degree distributions that make the paper's
    load-balancing (fine-grained task partitioning) matter.
    """
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities (a, b, c, d)
        go_right = r >= a + b          # dst high bit
        go_down = ((r >= a) & (r < a + b)) | (r >= a + b + c)  # src high bit
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    edges = np.stack([src, dst], axis=1)
    return GraphCSR.from_edges(
        n, edges, relabel_by_degree=relabel_by_degree, name=name or f"RMAT{scale}"
    )


def load_edge_list(path: str, name: str = "") -> GraphCSR:
    """SNAP-style whitespace edge list; '#' comments allowed."""
    edges = np.loadtxt(path, dtype=np.int64, comments="#").reshape(-1, 2)
    n = int(edges.max()) + 1
    return GraphCSR.from_edges(n, edges, name=name or path)


# --------------------------------------------------------------------------
# Named synthetic stand-ins scaled like the paper's datasets (Table I).
# (wiki-vote 7.1K/100.8K, mico 96.6K/1.1M, patents 3.8M/16.5M, ...)
# Only the first two are sized for CPU-quick runs; the rest gate behind
# explicit benchmark flags.
# --------------------------------------------------------------------------
_NAMED = {
    "wiki-vote-syn": lambda: rmat(13, 12, seed=1, name="wiki-vote-syn"),
    "mico-syn": lambda: rmat(17, 11, seed=2, name="mico-syn"),
    "patents-syn": lambda: rmat(22, 4, seed=3, name="patents-syn"),
    "tiny-er": lambda: erdos_renyi(256, 2048, seed=4, name="tiny-er"),
    "small-rmat": lambda: rmat(10, 8, seed=5, name="small-rmat"),
}


def named_dataset(name: str) -> GraphCSR:
    if name not in _NAMED:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(_NAMED)}")
    return _NAMED[name]()
