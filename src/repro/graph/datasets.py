"""Graph generators and loaders.

The container is offline, so the paper's SNAP graphs are stood in for by
synthetic generators matched to their |V|/|E| scale (DESIGN.md §10).  The
edge-list loader accepts the exact SNAP format, so the real datasets plug
in unchanged on a connected machine.
"""
from __future__ import annotations

import numpy as np

from .csr import GraphCSR


def complete_graph(n: int) -> GraphCSR:
    iu = np.triu_indices(n, k=1)
    edges = np.stack([iu[0], iu[1]], axis=1)
    return GraphCSR.from_edges(n, edges, name=f"K{n}")


def erdos_renyi(n: int, m: int, seed: int = 0, name: str = "") -> GraphCSR:
    """~m undirected edges sampled uniformly (dedup may shave a few)."""
    rng = np.random.default_rng(seed)
    # oversample to survive dedup/self-loop removal
    k = int(m * 1.2) + 16
    e = rng.integers(0, n, size=(k, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]][:m]
    return GraphCSR.from_edges(n, e, name=name or f"ER({n},{m})")


def rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    name: str = "",
    relabel_by_degree: bool = True,
) -> GraphCSR:
    """R-MAT power-law generator (Graph500 parameters by default).

    Produces the heavy-tailed degree distributions that make the paper's
    load-balancing (fine-grained task partitioning) matter.
    """
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities (a, b, c, d)
        go_right = r >= a + b          # dst high bit
        go_down = ((r >= a) & (r < a + b)) | (r >= a + b + c)  # src high bit
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    edges = np.stack([src, dst], axis=1)
    return GraphCSR.from_edges(
        n, edges, relabel_by_degree=relabel_by_degree, name=name or f"RMAT{scale}"
    )


def random_labels(
    n: int, n_labels: int, seed: int = 0, skew: float = 1.0
) -> np.ndarray:
    """Per-vertex labels 0..n_labels-1 with a geometric-ish skew.

    skew=1.0 is uniform; skew>1 makes low label ids more common (real
    property graphs are dominated by a few frequent types).  Every label
    id is guaranteed at least one vertex when n >= n_labels so per-label
    CSR views and question inventories never see an empty class.
    """
    if n_labels < 1:
        raise ValueError("n_labels must be >= 1")
    rng = np.random.default_rng(seed)
    w = skew ** -np.arange(n_labels, dtype=np.float64)
    labels = rng.choice(n_labels, size=n, p=w / w.sum()).astype(np.int32)
    if n >= n_labels:
        # pin one representative per label at random positions
        pos = rng.choice(n, size=n_labels, replace=False)
        labels[pos] = np.arange(n_labels, dtype=np.int32)
    return labels


def labeled_rmat(
    scale: int,
    edge_factor: int = 8,
    n_labels: int = 4,
    seed: int = 0,
    skew: float = 1.5,
    name: str = "",
) -> GraphCSR:
    """R-MAT skeleton with skewed random vertex labels — the synthetic
    property graph used by the labeled benchmarks.  Labels are drawn
    AFTER the degree relabel so label classes cut across the degree
    distribution (typed hubs and typed leaves both exist)."""
    g = rmat(scale, edge_factor, seed=seed,
             name=name or f"LRMAT{scale}x{n_labels}")
    labels = random_labels(g.n, n_labels, seed=seed + 1, skew=skew)
    return GraphCSR(n=g.n, m=g.m, indptr=g.indptr, indices=g.indices,
                    degrees=g.degrees, name=g.name, labels=labels)


def labeled_er(
    n: int,
    m: int,
    n_labels: int = 4,
    seed: int = 0,
    skew: float = 1.5,
    name: str = "",
) -> GraphCSR:
    """Erdős–Rényi skeleton with skewed random vertex labels."""
    rng = np.random.default_rng(seed)
    k = int(m * 1.2) + 16
    e = rng.integers(0, n, size=(k, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]][:m]
    labels = random_labels(n, n_labels, seed=seed + 1, skew=skew)
    return GraphCSR.from_edges(n, e, labels=labels,
                               name=name or f"LER({n},{m},{n_labels})")


def load_edge_list(path: str, name: str = "") -> GraphCSR:
    """SNAP-style whitespace edge list; '#' comments allowed."""
    edges = np.loadtxt(path, dtype=np.int64, comments="#").reshape(-1, 2)
    n = int(edges.max()) + 1
    return GraphCSR.from_edges(n, edges, name=name or path)


# --------------------------------------------------------------------------
# Named synthetic stand-ins scaled like the paper's datasets (Table I).
# (wiki-vote 7.1K/100.8K, mico 96.6K/1.1M, patents 3.8M/16.5M, ...)
# Only the first two are sized for CPU-quick runs; the rest gate behind
# explicit benchmark flags.
# --------------------------------------------------------------------------
_NAMED = {
    "wiki-vote-syn": lambda: rmat(13, 12, seed=1, name="wiki-vote-syn"),
    "mico-syn": lambda: rmat(17, 11, seed=2, name="mico-syn"),
    "patents-syn": lambda: rmat(22, 4, seed=3, name="patents-syn"),
    "tiny-er": lambda: erdos_renyi(256, 2048, seed=4, name="tiny-er"),
    "small-rmat": lambda: rmat(10, 8, seed=5, name="small-rmat"),
    # Property-graph stand-ins: the questions benchmark pins tiny-labeled
    # (small enough for the brute-force oracle to answer every question).
    "tiny-labeled": lambda: labeled_er(
        256, 1536, n_labels=4, seed=11, name="tiny-labeled"),
    "small-labeled-rmat": lambda: labeled_rmat(
        10, 8, n_labels=4, seed=12, name="small-labeled-rmat"),
}


def named_dataset(name: str) -> GraphCSR:
    if name not in _NAMED:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(_NAMED)}")
    return _NAMED[name]()
