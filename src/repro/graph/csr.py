"""Sorted CSR graph storage (paper §IV-E: sorted neighborhoods, O(n+m)
merges) plus JAX device views.

The executor never materializes a dense [V, max_deg] matrix for the whole
graph; it gathers fixed-width neighbor windows per frontier row from the
flat CSR `indices` array (padded with a sentinel), and performs membership
tests with a vectorized per-segment binary search.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from functools import cached_property
from typing import NamedTuple

import numpy as np


class LabelView(NamedTuple):
    """Per-label CSR view over a shared label-grouped flat array.

    ``flat[starts[v, c] : starts[v, c] + lens[v, c]]`` is N(v) restricted
    to vertices of label c, sorted by id — so labeled candidate windows
    gather straight from contiguous segments and membership tests against
    full rows keep using the plain sorted CSR.
    """

    flat: np.ndarray            # [2m (+pad)] int32, rows grouped by label
    starts: np.ndarray          # [n, L] int32 absolute offsets into flat
    lens: np.ndarray            # [n, L] int32 segment lengths
    max_label_degree: np.ndarray  # [L] int32, max over v of lens[v, c]


@dataclass(frozen=True)
class GraphCSR:
    n: int                     # vertices
    m: int                     # undirected edges
    indptr: np.ndarray         # [n+1] int32
    indices: np.ndarray        # [2m (+pad)] int32, sorted per segment
    degrees: np.ndarray        # [n] int32
    name: str = ""
    labels: np.ndarray | None = None   # [n] int32 vertex labels, or None

    # ------------------------------------------------------------ construct
    @staticmethod
    def from_edges(
        n: int,
        edges: np.ndarray,
        *,
        relabel_by_degree: bool = False,
        name: str = "",
        labels: np.ndarray | None = None,
    ) -> "GraphCSR":
        """Build from an undirected edge array [E, 2]; dedups, drops
        self-loops, symmetrizes, sorts neighborhoods by vertex id.
        `labels` ([n] small non-negative ints) makes a property graph."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        edges = edges[edges[:, 0] != edges[:, 1]]
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        key = lo * n + hi
        _, uniq = np.unique(key, return_index=True)
        lo, hi = lo[uniq], hi[uniq]

        if labels is not None:
            labels = np.asarray(labels, dtype=np.int32)
            if labels.shape != (n,):
                raise ValueError(f"labels shape {labels.shape} != ({n},)")
            if len(labels) and labels.min() < 0:
                raise ValueError("vertex labels must be non-negative")

        if relabel_by_degree:
            deg = np.bincount(
                np.concatenate([lo, hi]), minlength=n
            )
            # densest-first relabel: new id 0 = highest degree.  With the
            # executor's strided task striping this balances per-device work
            # and makes `id(a) > id(b)` restrictions prune early.
            perm = np.argsort(-deg, kind="stable")
            inv = np.empty(n, dtype=np.int64)
            inv[perm] = np.arange(n)
            lo, hi = inv[lo], inv[hi]
            lo, hi = np.minimum(lo, hi), np.maximum(lo, hi)
            if labels is not None:
                labels = labels[perm]

        src = np.concatenate([lo, hi])
        dst = np.concatenate([hi, lo])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        degrees = np.bincount(src, minlength=n).astype(np.int32)
        indptr = np.zeros(n + 1, dtype=np.int32)
        np.cumsum(degrees, out=indptr[1:])
        # pad the flat array with sentinels so fixed-width windows starting
        # at indptr[v] never index past the end
        pad = int(degrees.max()) if len(degrees) and degrees.max() > 0 else 1
        indices = np.concatenate(
            [dst.astype(np.int32), np.full(pad, n, dtype=np.int32)]
        )
        return GraphCSR(
            n=n,
            m=len(lo),
            indptr=indptr,
            indices=indices,
            degrees=degrees,
            name=name,
            labels=labels,
        )

    # ------------------------------------------------------------ properties
    @cached_property
    def max_degree(self) -> int:
        return int(self.degrees.max()) if self.n else 0

    @cached_property
    def fingerprint(self) -> str:
        """Stable content hash of the adjacency structure (not the name).

        Cache keys in the query subsystem (DESIGN.md §5) use this to
        invalidate plans when the resident graph changes; two loads of
        the same edge list (any name) share one fingerprint."""
        h = hashlib.sha256()
        h.update(f"{self.n}|{self.m}|".encode())
        h.update(np.ascontiguousarray(self.indptr).tobytes())
        h.update(np.ascontiguousarray(self.indices[: self.indptr[-1]])
                 .tobytes())
        if self.labels is not None:
            # Same structure with different labels must never share a
            # plan-cache entry; unlabeled graphs keep historical digests.
            h.update(b"|labels|")
            h.update(np.ascontiguousarray(self.labels).tobytes())
        return h.hexdigest()

    @cached_property
    def n_labels(self) -> int:
        """Number of distinct label slots L (labels are 0..L-1); 0 if
        unlabeled."""
        if self.labels is None:
            return 0
        return int(self.labels.max()) + 1 if self.n else 0

    @cached_property
    def label_view(self) -> LabelView:
        """Per-label CSR view (see LabelView).  Labeled graphs only."""
        if self.labels is None:
            raise ValueError(f"graph {self.name!r} has no vertex labels")
        L = self.n_labels
        nnz = int(self.indptr[-1])
        flat = np.full(len(self.indices), self.n, dtype=np.int32)
        starts = np.zeros((self.n, L), dtype=np.int32)
        lens = np.zeros((self.n, L), dtype=np.int32)
        dst = self.indices[:nnz]
        dst_lab = self.labels[dst]
        # Stable sort within each row by destination label: rows are already
        # sorted by id, so each (row, label) segment stays sorted by id.
        row = np.repeat(np.arange(self.n, dtype=np.int64), self.degrees)
        order = np.lexsort((dst_lab, row))   # row-major, label-grouped
        flat[:nnz] = dst[order]
        # Segment bookkeeping: per (row, label) counts -> offsets.
        counts = np.zeros((self.n, L), dtype=np.int64)
        np.add.at(counts, (row, dst_lab.astype(np.int64)), 1)
        seg_starts = (
            self.indptr[:-1].astype(np.int64)[:, None]
            + np.concatenate(
                [np.zeros((self.n, 1), dtype=np.int64),
                 np.cumsum(counts, axis=1)[:, :-1]], axis=1)
        )
        starts[:] = seg_starts.astype(np.int32)
        lens[:] = counts.astype(np.int32)
        max_label_degree = (lens.max(axis=0) if self.n
                            else np.zeros(L, dtype=np.int32))
        return LabelView(flat=flat, starts=starts, lens=lens,
                         max_label_degree=max_label_degree.astype(np.int32))

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        nb = self.neighbors(u)
        i = np.searchsorted(nb, v)
        return bool(i < len(nb) and nb[i] == v)

    def triangle_count_numpy(self) -> int:
        """Exact triangle count via per-edge sorted intersection (numpy).
        Fine up to ~1M edges; bigger graphs use the JAX executor instead."""
        total = 0
        for u in range(self.n):
            nu = self.neighbors(u)
            nu = nu[nu > u]
            for v in nu:
                nv = self.neighbors(int(v))
                nv = nv[nv > v]
                total += int(np.intersect1d(nu, nv, assume_unique=True).size)
        return total

    def to_device(self):
        """Device arrays consumed by the executor."""
        import jax.numpy as jnp

        return {
            "indptr": jnp.asarray(self.indptr),
            "indices": jnp.asarray(self.indices),
            "degrees": jnp.asarray(self.degrees),
            "n": self.n,
            "max_degree": self.max_degree,
        }

    def edge_array(self) -> np.ndarray:
        """Undirected [m, 2] array (u < v)."""
        out = []
        for u in range(self.n):
            nb = self.neighbors(u)
            for v in nb[nb > u]:
                out.append((u, int(v)))
        return np.asarray(out, dtype=np.int64).reshape(-1, 2)


@dataclass(frozen=True)
class GraphView:
    """Overlay-aware CSR view (live/overlay.py builds these).

    Same duck type the executor consumes (`n`, `indptr`, `indices`,
    `degrees`, `max_degree`, `labels`, `fingerprint`) with two deliberate
    departures from `GraphCSR`:

      * `indptr` holds per-row STARTS, not prefix sums — a mutated
        (dirty) row points into the patch region appended after the base
        flat array, so `indptr[v + 1]` is NOT row v's end.  The executor
        already reads rows as ``[indptr[v], indptr[v] + degrees[v])``
        everywhere (gather windows, binary-search membership, kernel
        DMAs), so this is invisible to it; host code must use
        :meth:`neighbors`, never slice between consecutive offsets.
      * `max_degree` reports the overlay's fixed gather `window`, which
        over-provisions the true max degree by the mutation headroom.
        That keeps the candidate-window width — a static shape baked
        into every jitted/AOT count program — IDENTICAL across epochs,
        so a mutation swap never recompiles.

    `fingerprint` is a precomputed content key (the overlay's edge-delta
    digest, O(1) to read) rather than a hash of the arrays: views are
    rebuilt per epoch and per-round identity checks must not re-hash the
    adjacency (live/epoch.py, `no-stale-fingerprint` lint rule).
    """

    n: int                      # vertices
    m: int                      # undirected edges at this epoch
    indptr: np.ndarray          # [n+1] int32 row STARTS (see above)
    indices: np.ndarray         # [flat_capacity] int32, sentinel-padded
    degrees: np.ndarray         # [n] int32 row lengths
    window: int                 # static gather width (>= any row length)
    fingerprint: str            # content key: base ⊕ delta digest
    name: str = ""
    labels: np.ndarray | None = None    # live views are unlabeled

    @property
    def max_degree(self) -> int:
        """The static gather window, NOT the true max degree — every
        compiled count program bakes this in as the candidate width, so
        it must be epoch-stable (and ≥ every actual row length)."""
        return self.window

    def neighbors(self, v: int) -> np.ndarray:
        s = int(self.indptr[v])
        return self.indices[s : s + int(self.degrees[v])]

    def has_edge(self, u: int, v: int) -> bool:
        nb = self.neighbors(u)
        i = np.searchsorted(nb, v)
        return bool(i < len(nb) and nb[i] == v)

    def edge_array(self) -> np.ndarray:
        """Undirected [m, 2] array (u < v) — oracle verification reads
        the PATCHED rows, so it sees base ⊕ delta."""
        out = []
        for u in range(self.n):
            nb = self.neighbors(u)
            for v in nb[nb > u]:
                out.append((u, int(v)))
        return np.asarray(out, dtype=np.int64).reshape(-1, 2)
