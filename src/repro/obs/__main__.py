"""CLI front door: `python -m repro.obs summarize trace.json ...`."""
from __future__ import annotations

import sys

from repro.obs.summarize import main as summarize_main


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv or argv[0] in ("-h", "--help"):
        print("usage: python -m repro.obs summarize TRACE.json "
              "[--top N] [--min-coverage X]", file=sys.stderr)
        return 0 if argv else 1
    cmd, rest = argv[0], argv[1:]
    if cmd == "summarize":
        return summarize_main(rest)
    print(f"repro.obs: unknown command {cmd!r} (expected 'summarize')",
          file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
