"""Shared `--trace` wiring for the serving/mining CLIs.

Every front door (`launch/gateway.py`, `launch/query_serve.py`,
`launch/mine.py`) takes the same three flags:

    --trace OUT.json     enable the tracer, export a Perfetto-loadable
                         trace on exit (inspect with
                         `python -m repro.obs summarize OUT.json` or
                         https://ui.perfetto.dev)
    --trace-sync         also fence per-level executor spans with
                         block_until_ready (real device time per level;
                         serializes the dispatch pipeline — opt-in)
    --metrics OUT.json   dump a MetricsRegistry snapshot on exit

`add_trace_args` registers them; `start_tracing` installs the tracer
(also honouring REPRO_TRACE already set in the environment);
`finish_tracing` exports the artifacts and prints one status line each.
"""
from __future__ import annotations

import json

from repro.obs.trace import Tracer, get_tracer, set_tracer

__all__ = ["add_trace_args", "start_tracing", "finish_tracing"]


def add_trace_args(ap) -> None:
    g = ap.add_argument_group("observability")
    g.add_argument("--trace", default="", metavar="OUT.json",
                   help="export a Chrome/Perfetto trace of this run "
                        "(summarize: python -m repro.obs summarize)")
    g.add_argument("--trace-sync", action="store_true",
                   help="with --trace: fence per-level executor spans "
                        "(block_until_ready) so span durations are real "
                        "device time — serializes the hot path")
    g.add_argument("--metrics", default="", metavar="OUT.json",
                   help="dump the metrics-registry snapshot as JSON")


def start_tracing(args) -> Tracer:
    """Install the process tracer per the CLI flags.  Without `--trace`
    the env-configured tracer (REPRO_TRACE) stays as-is, so the flags
    only ever widen observability."""
    if args.trace:
        return set_tracer(Tracer(enabled=True, sync=args.trace_sync))
    return get_tracer()


def finish_tracing(args, *, registry=None, tag: str = "obs") -> None:
    """Export `--trace` / `--metrics` artifacts (no-op without flags)."""
    if args.trace:
        n = get_tracer().export_chrome(args.trace)
        print(f"[{tag}] trace: {n} spans -> {args.trace}")
    if args.metrics and registry is not None:
        with open(args.metrics, "w") as f:
            json.dump(registry.snapshot(), f, indent=1, default=str,
                      sort_keys=True)
        print(f"[{tag}] metrics snapshot -> {args.metrics}")
