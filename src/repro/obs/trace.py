"""Tracer — nested structured spans with Perfetto/JSONL export.

One process-wide span timeline (DESIGN.md §7): every subsystem opens
spans through the module-level tracer —

    from repro.obs import get_tracer
    with get_tracer().span("engine.round", tickets=4) as sp:
        ...
        sp.set(coalesced=2)

and a launcher that wants a trace swaps in an enabled tracer
(`set_tracer(Tracer(enabled=True))`, or the `--trace` flag on the
serving CLIs) and exports `trace.json` at exit.  Span names are
dot-namespaced `subsystem.what` (taxonomy table in DESIGN.md §7); the
part before the first dot becomes the Chrome/Perfetto category.

Design constraints, in order:

  * NEAR-ZERO COST WHEN DISABLED.  `span()` on a disabled tracer
    returns one shared no-op context manager — no Span allocation, no
    clock read, no lock (< 1µs per call, asserted in tests/test_obs.py)
    — so instrumentation stays compiled into the hot paths permanently.
  * THREAD-SAFE NESTING.  The current-span stack is thread-local (each
    thread gets its own parent chain; spans never parent across
    threads) and finished spans append to one lock-guarded list.
  * JAX-FREE.  serve/scheduler.py imports this module and is linted to
    never touch JAX; everything here is stdlib.

The exporter writes the Chrome trace-event format (`ph: "X"` complete
events with microsecond timestamps) wrapped as {"traceEvents": [...]},
which both `chrome://tracing` and https://ui.perfetto.dev load
directly; `export_jsonl` writes one span record per line for ad-hoc
`jq`-style analysis.  `python -m repro.obs summarize trace.json` prints
the self-time breakdown.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time

__all__ = [
    "Span",
    "Timer",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "timer",
]


class Timer:
    """Sanctioned monotonic stopwatch for serving-path counters.

    The repo lint (`no-raw-timing`) forbids raw ``time.perf_counter()``
    in `serve/` and `query/`: durations that feed *metrics* must come
    from here (or from a span), so there is exactly one clock and one
    place to audit.  Usage::

        with timer() as t:
            work()
        stats.seconds += t.seconds
    """

    __slots__ = ("seconds", "_t0")

    def __enter__(self) -> "Timer":
        self.seconds = 0.0
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.seconds = time.perf_counter() - self._t0
        return False


def timer() -> Timer:
    return Timer()


class _NopSpan:
    """Shared do-nothing span: the entire disabled-tracer cost."""

    __slots__ = ()

    def __enter__(self) -> "_NopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NopSpan":
        return self


_NOP = _NopSpan()


class Span:
    """One live span.  Use as a context manager; `set()` attaches
    attributes discovered mid-span (they export under `args`)."""

    __slots__ = ("_tracer", "name", "attrs", "span_id", "parent_id",
                 "tid", "t0_ns", "dur_ns")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = 0
        self.parent_id = None
        self.tid = 0
        self.t0_ns = 0
        self.dur_ns = 0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    @property
    def duration_s(self) -> float:
        return self.dur_ns / 1e9

    def __enter__(self) -> "Span":
        tr = self._tracer
        stack = tr._stack()
        self.span_id = next(tr._ids)
        self.parent_id = stack[-1].span_id if stack else None
        self.tid = threading.get_ident()
        stack.append(self)
        self.t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        self.dur_ns = time.perf_counter_ns() - self.t0_ns
        stack = self._tracer._stack()
        # tolerate exotic exits (a span leaked past its parent's exit):
        # unwind to self so one bad caller can't corrupt the whole stack
        while stack and stack[-1] is not self:
            stack.pop()
        if stack:
            stack.pop()
        self._tracer._finish(self)
        return False


class Tracer:
    """Span collector.  `enabled=False` (the default process tracer) is
    the production mode: every `span()` call returns the shared no-op.

    `sync` requests device-fenced per-level executor spans (the
    `--trace-sync` flag): the executor inserts `block_until_ready`
    fences so span durations are real device time — strictly opt-in
    because fencing serializes the dispatch pipeline.

    `max_spans` bounds memory on long serving runs; once full, new
    spans are counted in `dropped` instead of recorded.
    """

    def __init__(self, *, enabled: bool = True, sync: bool = False,
                 max_spans: int = 1_000_000):
        self.enabled = enabled
        self.sync = sync
        self.max_spans = max_spans
        self.dropped = 0
        self.epoch_ns = time.perf_counter_ns()
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._spans: list[dict] = []

    # ------------------------------------------------------------ recording
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **attrs):
        """Open a span (context manager).  Keyword arguments become
        structured attributes; add more later with `.set(...)`."""
        if not self.enabled:
            return _NOP
        return Span(self, name, attrs)

    def _finish(self, span: Span) -> None:
        rec = {
            "name": span.name,
            "id": span.span_id,
            "parent": span.parent_id,
            "tid": span.tid,
            "t0_ns": span.t0_ns - self.epoch_ns,
            "dur_ns": span.dur_ns,
            "attrs": span.attrs,
        }
        with self._lock:
            if len(self._spans) >= self.max_spans:
                self.dropped += 1
            else:
                self._spans.append(rec)

    # ------------------------------------------------------------ reading
    def spans(self) -> list[dict]:
        with self._lock:
            return list(self._spans)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0

    # ------------------------------------------------------------ export
    def chrome_events(self) -> list[dict]:
        """Spans as Chrome trace-event dicts (`ph: "X"` complete events,
        microsecond floats, span ids threaded through `args`)."""
        pid = os.getpid()
        out = []
        for s in self.spans():
            out.append({
                "name": s["name"],
                "cat": s["name"].split(".", 1)[0],
                "ph": "X",
                "ts": s["t0_ns"] / 1e3,
                "dur": s["dur_ns"] / 1e3,
                "pid": pid,
                "tid": s["tid"],
                "args": {"id": s["id"], "parent": s["parent"],
                         **s["attrs"]},
            })
        return out

    def export_chrome(self, path: str) -> int:
        """Write a Perfetto/chrome://tracing-loadable trace.json;
        returns the number of events written."""
        events = self.chrome_events()
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        if self.dropped:
            doc["otherData"] = {"dropped_spans": self.dropped}
        with open(path, "w") as f:
            json.dump(doc, f, default=str)
        return len(events)

    def export_jsonl(self, path: str) -> int:
        """One span record per line (raw ns timestamps + attrs)."""
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s, default=str) + "\n")
        return len(spans)


def _from_env() -> Tracer:
    # REPRO_TRACE=1 pre-enables tracing before any code runs (the
    # benchmark harness path); REPRO_TRACE_SYNC=1 adds device fencing.
    on = os.environ.get("REPRO_TRACE", "") not in ("", "0")
    sync = os.environ.get("REPRO_TRACE_SYNC", "") not in ("", "0")
    return Tracer(enabled=on, sync=sync)


_tracer = _from_env()


def get_tracer() -> Tracer:
    """The process tracer.  Instrumented code calls this at span-open
    time (never caches it), so launchers/tests can swap tracers at any
    point with `set_tracer`."""
    return _tracer


def set_tracer(tracer: Tracer) -> Tracer:
    global _tracer
    _tracer = tracer
    return tracer
