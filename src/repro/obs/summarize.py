"""Self-time breakdown of a Chrome/Perfetto trace.json.

    python -m repro.obs summarize trace.json [--top N] [--min-coverage X]

Rebuilds the span tree from the `args.id`/`args.parent` links our
exporter threads through each event, computes per-name self time
(span duration minus the duration of its direct children), and prints
a table sorted by total self time.  Exits nonzero when the trace is
missing, malformed, or empty — bench_smoke.sh uses that as its trace
sanity gate — and, with `--min-coverage`, when leaf spans attribute
less than the given fraction of wall time (the acceptance bar for the
instrumentation being dense enough to localize a slow query).
"""
from __future__ import annotations

import json
import sys

__all__ = ["summarize", "main"]


def summarize(doc: dict) -> dict:
    """Reduce a chrome-trace doc to the summary the CLI prints.

    Returns {"events", "wall_us", "leaf_us", "leaf_coverage", "rows"}
    where rows is [{name, count, total_us, self_us, leaf}] sorted by
    self_us descending.  Raises ValueError on malformed input.
    """
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a chrome trace: missing 'traceEvents'")
    events = [e for e in doc["traceEvents"]
              if isinstance(e, dict) and e.get("ph") == "X"]
    if not events:
        raise ValueError("trace contains no complete ('X') span events")

    child_dur: dict[int, float] = {}
    for e in events:
        try:
            dur = float(e["dur"])
            args = e.get("args") or {}
            parent = args.get("parent")
        except (KeyError, TypeError, ValueError) as exc:
            raise ValueError(f"malformed event {e!r}: {exc}") from exc
        if parent is not None:
            child_dur[parent] = child_dur.get(parent, 0.0) + dur

    per_name: dict[str, dict] = {}
    wall_us = 0.0   # duration of root spans only (no double counting)
    leaf_us = 0.0
    for e in events:
        dur = float(e["dur"])
        args = e.get("args") or {}
        sid = args.get("id")
        self_us = dur - child_dur.get(sid, 0.0)
        is_leaf = sid not in child_dur
        if args.get("parent") is None:
            wall_us += dur
        if is_leaf:
            leaf_us += dur
        row = per_name.setdefault(
            e.get("name", "?"),
            {"count": 0, "total_us": 0.0, "self_us": 0.0, "leaf": is_leaf})
        row["count"] += 1
        row["total_us"] += dur
        row["self_us"] += max(self_us, 0.0)
        row["leaf"] = row["leaf"] and is_leaf

    rows = [{"name": k, **v} for k, v in per_name.items()]
    rows.sort(key=lambda r: -r["self_us"])
    coverage = (leaf_us / wall_us) if wall_us > 0 else 0.0
    return {"events": len(events), "wall_us": wall_us, "leaf_us": leaf_us,
            "leaf_coverage": coverage, "rows": rows}


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.2f}ms"
    return f"{us:.0f}us"


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs summarize",
        description="Self-time breakdown of an obs trace.json")
    ap.add_argument("trace", help="path to a Chrome/Perfetto trace.json")
    ap.add_argument("--top", type=int, default=20,
                    help="rows to print (default 20)")
    ap.add_argument("--min-coverage", type=float, default=None,
                    help="fail unless leaf spans cover at least this "
                         "fraction of root wall time (e.g. 0.95)")
    args = ap.parse_args(argv)

    try:
        with open(args.trace) as f:
            doc = json.load(f)
        summ = summarize(doc)
    except (OSError, json.JSONDecodeError, ValueError) as exc:
        print(f"summarize: bad trace {args.trace}: {exc}", file=sys.stderr)
        return 1

    total_self = sum(r["self_us"] for r in summ["rows"]) or 1.0
    print(f"trace: {args.trace}")
    print(f"  events={summ['events']}  wall={_fmt_us(summ['wall_us'])}  "
          f"leaf_coverage={summ['leaf_coverage']:.1%}")
    print(f"  {'span':<28} {'n':>6} {'total':>10} {'self':>10} {'self%':>7}")
    for r in summ["rows"][:args.top]:
        mark = "*" if r["leaf"] else " "
        print(f"  {r['name']:<27}{mark} {r['count']:>6} "
              f"{_fmt_us(r['total_us']):>10} {_fmt_us(r['self_us']):>10} "
              f"{r['self_us'] / total_self:>6.1%}")
    if len(summ["rows"]) > args.top:
        print(f"  ... {len(summ['rows']) - args.top} more span names")
    print("  (* = leaf span)")

    if args.min_coverage is not None and \
            summ["leaf_coverage"] < args.min_coverage:
        print(f"summarize: leaf coverage {summ['leaf_coverage']:.1%} "
              f"< required {args.min_coverage:.1%}", file=sys.stderr)
        return 2
    return 0
