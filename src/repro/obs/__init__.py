"""repro.obs — tracing and metrics for the whole serving stack.

Two planes (DESIGN.md §7):

  * spans   — `get_tracer().span("engine.plan", pattern=key)` nest into
              a timeline exportable as Perfetto `trace.json`;
  * metrics — `MetricsRegistry` counters/gauges/histograms keyed
              `subsystem.metric{labels}`, one `snapshot()` per engine
              or gateway, `latency_summary()` as the single percentile
              dict shape.

Stdlib-only by contract: serve/scheduler.py imports this and the lint
keeps that module free of JAX (and this one free of numpy).
"""
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    latency_summary,
)
from repro.obs.trace import (
    Span,
    Timer,
    Tracer,
    get_tracer,
    set_tracer,
    timer,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Timer",
    "Tracer",
    "get_tracer",
    "latency_summary",
    "set_tracer",
    "timer",
]
