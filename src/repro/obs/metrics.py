"""MetricsRegistry — one snapshot for every subsystem's counters.

Before this layer each subsystem kept its own stats object with its own
reporting convention: `CacheStats` (query/cache.py), `StoreStats`
(query/store.py), the engine's latency list, the gateway's hand-rolled
percentile dicts.  The registry unifies the *reporting* plane without
disturbing the storage plane: dataclass stats objects keep their fields
(they're part of each subsystem's API), and a registered collector
merges them into the snapshot at read time.

Naming convention (DESIGN.md §7): keys are
``subsystem.metric{label=value,...}`` — e.g.

    cache.hits
    engine.query_latency_ms{...percentile summary...}
    scheduler.turn_item_ms{phase=contended,workload=graph}

Live engines (query/engine.py with a delta overlay) contribute a
``live.*`` family: ``live.epoch`` / ``live.stats_epoch`` (current edge
and stats epochs), ``live.overlay_edges``, ``live.compactions``,
``live.mutations_applied``, ``live.pending_mutations``,
``live.matcher_rebinds`` / ``live.matcher_rebuilds`` (epoch swaps that
reused vs recompiled the resident matchers), and the maintainer's
counters (``live.memo_hits``, ``live.incremental_hits``,
``live.full_recounts``, ``live.memo_invalidations``,
``live.spans_reused``, ``live.spans_recomputed``).

Histograms are deterministic bounded reservoirs: when full, the
reservoir thins by doubling its sampling stride (keep every 2nd, then
every 4th, ...) instead of random eviction — the scheduler path is
linted against nondeterminism, so no RNG anywhere here.  Percentiles
use linear interpolation (numpy's default), so snapshots are drop-in
replacements for the np.percentile dicts they retire.

Everything is stdlib: serve/scheduler.py imports this and must stay
JAX- and numpy-free.
"""
from __future__ import annotations

import math
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "latency_summary",
]


def _key(name: str, labels: dict | None) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def percentile(sorted_vals: list[float], q: float) -> float:
    """Linear-interpolation percentile over pre-sorted values, matching
    np.percentile(..., q) so retired numpy call sites keep their
    numbers bit-for-bit on identical samples."""
    n = len(sorted_vals)
    if n == 0:
        raise ValueError("percentile of empty sample")
    if n == 1:
        return sorted_vals[0]
    pos = (q / 100.0) * (n - 1)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    if lo == hi:
        return sorted_vals[lo]
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


class Counter:
    """Monotonic (well — resettable-window) float/int counter."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def reset(self) -> None:
        with self._lock:
            self.value = 0.0


class Gauge:
    """Last-write-wins scalar (frontier size, capacity, queue depth)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = value


class Histogram:
    """Bounded-reservoir histogram with deterministic decimation.

    Records every observation until `max_samples`, then halves the
    reservoir by keeping every other retained sample and doubles the
    sampling stride for future observations.  Total count and sum are
    exact regardless; percentiles are computed over the reservoir.
    No randomness — identical observation sequences yield identical
    snapshots (the scheduler path is linted deterministic).
    """

    __slots__ = ("max_samples", "count", "total", "_samples", "_stride",
                 "_phase", "_lock")

    def __init__(self, max_samples: int = 4096):
        if max_samples < 2:
            raise ValueError("max_samples must be >= 2")
        self.max_samples = max_samples
        self.count = 0
        self.total = 0.0
        self._samples: list[float] = []
        self._stride = 1
        self._phase = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        with self._lock:
            self.count += 1
            self.total += value
            self._phase += 1
            if self._phase >= self._stride:
                self._phase = 0
                self._samples.append(value)
                if len(self._samples) >= self.max_samples:
                    self._samples = self._samples[::2]
                    self._stride *= 2

    def reset(self) -> None:
        with self._lock:
            self.count = 0
            self.total = 0.0
            self._samples = []
            self._stride = 1
            self._phase = 0

    def summary(self) -> dict:
        """The unified latency dict: n / p50 / p95 / p99 / mean, in the
        same unit the observations were recorded in."""
        with self._lock:
            n = self.count
            total = self.total
            samples = sorted(self._samples)
        if n == 0 or not samples:
            return {"n": 0, "p50": 0.0, "p95": 0.0, "p99": 0.0,
                    "mean": 0.0}
        return {
            "n": n,
            "p50": percentile(samples, 50),
            "p95": percentile(samples, 95),
            "p99": percentile(samples, 99),
            "mean": total / n,
        }


def latency_summary(hist: Histogram) -> dict:
    """Millisecond-keyed summary of a Histogram that observed ms values
    — the one percentile dict shape shared by engine, gateway, and the
    benchmarks (retires `engine.latency_percentiles` and the gateway's
    `_pcts`, whose key sets had drifted apart)."""
    s = hist.summary()
    return {
        "n": s["n"],
        "p50_ms": s["p50"],
        "p95_ms": s["p95"],
        "p99_ms": s["p99"],
        "mean_ms": s["mean"],
    }


class MetricsRegistry:
    """Namespace of counters/gauges/histograms plus read-time collectors.

    Instruments get-or-create by (name, labels); `snapshot()` returns a
    flat dict keyed `subsystem.metric{labels}`.  Subsystems whose stats
    already live in dataclasses (CacheStats, StoreStats) register a
    collector — a zero-arg callable returning {metric_name: value} —
    merged into every snapshot, so the registry is the single pane of
    glass without duplicating counter storage.

    Registries are per-engine/per-gateway, not process-global:
    benchmarks/run.py executes several benchmark mains in one process
    and each must see a clean window.  Launchers that want one pane
    share a single instance explicitly.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._collectors: list = []

    # ---------------------------------------------------------- instruments
    def counter(self, name: str, **labels) -> Counter:
        k = _key(name, labels)
        with self._lock:
            c = self._counters.get(k)
            if c is None:
                c = self._counters[k] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = _key(name, labels)
        with self._lock:
            g = self._gauges.get(k)
            if g is None:
                g = self._gauges[k] = Gauge()
        return g

    def histogram(self, name: str, max_samples: int = 4096,
                  **labels) -> Histogram:
        k = _key(name, labels)
        with self._lock:
            h = self._histograms.get(k)
            if h is None:
                h = self._histograms[k] = Histogram(max_samples)
        return h

    def register_collector(self, fn) -> None:
        """fn() -> {metric_name: scalar} merged into each snapshot."""
        with self._lock:
            self._collectors.append(fn)

    # ------------------------------------------------------------- reading
    def snapshot(self) -> dict:
        """Flat {key: value} view: counters/gauges as scalars,
        histograms as their summary dicts, collectors merged last."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
            collectors = list(self._collectors)
        out: dict = {}
        for k, c in counters.items():
            out[k] = c.value
        for k, g in gauges.items():
            out[k] = g.value
        for k, h in hists.items():
            out[k] = h.summary()
        for fn in collectors:
            out.update(fn())
        return out

    def reset_window(self) -> None:
        """Zero every counter and histogram (gauges keep last value —
        they describe current state, not a window).  Both the engine and
        the gateway expose this so benchmark phases (warmup vs measured)
        reset the same window the same way."""
        with self._lock:
            counters = list(self._counters.values())
            hists = list(self._histograms.values())
        for c in counters:
            c.reset()
        for h in hists:
            h.reset()
