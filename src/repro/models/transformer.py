"""Model assembly for every assigned architecture family.

One parameter pytree layout per family; homogeneous layer stacks are
jax.lax.scan-ed (keeps HLO/compile size O(1) in depth — essential for the
88-layer dry-runs), jamba scans over 8-layer superblocks.

Public API (family-agnostic):
    init(cfg, key)                         -> params
    loss_fn(cfg, remat=...)(params, batch) -> (loss, metrics)
    prefill_fn(cfg)(params, batch)         -> (last_logits, cache)
    decode_fn(cfg)(params, tokens, cache, pos) -> (logits, cache)

Batches (see configs.input_specs):
    train : {"tokens" | "embeds", "labels", ["positions3"], ["enc_embeds"]}
    prefill: same minus labels
    decode: {"tokens" [B,1]} + cache pytree
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import layers as L
from .layers import Params, cast, dense, init_dense, init_mlp, init_rmsnorm, rms_norm, swiglu_mlp
from .mamba2 import (
    init_mamba, init_mamba_state, mamba_block, mamba_decode_step,
)
from .moe import init_moe, moe_dense, moe_sorted


# ===========================================================================
# layer classification
# ===========================================================================
def layer_kinds(cfg) -> list[str]:
    """Per-layer mixer kind: 'attn' or 'ssm'."""
    if cfg.family == "ssm":
        return ["ssm"] * cfg.n_layers
    if cfg.family == "hybrid":
        assert cfg.attn_every > 0
        return [
            "attn" if i % cfg.attn_every == cfg.attn_every - 1 else "ssm"
            for i in range(cfg.n_layers)
        ]
    return ["attn"] * cfg.n_layers


def mlp_kinds(cfg) -> list[str]:
    """Per-layer MLP kind: 'dense', 'moe' or 'none'."""
    out = []
    for i in range(cfg.n_layers):
        if cfg.family == "ssm":
            out.append("none")
        elif cfg.n_experts and i % cfg.moe_every == cfg.moe_every - 1:
            out.append("moe")
        elif cfg.d_ff:
            out.append("dense")
        else:
            out.append("none")
    return out


def _block_len(cfg) -> int:
    """Layers per scanned superblock (1 for homogeneous stacks)."""
    kinds = list(zip(layer_kinds(cfg), mlp_kinds(cfg)))
    for blk in range(1, cfg.n_layers + 1):
        if cfg.n_layers % blk:
            continue
        pattern = kinds[:blk]
        if all(
            kinds[i * blk : (i + 1) * blk] == pattern
            for i in range(cfg.n_layers // blk)
        ):
            return blk
    return cfg.n_layers


# ===========================================================================
# init
# ===========================================================================
def _init_block(cfg, key, kinds_one_block):
    """One superblock's params: dict keyed by position-in-block."""
    p = {}
    for j, (mix, mlp) in enumerate(kinds_one_block):
        kj = jax.random.fold_in(key, j)
        k1, k2, k3, k4 = jax.random.split(kj, 4)
        lp = {"norm1": init_rmsnorm(cfg.d_model)}
        if mix == "attn":
            lp["attn"] = L.init_attention(k1, cfg)
        else:
            lp["ssm"] = init_mamba(k1, cfg)
        if mlp != "none":
            lp["norm2"] = init_rmsnorm(cfg.d_model)
            lp["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff) if mlp == "dense" \
                else init_moe(k2, cfg)
        p[f"l{j}"] = lp
    return p


def init(cfg, key) -> Params:
    ks = jax.random.split(key, 8)
    blk = _block_len(cfg)
    kinds = list(zip(layer_kinds(cfg), mlp_kinds(cfg)))
    n_blocks = cfg.n_layers // blk
    block_keys = jax.random.split(ks[0], n_blocks)
    params: Params = {
        "embed": {
            "w": jax.random.normal(
                ks[1], (cfg.vocab, cfg.d_model), jnp.float32
            ) * 0.02
        },
        "final_norm": init_rmsnorm(cfg.d_model),
        "blocks": jax.vmap(
            lambda k: _init_block(cfg, k, kinds[:blk])
        )(block_keys),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(ks[2], cfg.d_model, cfg.vocab, scale=0.02)
    if cfg.family == "encdec":
        enc_keys = jax.random.split(ks[3], cfg.enc_layers)
        params["encoder"] = jax.vmap(
            lambda k: {
                "norm1": init_rmsnorm(cfg.d_model),
                "attn": L.init_attention(jax.random.fold_in(k, 0), cfg),
                "norm2": init_rmsnorm(cfg.d_model),
                "mlp": init_mlp(jax.random.fold_in(k, 1), cfg.d_model, cfg.d_ff),
            }
        )(enc_keys)
        params["enc_final_norm"] = init_rmsnorm(cfg.d_model)
        dec_keys = jax.random.split(ks[4], cfg.n_layers)
        params["cross"] = jax.vmap(
            lambda k: {
                "norm": init_rmsnorm(cfg.d_model),
                "attn": L.init_attention(k, cfg),
            }
        )(dec_keys)
    return params


# ===========================================================================
# forward building blocks
# ===========================================================================
def _apply_block(cfg, bp, x, kinds_one_block, dtype, positions, positions3,
                 ssm_states=None, q_chunk=0):
    """Apply one superblock.  ssm_states: dict pos->state (prefill capture)."""
    new_states = {}
    for j, (mix, mlp) in enumerate(kinds_one_block):
        lp = bp[f"l{j}"]
        h = rms_norm(lp["norm1"], x, cfg.norm_eps)
        if mix == "attn":
            h = L.attention(
                lp["attn"], h, cfg, dtype,
                causal=True, positions=positions, positions3=positions3,
                q_chunk=q_chunk,
            )
        else:
            init_s = None if ssm_states is None else ssm_states.get(f"l{j}")
            h, st = mamba_block(lp["ssm"], h, cfg, dtype, initial_state=init_s)
            new_states[f"l{j}"] = st
        x = x + h
        if mlp != "none":
            h = rms_norm(lp["norm2"], x, cfg.norm_eps)
            if mlp == "dense":
                h = swiglu_mlp(lp["mlp"], h, dtype)
            else:
                h, _aux = moe_sorted(lp["mlp"], h, cfg, dtype)
            x = x + h
    return x, new_states


def _backbone(cfg, params, x, dtype, positions, positions3, remat=False,
              q_chunk=0):
    """Scan the decoder stack over superblocks.  x [B,S,d] -> [B,S,d]."""
    blk = _block_len(cfg)
    kinds = list(zip(layer_kinds(cfg), mlp_kinds(cfg)))[:blk]

    def body(h, bp):
        h, _ = _apply_block(
            cfg, bp, h, kinds, dtype, positions, positions3, q_chunk=q_chunk
        )
        return h, ()

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return x


def _encoder(cfg, params, enc_embeds, dtype, q_chunk=0, flash=False):
    """Whisper-style bidirectional encoder over (stub) frame embeddings."""
    B, S, d = enc_embeds.shape
    x = enc_embeds.astype(dtype) + L.sinusoidal_positions(S, d).astype(dtype)

    def body(h, lp):
        a = rms_norm(lp["norm1"], h, cfg.norm_eps)
        a = L.attention(lp["attn"], a, cfg, dtype, causal=False,
                        positions=None, q_chunk=q_chunk, flash=flash)
        h = h + a
        m = rms_norm(lp["norm2"], h, cfg.norm_eps)
        h = h + swiglu_mlp(lp["mlp"], m, dtype)
        return h, ()

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(params["enc_final_norm"], x, cfg.norm_eps)


def _decdec_backbone(cfg, params, x, enc_out, dtype, positions, remat=False,
                     q_chunk=0, flash=False):
    """Enc-dec decoder: self-attn blocks interleaved with cross-attn.

    Layers are homogeneous → scan over (block, cross) jointly."""
    kinds = list(zip(layer_kinds(cfg), mlp_kinds(cfg)))[:1]

    def body(h, bp_cp):
        bp, cp = bp_cp
        lp = bp["l0"]
        a = rms_norm(lp["norm1"], h, cfg.norm_eps)
        a = L.attention(lp["attn"], a, cfg, dtype, causal=True,
                        positions=positions, q_chunk=q_chunk, flash=flash)
        h = h + a
        c = rms_norm(cp["norm"], h, cfg.norm_eps)
        kv = L.enc_kv(cp["attn"], enc_out, cfg, dtype)
        h = h + L.cross_attention(cp["attn"], c, kv, cfg, dtype,
                                  q_chunk=q_chunk, flash=flash)
        m = rms_norm(lp["norm2"], h, cfg.norm_eps)
        h = h + swiglu_mlp(lp["mlp"], m, dtype)
        return h, ()

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["blocks"], params["cross"]))
    return x


def _embed_in(cfg, params, batch, dtype):
    """Token or stub-frontend embedding input + positions."""
    if "embeds" in batch:                       # vlm/audio stub frontend
        x = batch["embeds"].astype(dtype)
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = cast(params["embed"]["w"], dtype)[tokens]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    positions3 = batch.get("positions3")
    return x, positions, positions3


def _logits(cfg, params, x, dtype):
    if cfg.tie_embeddings:
        w = cast(params["embed"]["w"], dtype).T
    else:
        w = cast(params["lm_head"]["w"], dtype)
    return x @ w


# ===========================================================================
# public entry points
# ===========================================================================
def _xent(cfg, params, x, labels, dtype, loss_chunk: int = 0):
    """Token NLL sum + count; optionally chunked over the sequence so the
    [B, S, V] fp32 logits never fully materialize (large-vocab configs)."""

    def piece(xc, lc):
        logits = _logits(cfg, params, xc, dtype).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum(nll * mask), mask.sum()

    B, S = labels.shape
    if loss_chunk and S % loss_chunk == 0 and S > loss_chunk:
        n = S // loss_chunk
        xs = x.reshape(B, n, loss_chunk, -1).transpose(1, 0, 2, 3)
        ls = labels.reshape(B, n, loss_chunk).transpose(1, 0, 2)

        def body(carry, inp):
            tot, cnt = carry
            s, c = piece(*inp)
            return (tot + s, cnt + c), ()

        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                     (xs, ls))
        return tot, cnt
    return piece(x, labels)


def loss_fn(cfg, *, remat: bool = False, q_chunk: int = 0,
            loss_chunk: int = 0) -> Callable:
    dtype = jnp.dtype(cfg.dtype)

    def loss(params, batch):
        x, positions, positions3 = _embed_in(cfg, params, batch, dtype)
        if cfg.family == "encdec":
            enc_out = _encoder(cfg, params, batch["enc_embeds"], dtype,
                               q_chunk=q_chunk)
            x = _decdec_backbone(cfg, params, x, enc_out, dtype, positions,
                                 remat=remat, q_chunk=q_chunk)
        else:
            x = _backbone(cfg, params, x, dtype, positions, positions3,
                          remat=remat, q_chunk=q_chunk)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        tot, cnt = _xent(cfg, params, x, batch["labels"], dtype, loss_chunk)
        loss = tot / jnp.maximum(cnt, 1.0)
        return loss, {"loss": loss, "tokens": cnt}

    return loss


# ------------------------------------------------------------- serving ----
def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Decode cache pytree (stacked over scan blocks)."""
    blk = _block_len(cfg)
    n_blocks = cfg.n_layers // blk
    kinds = list(zip(layer_kinds(cfg), mlp_kinds(cfg)))[:blk]
    per_block: dict[str, Any] = {}
    for j, (mix, _) in enumerate(kinds):
        if mix == "attn":
            per_block[f"l{j}"] = {
                "k": jnp.zeros(
                    (n_blocks, batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                    dtype,
                ),
                "v": jnp.zeros(
                    (n_blocks, batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                    dtype,
                ),
            }
        else:
            st = init_mamba_state(cfg, batch)
            per_block[f"l{j}"] = jax.tree.map(
                lambda a: jnp.zeros((n_blocks,) + a.shape, a.dtype), st
            )
    cache = {"blocks": per_block}
    if cfg.family == "encdec":
        cache["cross_kv"] = jnp.zeros(
            (cfg.n_layers, 2, batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
            dtype,
        )
    return cache


def decode_fn(cfg) -> Callable:
    """One-token decode step: (params, tokens [B,1], cache, pos) ->
    (logits [B,V], cache)."""
    dtype = jnp.dtype(cfg.dtype)
    blk = _block_len(cfg)
    kinds = list(zip(layer_kinds(cfg), mlp_kinds(cfg)))[:blk]

    def step(params, tokens, cache, pos):
        B = tokens.shape[0]
        x = cast(params["embed"]["w"], dtype)[tokens]          # [B,1,d]

        def body(h, scan_in):
            bp, blk_cache = scan_in[0], scan_in[1]
            new_cache = {}
            for j, (mix, mlp) in enumerate(kinds):
                lp = bp[f"l{j}"]
                a = rms_norm(lp["norm1"], h, cfg.norm_eps)
                if mix == "attn":
                    a, ck, cv = L.attention_decode(
                        lp["attn"], a, blk_cache[f"l{j}"]["k"],
                        blk_cache[f"l{j}"]["v"], pos, cfg, dtype,
                    )
                    new_cache[f"l{j}"] = {"k": ck, "v": cv}
                else:
                    a, st = mamba_decode_step(
                        lp["ssm"], a, blk_cache[f"l{j}"], cfg, dtype
                    )
                    new_cache[f"l{j}"] = st
                h = h + a
                if mlp != "none":
                    m = rms_norm(lp["norm2"], h, cfg.norm_eps)
                    if mlp == "dense":
                        m = swiglu_mlp(lp["mlp"], m, dtype)
                    else:
                        # decode uses DROPLESS routing: a decode step sees
                        # k·B token-expert pairs (tiny), and capacity-drop
                        # semantics would make decode diverge from prefill
                        m, _ = moe_dense(lp["mlp"], m, cfg, dtype)
                    h = h + m
            return h, new_cache

        if cfg.family == "encdec":
            # decoder-only step with precomputed cross-attention K/V
            def body_encdec(h, scan_in):
                bp, cp, blk_cache, ckv = scan_in
                lp = bp["l0"]
                a = rms_norm(lp["norm1"], h, cfg.norm_eps)
                a, ck, cv = L.attention_decode(
                    lp["attn"], a, blk_cache["l0"]["k"], blk_cache["l0"]["v"],
                    pos, cfg, dtype,
                )
                h = h + a
                c = rms_norm(cp["norm"], h, cfg.norm_eps)
                h = h + L.cross_attention(
                    cp["attn"], c, (ckv[0].astype(dtype), ckv[1].astype(dtype)),
                    cfg, dtype,
                )
                m = rms_norm(lp["norm2"], h, cfg.norm_eps)
                h = h + swiglu_mlp(lp["mlp"], m, dtype)
                return h, {"l0": {"k": ck, "v": cv}}

            x, new_blocks = jax.lax.scan(
                body_encdec, x,
                (params["blocks"], params["cross"], cache["blocks"],
                 cache["cross_kv"]),
            )
            new_cache = {"blocks": new_blocks, "cross_kv": cache["cross_kv"]}
        else:
            x, new_blocks = jax.lax.scan(body, x, (params["blocks"],
                                                   cache["blocks"]))
            new_cache = {"blocks": new_blocks}
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = _logits(cfg, params, x, dtype)[:, 0, :]
        return logits.astype(jnp.float32), new_cache

    return step


def prefill_fn(cfg, *, q_chunk: int = 0, flash: bool = True) -> Callable:
    """Full-sequence prefill: returns last-token logits + populated cache.

    `flash=True` routes self-attention through the Pallas flash kernel
    when shapes/sharding allow (serving has no backward pass, so no
    custom VJP is needed)."""
    dtype = jnp.dtype(cfg.dtype)
    blk = _block_len(cfg)
    kinds = list(zip(layer_kinds(cfg), mlp_kinds(cfg)))[:blk]

    def prefill(params, batch):
        x, positions, positions3 = _embed_in(cfg, params, batch, dtype)
        B, S = x.shape[:2]

        if cfg.family == "encdec":
            enc_out = _encoder(cfg, params, batch["enc_embeds"], dtype,
                               q_chunk=q_chunk, flash=flash)
            x = _decdec_backbone(cfg, params, x, enc_out, dtype, positions,
                                 q_chunk=q_chunk, flash=flash)
            kv = jax.vmap(
                lambda cp: jnp.stack(L.enc_kv(cp["attn"], enc_out, cfg, dtype))
            )(params["cross"])
            x = rms_norm(params["final_norm"], x, cfg.norm_eps)
            logits = _logits(cfg, params, x[:, -1:, :], dtype)[:, 0]
            return logits.astype(jnp.float32), {"cross_kv": kv}

        def body(h, bp):
            h, states = _apply_block(
                cfg, bp, h, kinds, dtype, positions, positions3,
                ssm_states=None,
            )
            return h, states

        # capture per-layer K/V along the way: recompute qkv per block is
        # wasteful; for prefill we simply run the backbone and store K/V via
        # a second pass over attention projections (cheap relative to attn).
        def body_kv(h, bp):
            new = {}
            for j, (mix, mlp) in enumerate(kinds):
                lp = bp[f"l{j}"]
                a = rms_norm(lp["norm1"], h, cfg.norm_eps)
                if mix == "attn":
                    q, k, v = L._qkv(lp["attn"], a, cfg, dtype, positions,
                                     positions3)
                    o = L.sdpa_any(q, k, v, causal=True, q_chunk=q_chunk,
                                   flash=flash)
                    o = dense(lp["attn"]["wo"], o.reshape(B, S, -1), dtype)
                    new[f"l{j}"] = {"k": k, "v": v}
                    h = h + o
                else:
                    o, st = mamba_block(lp["ssm"], a, cfg, dtype)
                    new[f"l{j}"] = st
                    h = h + o
                if mlp != "none":
                    m = rms_norm(lp["norm2"], h, cfg.norm_eps)
                    m = swiglu_mlp(lp["mlp"], m, dtype) if mlp == "dense" \
                        else moe_sorted(lp["mlp"], m, cfg, dtype)[0]
                    h = h + m
            return h, new

        x, caches = jax.lax.scan(body_kv, x, params["blocks"])
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = _logits(cfg, params, x[:, -1:, :], dtype)[:, 0]
        return logits.astype(jnp.float32), {"blocks": caches}

    return prefill
