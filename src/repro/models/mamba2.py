"""Mamba-2 (SSD — state-space duality) block, chunked matmul formulation.

The SSD scan is reorganized into MXU-friendly matmuls (the "chunked"
algorithm from the Mamba-2 paper): within a chunk of length Q all
interactions are dense matmuls under a decay mask; across chunks a small
recurrent state [H, hd, ds] is carried by a lax.scan.

Decode is the O(1) recurrent update: h ← a·h + dt·B⊗x, y = C·h + D·x.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Params, cast, dense, init_dense, rms_norm


def init_mamba(key, cfg) -> Params:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, cw = cfg.ssm_heads, cfg.conv_width
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * di + 2 * ds + nh          # z, x, B, C, dt
    conv_dim = di + 2 * ds
    return {
        "in_proj": init_dense(ks[0], d, d_in_proj),
        "conv_w": jax.random.normal(ks[1], (cw, conv_dim), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),                                     # per-head decay
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),  # gated RMSNorm scale
        "out_proj": init_dense(ks[4], di, d),
    }


def _split_proj(cfg, zxbcdt):
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * ds]
    dt = zxbcdt[..., 2 * di + 2 * ds :]
    return z, xBC, dt


def _causal_conv(xBC, w, b, *, state=None):
    """Depthwise causal conv, width cw.  xBC [B,S,Cd]; w [cw,Cd].
    With `state` [B,cw-1,Cd] it runs in streaming (decode) mode and
    returns the updated state."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros(xBC.shape[:1] + (cw - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    full = jnp.concatenate([pad, xBC], axis=1)
    out = sum(
        full[:, i : i + xBC.shape[1], :] * w[i][None, None, :].astype(xBC.dtype)
        for i in range(cw)
    )
    out = jax.nn.silu(out + b.astype(xBC.dtype))
    new_state = full[:, -(cw - 1) :, :] if cw > 1 else None
    return out, new_state


def _gated_norm(y, z, scale, eps):
    dt = y.dtype
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * scale).astype(dt)


def mamba_block(p: Params, x, cfg, dtype, *, initial_state=None):
    """x [B,S,d] → y [B,S,d].  S must be a multiple of cfg.ssm_chunk
    (callers pad).  Returns (y, state) with state = {"h", "conv"}
    (decode-compatible: see init_mamba_state)."""
    B, S, d = x.shape
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    zxbcdt = dense(p["in_proj"], x, dtype)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC, conv_state = _causal_conv(
        xBC, p["conv_w"], p["conv_b"],
        state=None if initial_state is None else initial_state["conv"],
    )
    xs = xBC[..., :di].reshape(B, S, nh, hd)
    Bm = xBC[..., di : di + ds]                       # [B,S,ds] (1 group)
    Cm = xBC[..., di + ds :]                          # [B,S,ds]
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + p["dt_bias"]
    )                                                 # [B,S,nh]
    A = -jnp.exp(p["A_log"])                          # [nh] negative
    # discretize: per-step log decay  aᵗ = exp(A·dtᵗ)
    dA = dt * A[None, None, :]                        # [B,S,nh] (log a)

    nq = S // Q
    xs = xs.reshape(B, nq, Q, nh, hd)
    Bm = Bm.reshape(B, nq, Q, ds)
    Cm = Cm.reshape(B, nq, Q, ds)
    dtc = dt.reshape(B, nq, Q, nh)
    dAc = dA.reshape(B, nq, Q, nh)

    seg = jnp.cumsum(dAc, axis=2)                     # [B,nq,Q,nh]
    # intra-chunk (dual/quadratic form): L[q,s] = exp(seg_q - seg_s) (q>=s)
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]    # [B,nq,Q,Q,nh]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: exp of the (positive) upper triangle would overflow
    # and poison the backward pass with 0·inf
    rel = jnp.where(tri[None, None, :, :, None], rel, -jnp.inf)
    L = jnp.exp(rel)
    cb = jnp.einsum("bnqs,bnts->bnqt", Cm.astype(jnp.float32),
                    Bm.astype(jnp.float32))           # [B,nq,Q,Q]
    gate = cb[..., None] * L                          # [B,nq,Q,Q,nh]
    xdt = xs.astype(jnp.float32) * dtc[..., None]     # [B,nq,Q,nh,hd]
    y_intra = jnp.einsum("bnqth,bnthp->bnqhp", gate, xdt)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(seg[:, :, -1, :])           # [B,nq,nh]
    # state contribution of each chunk: Σ_s exp(seg_last - seg_s)·dt·x·B
    w = jnp.exp(seg[:, :, -1:, :] - seg)              # [B,nq,Q,nh]
    state_in = jnp.einsum(
        "bnqs,bnqh,bnqhp->bnhps", Bm.astype(jnp.float32),
        w * dtc, xs.astype(jnp.float32)
    )                                                 # [B,nq,nh,hd,ds]

    def scan_fn(h, inp):
        decay, sin = inp                              # [B,nh], [B,nh,hd,ds]
        h_new = h * decay[:, :, None, None] + sin
        return h_new, h                               # emit state BEFORE chunk

    h0 = (
        initial_state["h"].astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, nh, hd, ds), jnp.float32)
    )
    hN, h_before = jax.lax.scan(
        scan_fn,
        h0,
        (chunk_decay.transpose(1, 0, 2), state_in.transpose(1, 0, 2, 3, 4)),
    )
    h_before = h_before.transpose(1, 0, 2, 3, 4)      # [B,nq,nh,hd,ds]
    # y_inter[t] = exp(seg_t)·C_t·h_chunk_start
    y_inter = jnp.einsum(
        "bnqs,bnhps,bnqh->bnqhp", Cm.astype(jnp.float32), h_before,
        jnp.exp(seg)
    )
    y = (y_intra + y_inter).reshape(B, S, nh, hd)
    y = y + xs.reshape(B, S, nh, hd).astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    state = {"h": hN, "conv": conv_state.astype(jnp.float32)}
    return dense(p["out_proj"], y, dtype), state


def mamba_decode_step(p: Params, x, state, cfg, dtype):
    """x [B,1,d]; state = {"h": [B,nh,hd,ds], "conv": [B,cw-1,conv_dim]}."""
    B = x.shape[0]
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = dense(p["in_proj"], x, dtype)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC, conv_state = _causal_conv(
        xBC, p["conv_w"], p["conv_b"], state=state["conv"]
    )
    xs = xBC[..., :di].reshape(B, nh, hd)
    Bm = xBC[:, 0, di : di + ds]                      # [B,ds]
    Cm = xBC[:, 0, di + ds :]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,nh]
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))            # [B,nh]
    h = state["h"].astype(jnp.float32)
    upd = jnp.einsum(
        "bh,bhp,bs->bhps", dt, xs.astype(jnp.float32), Bm.astype(jnp.float32)
    )
    h = h * a[:, :, None, None] + upd
    y = jnp.einsum("bhps,bs->bhp", h, Cm.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = dense(p["out_proj"], y, dtype)
    return out, {"h": h, "conv": conv_state}


def init_mamba_state(cfg, batch: int):
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * ds
    return {
        "h": jnp.zeros((batch, nh, hd, ds), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), jnp.float32),
    }
