"""Token-choice top-k Mixture-of-Experts.

Two dispatch implementations:

 * `moe_sorted` (default): sort-based capacity-bounded dispatch.  Tokens
   are argsorted by expert id and scattered into per-expert buckets
   [E, C, d]; expert FFNs run as one batched einsum over E.  With experts
   sharded over the mesh `model` axis, the scatter/gather crosses the
   sharding boundary and lowers to all-to-alls (expert parallelism).
 * `moe_dense` (reference): computes every expert on every token and
   combines with routing weights — exact (no capacity drops), used as the
   oracle in tests and for tiny smoke configs.

Router: softmax over expert logits, top-k, weights renormalized over the
selected experts (standard Mixtral/granite semantics), plus the usual
load-balancing auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Params, cast, dense, init_dense


def init_moe(key, cfg) -> Params:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    return {
        "router": init_dense(ks[0], d, E),
        "gate": jax.random.normal(ks[1], (E, d, ff), jnp.float32) * s,
        "up": jax.random.normal(ks[2], (E, d, ff), jnp.float32) * s,
        "down": jax.random.normal(ks[3], (E, ff, d), jnp.float32)
        / jnp.sqrt(ff).astype(jnp.float32),
    }


def _route(p, x, cfg, dtype):
    """x [N, d] → (weights [N, k], experts [N, k], aux_loss)."""
    logits = dense(p["router"], x, jnp.float32)          # router in fp32
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch): E * Σ_e f_e · p_e
    E = cfg.n_experts
    hot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    f = hot.mean(axis=0)
    pbar = probs.mean(axis=0)
    aux = E * jnp.sum(f * pbar)
    return w.astype(dtype), idx, aux


def moe_dense(p: Params, x, cfg, dtype):
    """Reference: all experts on all tokens."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    w, idx, aux = _route(p, xf, cfg, dtype)
    g = jnp.einsum("nd,edf->nef", xf, cast(p["gate"], dtype))
    u = jnp.einsum("nd,edf->nef", xf, cast(p["up"], dtype))
    y = jnp.einsum("nef,efd->ned", jax.nn.silu(g) * u, cast(p["down"], dtype))
    sel = jnp.take_along_axis(y, idx[:, :, None], axis=1)   # [N, k, d]
    out = jnp.einsum("nkd,nk->nd", sel, w)
    return out.reshape(B, S, d), aux


def moe_sorted(p: Params, x, cfg, dtype):
    """Sort-based dispatch with per-expert capacity.

    capacity C = ceil(N·k/E · capacity_factor); overflow tokens drop
    (their residual path still carries them — standard capacity-factor
    semantics)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(-1, d)
    N = xf.shape[0]
    w, idx, aux = _route(p, xf, cfg, dtype)

    C = max(1, int((N * k) / E * cfg.capacity_factor))
    flat_e = idx.reshape(-1)                             # [N*k]
    flat_t = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    flat_w = w.reshape(-1)

    # stable sort by expert → tokens grouped per expert
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within the expert group: position − group start
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(se.shape[0], dtype=jnp.int32) - starts[se].astype(
        jnp.int32
    )
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)         # E*C = drop slot

    buckets = jnp.zeros((E * C, d), dtype)
    buckets = buckets.at[slot].set(xf[st].astype(dtype), mode="drop")
    buckets = buckets.reshape(E, C, d)

    g = jnp.einsum("ecd,edf->ecf", buckets, cast(p["gate"], dtype))
    u = jnp.einsum("ecd,edf->ecf", buckets, cast(p["up"], dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, cast(p["down"], dtype))
    y = y.reshape(E * C, d)

    gathered = y[jnp.minimum(slot, E * C - 1)]           # [N*k, d]
    gathered = jnp.where(keep[:, None], gathered, 0)
    out = jnp.zeros((N, d), dtype)
    out = out.at[st].add(gathered * sw[:, None].astype(dtype))
    return out.reshape(B, S, d), aux
