"""Shared neural building blocks (pure JAX, param pytrees = nested dicts).

Conventions:
 * params are fp32 masters; `cast` converts activations/weights to the
   compute dtype at use sites (mixed precision);
 * every init_* is pure-jax (traceable under jax.eval_shape for the
   dry-run: parameter shapes without allocation);
 * batch is logically sharded over the mesh data axes, d_ff/heads over
   the model axis (see parallel/sharding.py).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


def init_dense(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}


def dense(p: Params, x, dtype):
    return x @ cast(p["w"], dtype)


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: Params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * p["scale"]).astype(dt)


def head_rms_norm(x, scale, eps: float = 1e-6):
    """Per-head q/k norm (Qwen3 qk_norm): x [..., head_dim]."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(dt)


# ------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float):
    """x [..., S, H, hd]; positions [..., S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    ang = ang[..., None, :]                              # [..., S, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections=(16, 24, 24)):
    """Qwen2-VL M-RoPE: positions3 [..., 3, S] (t, h, w components);
    head_dim/2 frequency slots are split across the 3 components.

    `sections` are the qwen2-vl mrope_section values (sum = hd/2).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                        # [hd/2]
    # per-frequency component selector
    comp = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )                                                    # [hd/2]
    p3 = jnp.moveaxis(positions3, -2, -1)                # [..., S, 3]
    pos = p3[..., comp]                                  # [..., S, hd/2]
    ang = pos.astype(jnp.float32) * freqs                # [..., S, hd/2]
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ------------------------------------------------------------------ MLP
def init_mlp(key, d: int, ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d, ff),
        "up": init_dense(k2, d, ff),
        "down": init_dense(k3, ff, d),
    }


def swiglu_mlp(p: Params, x, dtype):
    g = dense(p["gate"], x, dtype)
    u = dense(p["up"], x, dtype)
    return dense(p["down"], jax.nn.silu(g) * u, dtype)


# ------------------------------------------------------------- attention
def init_attention(key, cfg) -> Params:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, H * hd),
        "wk": init_dense(ks[1], d, K * hd),
        "wv": init_dense(ks[2], d, K * hd),
        "wo": init_dense(ks[3], H * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _qkv(p, x, cfg, dtype, positions=None, positions3=None):
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(dense(p["wq"], x, dtype), H, hd)
    k = _split_heads(dense(p["wk"], x, dtype), K, hd)
    v = _split_heads(dense(p["wv"], x, dtype), K, hd)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope and positions3 is not None:
        q = apply_mrope(q, positions3, cfg.rope_theta, _mrope_sections(hd))
        k = apply_mrope(k, positions3, cfg.rope_theta, _mrope_sections(hd))
    elif positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mrope_sections(hd: int):
    # qwen2-vl uses (16, 24, 24) for hd=128; scale proportionally otherwise
    base = (16, 24, 24)
    if hd // 2 == sum(base):
        return base
    unit = (hd // 2) // 4
    return (unit, (hd // 2 - unit) // 2, hd // 2 - unit - (hd // 2 - unit) // 2)


def _sdpa(q, k, v, *, causal: bool, q_offset=0):
    """q [B,Sq,H,hd], k/v [B,Sk,K,hd] — grouped-query attention."""
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    q = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) / math.sqrt(hd)
    scores = scores.astype(jnp.float32)
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_offset
        ki = jnp.arange(Sk)[None, :]
        scores = jnp.where(qi >= ki, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_chunked(q, k, v, *, causal: bool, chunk: int = 1024):
    """Memory-efficient attention: lax.scan over query chunks (flash-style
    running softmax is unnecessary when the k/v fit — we chunk queries so
    the [Sq, Sk] score matrix never fully materializes)."""
    B, Sq, H, hd = q.shape
    if Sq <= chunk:
        return _sdpa(q, k, v, causal=causal)
    n = Sq // chunk
    assert Sq % chunk == 0, (Sq, chunk)
    qs = q.reshape(B, n, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    def step(carry, args):
        i, qc = args
        out = _sdpa(qc, k, v, causal=causal, q_offset=i * chunk)
        return carry, out

    _, outs = jax.lax.scan(step, (), (jnp.arange(n), qs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def _ambient_model_axis():
    """(model_axis_size, dp_axes) from the ambient mesh, or (1, ())."""
    from ..compat import get_abstract_mesh

    try:
        mesh = get_abstract_mesh()
    except Exception:  # pragma: no cover
        return 1, ()
    if mesh is None or not getattr(mesh, "axis_names", None):
        return 1, ()
    names = mesh.axis_names
    if "model" not in names:
        return 1, ()
    dp = tuple(a for a in names if a in ("pod", "data"))
    return mesh.shape["model"], dp


def _seq_shard_qkv(q, k, v):
    """KV-sequence sharding for head counts that do not divide the model
    axis (§Perf iteration 2, minitron-4b: 24 heads on a 16-way axis made
    GSPMD replicate/all-gather the score tensors — 542 s of ICI per
    prefill step).  Instead: replicate q over `model`, shard K/V along
    the sequence; scores/softmax/out then contract the sharded key axis
    locally and GSPMD inserts only the small softmax-stat and output
    psums (flash-decoding style, applied to prefill/train)."""
    m, dp = _ambient_model_axis()
    H = q.shape[2]
    Sk = k.shape[1]
    if m <= 1 or H % m == 0 or Sk % m != 0:
        return q, k, v
    from jax.sharding import PartitionSpec as P

    bspec = dp if dp else None
    q = jax.lax.with_sharding_constraint(q, P(bspec, None, None, None))
    k = jax.lax.with_sharding_constraint(k, P(bspec, "model", None, None))
    v = jax.lax.with_sharding_constraint(v, P(bspec, "model", None, None))
    return q, k, v


def _flash_sharded(q, k, v, *, causal: bool):
    """Route self-attention through the Pallas flash kernel when shapes
    and sharding allow; returns None to fall back to the XLA path.

    §Perf iteration 3: the XLA path materializes fp32 score tensors at
    fusion boundaries (the dominant prefill memory term); the kernel
    keeps them in VMEM.  Distribution: shard_map with batch over the
    data axes and q-heads over `model` (each shard gathers its matching
    kv heads — zero-copy GQA inside the shard).  Head counts that do not
    divide `model` fall back to data-only sharding (attention compute
    replicated over `model`; still memory-optimal)."""
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    if Sq != Sk or Sq % 512 or hd > 128:
        return None
    import os

    from ..kernels.ops import flash_attention as _real_flash

    if os.environ.get("REPRO_FLASH_STUB") == "1":
        # Dry-run roofline mode: the Pallas kernel is an opaque custom
        # call on real hardware (cost_analysis cannot see inside it
        # there either), and its interpret-mode HLO emulation has a
        # wildly different byte profile.  Substitute an op with the
        # kernel's exact HBM footprint — read q,k,v, write o — and let
        # launch/dryrun add the MXU flops analytically.
        def flash_attention(ql, kl, vl, causal=True):
            scale = (kl.mean() + vl.mean()).astype(ql.dtype)
            return ql * scale
    else:
        flash_attention = _real_flash

    m, dp = _ambient_model_axis()
    if m <= 1 and not dp:
        return flash_attention(q, k, v, causal=causal)
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from ..compat import get_abstract_mesh, shard_map

    mesh = get_abstract_mesh()
    ndp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if dp and B % ndp:
        dp = dp[:-1]
        ndp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        if dp and B % ndp:
            return None
    bspec = dp if dp else None
    head_sharded = H % m == 0 and m > 1
    G = H // K

    if head_sharded:
        qspec = P(bspec, None, "model", None)
        kvspec = P(bspec, None, None, None)

        def local(ql, kl, vl):
            Hl = ql.shape[2]
            off = jax.lax.axis_index("model") * Hl
            kvidx = (off + jnp.arange(Hl, dtype=jnp.int32)) // G
            kl = jnp.take(kl, kvidx, axis=2)
            vl = jnp.take(vl, kvidx, axis=2)
            return flash_attention(ql, kl, vl, causal=causal)

        return shard_map(
            local, mesh=mesh, in_specs=(qspec, kvspec, kvspec),
            out_specs=qspec, check_vma=False,
        )(q, k, v)

    spec = P(bspec, None, None, None)
    return shard_map(
        lambda ql, kl, vl: flash_attention(ql, kl, vl, causal=causal),
        mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
        check_vma=False,
    )(q, k, v)


def sdpa_any(q, k, v, *, causal: bool, q_chunk: int = 0, flash: bool = False):
    """Dispatch: Pallas flash (serving) → chunked XLA → plain XLA."""
    if flash:
        out = _flash_sharded(q, k, v, causal=causal)
        if out is not None:
            return out
    q, k, v = _seq_shard_qkv(q, k, v)
    if q_chunk:
        return _sdpa_chunked(q, k, v, causal=causal, chunk=q_chunk)
    return _sdpa(q, k, v, causal=causal)


def attention(
    p: Params, x, cfg, dtype, *,
    causal=True, positions=None, positions3=None, q_chunk: int = 0,
    flash: bool = False,
):
    q, k, v = _qkv(p, x, cfg, dtype, positions, positions3)
    out = sdpa_any(q, k, v, causal=causal, q_chunk=q_chunk, flash=flash)
    B, S = x.shape[:2]
    return dense(p["wo"], out.reshape(B, S, -1), dtype)


def cross_attention(p: Params, x, enc_kv, cfg, dtype, *, q_chunk: int = 0,
                    flash: bool = False):
    """x [B,Sq,d]; enc_kv = (k, v) precomputed from encoder output."""
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(dense(p["wq"], x, dtype), H, hd)
    k, v = enc_kv
    out = sdpa_any(q, k, v, causal=False, q_chunk=q_chunk, flash=flash)
    B, S = x.shape[:2]
    return dense(p["wo"], out.reshape(B, S, -1), dtype)


def enc_kv(p: Params, enc_out, cfg, dtype):
    K, hd = cfg.n_kv_heads, cfg.head_dim
    k = _split_heads(dense(p["wk"], enc_out, dtype), K, hd)
    v = _split_heads(dense(p["wv"], enc_out, dtype), K, hd)
    return k, v


# --------------------------------------------------- decode (KV cache) ----
def attention_decode(p: Params, x, cache_k, cache_v, pos, cfg, dtype,
                     positions3=None):
    """One-token decode: x [B,1,d]; cache [B,S,K,hd]; pos scalar int OR a
    per-row ``[B]`` int vector (continuous batching: each slot of the
    padded batch sits at its own sequence position — admissions mid-
    decode are what make the vector form necessary, DESIGN.md §5).

    The cache sequence axis may be sharded over the mesh `model` axis;
    the softmax reductions below are partitioner-safe (GSPMD inserts the
    cross-shard all-reduces — flash-decoding style).
    """
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    posv = pos[:, None] if per_row else jnp.full((B, 1), pos, jnp.int32)
    if cfg.mrope and positions3 is None:
        positions3 = jnp.broadcast_to(posv[:, None, :], (B, 3, 1))
    q, k, v = _qkv(p, x, cfg, dtype, posv, positions3)
    if per_row:
        # each row writes its token at its own position
        row_upd = jax.vmap(
            lambda c, u, pp: jax.lax.dynamic_update_slice(c, u, (pp, 0, 0)))
        cache_k = row_upd(cache_k, k.astype(cache_k.dtype), pos)
        cache_v = row_upd(cache_v, v.astype(cache_v.dtype), pos)
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0)
        )
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0)
        )
    S = cache_k.shape[1]
    G = H // K
    qh = q.reshape(B, 1, K, G, hd)
    scores = jnp.einsum(
        "bqkgh,bskh->bkgqs", qh, cache_k.astype(dtype)
    ) / math.sqrt(hd)
    scores = scores.astype(jnp.float32)
    # [B,1,1,1,S] per-row causal horizon (broadcasts over heads/groups)
    mask = (jnp.arange(S)[None, :] <= posv)[:, None, None, None, :]
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, cache_v.astype(dtype))
    out = out.reshape(B, 1, H * hd)
    return dense(p["wo"], out, dtype), cache_k, cache_v
