"""Gradient compression for the DP all-reduce (int8 + error feedback).

At 1000+ nodes the data-parallel gradient all-reduce is the dominant
cross-pod collective.  This module provides an int8 per-tensor-scaled
quantizer with error feedback (residual carried between steps), exposed
as a shard_map-compatible reduce.  It is OFF by default; train_step can
enable it for the cross-pod axis only (gradients inside a pod stay bf16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, scale=None):
    x32 = x.astype(jnp.float32)
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(tree, axis_name: str, error_state=None):
    """int8-compressed psum with error feedback.

    error_state: pytree like `tree` carrying the quantization residual
    from the previous step (or None).  Returns (reduced, new_error)."""
    if error_state is None:
        error_state = jax.tree.map(
            lambda v: jnp.zeros_like(v, dtype=jnp.float32), tree
        )

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        new_e = g32 - deq
        # the int8 payload is what crosses the (slow) axis; scales are
        # tiny fp32 scalars
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s = jax.lax.pmax(scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * s / n).astype(g.dtype), new_e

    flat, tdef = jax.tree.flatten(tree)
    flat_e = tdef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat, flat_e)]
    return (
        tdef.unflatten([o[0] for o in out]),
        tdef.unflatten([o[1] for o in out]),
    )
