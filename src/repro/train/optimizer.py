"""AdamW (decoupled weight decay) + LR schedules + global-norm clipping.

Implemented from scratch (no optax in the environment).  State pytree
mirrors params, so the ZeRO-style sharding rules apply verbatim — the
optimizer state is fully sharded across the mesh like its parameters.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup → cosine decay (fp32 scalar, traceable)."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.zeros_like, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics).  Donation-friendly."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
