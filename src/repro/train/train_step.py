"""Sharded train-step construction (pjit): loss+grad → AdamW update.

Features: microbatch gradient accumulation (lax.scan), activation remat,
query-chunked attention, sequence-chunked loss, donated params/opt-state,
2-D (TP×FSDP) sharded params and fully-sharded optimizer state.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import transformer as T
from ..parallel.sharding import (
    batch_specs, dp_axes, opt_state_shardings, param_shardings, pick_layout,
)
from .optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclass(frozen=True)
class TrainOptions:
    remat: bool = True
    q_chunk: int = 1024          # query chunking for long-seq attention
    loss_chunk: int = 1024       # sequence chunking for the vocab softmax
    accum_steps: int = 1         # microbatch gradient accumulation


def abstract_params(cfg):
    return jax.eval_shape(lambda k: T.init(cfg, k), jax.random.PRNGKey(0))


def make_train_step(cfg, opt_cfg: AdamWConfig, mesh, opts: TrainOptions,
                    batch_shape):
    """Returns (jitted_step, params_sh, opt_sh, batch_sh).

    jitted_step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    loss = T.loss_fn(
        cfg, remat=opts.remat,
        q_chunk=(opts.q_chunk if _needs_chunk(cfg, batch_shape, opts) else 0),
        loss_chunk=opts.loss_chunk,
    )

    def grads_of(params, batch):
        if opts.accum_steps <= 1:
            (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(
                params, batch
            )
            return l, metrics, g
        # microbatch accumulation over the leading batch dim
        A = opts.accum_steps

        def split(x):
            return x.reshape((A, x.shape[0] // A) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, tot = carry
            (l, _m), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, tot + l), ()

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, tot), _ = jax.lax.scan(body, (zero, jnp.zeros(())), micro)
        g = jax.tree.map(lambda x: x / A, g)
        l = tot / A
        return l, {"loss": l, "tokens": jnp.zeros(())}, g

    def step(params, opt_state, batch):
        l, metrics, g = grads_of(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, g, opt_state, params)
        return params, opt_state, {**metrics, **om}

    layout = pick_layout(cfg, mesh)
    p_shape = abstract_params(cfg)
    p_sh = param_shardings(p_shape, mesh, layout)
    o_shape = jax.eval_shape(init_opt_state, p_shape)
    o_sh = opt_state_shardings(o_shape, p_sh, mesh)
    b_sh = batch_specs(batch_shape, mesh, layout)
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )
    return jitted, p_sh, o_sh, b_sh


def _needs_chunk(cfg, batch_shape, opts):
    leaf = batch_shape.get("tokens", batch_shape.get("embeds"))
    S = leaf.shape[1]
    return bool(opts.q_chunk) and S >= 2 * opts.q_chunk


def init_sharded(cfg, mesh, seed: int = 0):
    """Initialize params/opt-state directly into their shardings."""
    p_shape = abstract_params(cfg)
    p_sh = param_shardings(p_shape, mesh)
    params = jax.jit(
        lambda k: T.init(cfg, k), out_shardings=p_sh
    )(jax.random.PRNGKey(seed))
    o_shape = jax.eval_shape(init_opt_state, p_shape)
    o_sh = opt_state_shardings(o_shape, p_sh, mesh)
    opt_state = jax.jit(init_opt_state, out_shardings=o_sh)(params)
    return params, opt_state, p_sh, o_sh
