"""Deterministic, resumable data pipeline.

Synthetic LM token stream: batch(step) is a pure function of (seed, step)
— resuming from a checkpoint at step k reproduces the exact stream with
no iterator state to persist (the fault-tolerance contract).  A memmap'd
token-file source with the same interface is provided for real corpora.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLM:
    """Zipf-ish synthetic tokens (uniform is adversarially easy to fit)."""

    def __init__(self, cfg: DataConfig, model_cfg=None):
        self.cfg = cfg
        self.model_cfg = model_cfg

    def batch(self, step: int):
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        k1, k2 = jax.random.split(key)
        # zipf via exponentiated uniform
        u = jax.random.uniform(
            k1, (c.global_batch, c.seq_len + 1), minval=1e-6, maxval=1.0
        )
        toks = jnp.clip(
            (jnp.power(u, 3.0) * c.vocab).astype(jnp.int32), 0, c.vocab - 1
        )
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        mc = self.model_cfg
        if mc is not None and mc.family == "encdec":
            batch["enc_embeds"] = jax.random.normal(
                k2, (c.global_batch, c.seq_len, mc.d_model), jnp.bfloat16
            )
        if mc is not None and mc.family == "vlm" and mc.stub_frontend:
            batch = {
                "embeds": jax.random.normal(
                    k2, (c.global_batch, c.seq_len, mc.d_model), jnp.bfloat16
                ),
                "positions3": jnp.broadcast_to(
                    jnp.arange(c.seq_len, dtype=jnp.int32),
                    (c.global_batch, 3, c.seq_len),
                ),
                "labels": batch["labels"],
            }
        return batch


class TokenFile:
    """Memmap token corpus: deterministic strided windows by step."""

    def __init__(self, path: str, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=dtype, mode="r")

    def batch(self, step: int):
        c = self.cfg
        n_win = (len(self.data) - 1) // c.seq_len
        rng = np.random.default_rng(c.seed + step)
        idx = rng.integers(0, n_win, size=c.global_batch)
        tok = np.stack(
            [self.data[i * c.seq_len : i * c.seq_len + c.seq_len + 1]
             for i in idx]
        ).astype(np.int32)
        return {
            "tokens": jnp.asarray(tok[:, :-1]),
            "labels": jnp.asarray(tok[:, 1:]),
        }
