"""Sharded, atomic, resumable checkpoints (no orbax in the environment).

Layout:  <dir>/step_<k>/
             manifest.json        tree structure, shapes, dtypes, step
             arr_<i>.npy          one file per leaf (host-gathered)
         <dir>/LATEST             text file → "step_<k>"  (atomic rename)

Restore supports *elastic resharding*: leaves are loaded on host and
device_put with the target mesh's shardings, so a run checkpointed on a
256-chip pod restarts unchanged on 512 chips (or 8 host devices in CI).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten(tree):
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
             for kp, _ in flat]
    return paths, [v for _, v in flat], tdef


def save(ckpt_dir: str, step: int, tree) -> str:
    """Atomic: write to tmp dir, fsync manifest, rename, repoint LATEST."""
    os.makedirs(ckpt_dir, exist_ok=True)
    paths, leaves, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    manifest = {"step": step, "leaves": []}
    try:
        for i, (p, v) in enumerate(zip(paths, leaves)):
            arr = np.asarray(jax.device_get(v))
            np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            manifest["leaves"].append(
                {"path": p, "file": f"arr_{i}.npy",
                 "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(f"step_{step}")
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> int | None:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None
    name = open(latest).read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, tree_like, *, step: int | None = None,
            shardings=None):
    """Load into the structure of `tree_like`; device_put with `shardings`
    (a matching tree of NamedShardings) if given — elastic restart."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    by_path = {m["path"]: m for m in manifest["leaves"]}

    paths, leaves, tdef = _flatten(tree_like)
    shard_flat = (
        jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for p, like, sh in zip(paths, leaves, shard_flat):
        m = by_path[p]
        arr = np.load(os.path.join(d, m["file"]))
        want = np.dtype(m["dtype"])
        if arr.dtype != want and arr.dtype.itemsize == want.itemsize:
            # extension dtypes (bfloat16 & friends) round-trip through
            # .npy as raw void records; the manifest kept the real name
            arr = arr.view(want)
        assert tuple(arr.shape) == tuple(like.shape), (p, arr.shape, like.shape)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, out), step
