#!/usr/bin/env bash
# Gateway smoke (ISSUE 5 + ISSUE 9 acceptance), CPU, seconds-scale:
#   1. replay one request trace through the legacy single-tenant path
#      (launch/query_serve.py, sequential rounds) and through the
#      Gateway (launch/gateway.py) co-scheduled with a live LM decode
#      workload — the per-query counts must be IDENTICAL (the gateway
#      changes scheduling, never results);
#   2. the gateway run must coalesce the trace's duplicate triangle
#      queries (--expect-coalesced) and finish its LM steps;
#   3. replay the SAME trace through the RPC socket front door
#      (launch/gateway.py --listen + repro.serve.rpc client, with a
#      preemption budget active) — every count bit-identical again;
#   4. (ISSUE 10) mutate-then-replay through a --live server: a trace
#      interleaving queries with insert_edges/delete_edges/compact
#      mutations, with every count diffed against a reference engine on
#      a CSR rebuilt FROM SCRATCH at the same epoch — the delta overlay
#      must be invisible in the results.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

cat > "$tmp/trace.jsonl" <<'EOF'
{"pattern": "triangle"}
{"pattern": "P1"}
{"pattern": {"n": 3, "edges": [[2, 1], [0, 2], [1, 0]]}}
{"pattern": "triangle"}
EOF

echo "== legacy path (query_serve, sequential rounds) =="
python -m repro.launch.query_serve --dataset tiny-er \
  --requests "$tmp/trace.jsonl" --capacity 8192 --single-device \
  --expect-min-hits 2 | tee "$tmp/legacy.log"

echo "== gateway path (co-scheduled with LM decode) =="
python -m repro.launch.gateway --dataset tiny-er \
  --requests "$tmp/trace.jsonl" --capacity 8192 --single-device \
  --graph-quantum 4 --expect-coalesced 2 \
  --arch qwen3-1.7b --batch 2 --prompt-len 16 --gen 4 --lm-quantum 2 \
  | tee "$tmp/gateway.log"

grep -o 'count=[0-9]*' "$tmp/legacy.log"  > "$tmp/legacy.counts"
grep -o 'count=[0-9]*' "$tmp/gateway.log" > "$tmp/gateway.counts"
if ! cmp -s "$tmp/legacy.counts" "$tmp/gateway.counts"; then
  echo "gateway_smoke FAILED: per-query counts differ between the" >&2
  echo "legacy path and the gateway path:" >&2
  diff "$tmp/legacy.counts" "$tmp/gateway.counts" >&2 || true
  exit 1
fi

echo "== RPC path (--listen socket front door, preemptive quanta) =="
python -m repro.launch.gateway --dataset tiny-er --no-lm \
  --capacity 8192 --single-device --graph-quantum 4 \
  --preempt-dispatches 8 --listen 0 --port-file "$tmp/port" \
  > "$tmp/server.log" 2>&1 &
server_pid=$!
for _ in $(seq 1 120); do
  [ -s "$tmp/port" ] && break
  if ! kill -0 "$server_pid" 2>/dev/null; then
    echo "gateway_smoke FAILED: RPC server died during startup:" >&2
    cat "$tmp/server.log" >&2
    exit 1
  fi
  sleep 1
done
[ -s "$tmp/port" ] || { echo "gateway_smoke FAILED: no port file" >&2; exit 1; }
read -r host port < "$tmp/port"
python -m repro.serve.rpc --connect "$host:$port" \
  --requests "$tmp/trace.jsonl" --shutdown | tee "$tmp/rpc.log"
wait "$server_pid" || {
  echo "gateway_smoke FAILED: RPC server exited nonzero:" >&2
  cat "$tmp/server.log" >&2
  exit 1
}
cat "$tmp/server.log"

grep -o 'count=[0-9]*' "$tmp/rpc.log" > "$tmp/rpc.counts"
if ! cmp -s "$tmp/legacy.counts" "$tmp/rpc.counts"; then
  echo "gateway_smoke FAILED: per-query counts differ between the" >&2
  echo "legacy path and the RPC socket path:" >&2
  diff "$tmp/legacy.counts" "$tmp/rpc.counts" >&2 || true
  exit 1
fi
echo "== live path (--live server, mutate-then-replay vs rebuilt CSR) =="
cat > "$tmp/mutate.jsonl" <<'EOF'
{"pattern": "triangle"}
{"pattern": "P1"}
{"mutate": "insert_edges", "edges": [[0, 1], [0, 2], [1, 2], [3, 250], [4, 251]]}
{"mutate": "delete_edges", "edges": [[0, 1], [5, 6]]}
{"pattern": "triangle"}
{"pattern": "P1"}
{"mutate": "compact"}
{"pattern": "triangle"}
EOF
python -m repro.launch.gateway --dataset tiny-er --no-lm --live \
  --capacity 8192 --single-device --graph-quantum 4 \
  --listen 0 --port-file "$tmp/port_live" \
  > "$tmp/live_server.log" 2>&1 &
live_pid=$!
for _ in $(seq 1 120); do
  [ -s "$tmp/port_live" ] && break
  if ! kill -0 "$live_pid" 2>/dev/null; then
    echo "gateway_smoke FAILED: live RPC server died during startup:" >&2
    cat "$tmp/live_server.log" >&2
    exit 1
  fi
  sleep 1
done
[ -s "$tmp/port_live" ] || { echo "gateway_smoke FAILED: no live port file" >&2; exit 1; }
read -r host port < "$tmp/port_live"
python -m repro.serve.rpc --connect "$host:$port" \
  --requests "$tmp/mutate.jsonl" --shutdown | tee "$tmp/live.log"
wait "$live_pid" || {
  echo "gateway_smoke FAILED: live RPC server exited nonzero:" >&2
  cat "$tmp/live_server.log" >&2
  exit 1
}
cat "$tmp/live_server.log"
grep -o 'count=[0-9]*' "$tmp/live.log" > "$tmp/live.counts"

# reference: rebuild the CSR from scratch at every mutation epoch and
# answer the same queries on frozen engines — no overlay involved
python - "$tmp/mutate.jsonl" <<'EOF' > "$tmp/rebuilt.counts"
import json, sys

from repro.configs.graphpi import get_dataset
from repro.core.executor import ExecutorConfig
from repro.graph.csr import GraphCSR
from repro.query import QueryEngine, QueryRequest
from repro.serve.rpc import request_from_spec

base = get_dataset("tiny-er")
edges = set(map(tuple, base.edge_array().tolist()))
cfg = ExecutorConfig(capacity=8192)
engine, epoch = None, -1
cur_epoch = 0
for line in open(sys.argv[1]):
    spec = json.loads(line)
    if "mutate" in spec:
        batch = {tuple(sorted(map(int, e))) for e in spec.get("edges", [])}
        if spec["mutate"] == "insert_edges":
            edges |= batch
        elif spec["mutate"] == "delete_edges":
            edges -= batch
        cur_epoch += 1          # compact: content unchanged, engine reusable
        continue
    if epoch != cur_epoch:
        g = GraphCSR.from_edges(base.n, sorted(edges), name="rebuilt")
        engine, epoch = QueryEngine(g, cfg=cfg), cur_epoch
    t = engine.enqueue(request_from_spec(spec))
    while not t.done:
        engine.run_pending()
    print(f"count={t.result.count}")
EOF
if ! cmp -s "$tmp/live.counts" "$tmp/rebuilt.counts"; then
  echo "gateway_smoke FAILED: live (overlay) counts differ from the" >&2
  echo "rebuilt-from-scratch CSR reference:" >&2
  diff "$tmp/live.counts" "$tmp/rebuilt.counts" >&2 || true
  exit 1
fi
grep -q 'mutations=' "$tmp/live_server.log" || {
  echo "gateway_smoke FAILED: live server summary missing mutation stats" >&2
  exit 1
}

echo "gateway_smoke OK: $(wc -l < "$tmp/legacy.counts") counts identical
across legacy, gateway, and RPC socket paths; $(wc -l < "$tmp/live.counts")
live-mutation counts identical to the rebuilt-from-scratch CSR"
