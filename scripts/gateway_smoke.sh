#!/usr/bin/env bash
# Gateway smoke (ISSUE 5 acceptance), CPU, seconds-scale:
#   1. replay one request trace through the legacy single-tenant path
#      (launch/query_serve.py, sequential rounds) and through the
#      Gateway (launch/gateway.py) co-scheduled with a live LM decode
#      workload — the per-query counts must be IDENTICAL (the gateway
#      changes scheduling, never results);
#   2. the gateway run must coalesce the trace's duplicate triangle
#      queries (--expect-coalesced) and finish its LM steps.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

cat > "$tmp/trace.jsonl" <<'EOF'
{"pattern": "triangle"}
{"pattern": "P1"}
{"pattern": {"n": 3, "edges": [[2, 1], [0, 2], [1, 0]]}}
{"pattern": "triangle"}
EOF

echo "== legacy path (query_serve, sequential rounds) =="
python -m repro.launch.query_serve --dataset tiny-er \
  --requests "$tmp/trace.jsonl" --capacity 8192 --single-device \
  --expect-min-hits 2 | tee "$tmp/legacy.log"

echo "== gateway path (co-scheduled with LM decode) =="
python -m repro.launch.gateway --dataset tiny-er \
  --requests "$tmp/trace.jsonl" --capacity 8192 --single-device \
  --graph-quantum 4 --expect-coalesced 2 \
  --arch qwen3-1.7b --batch 2 --prompt-len 16 --gen 4 --lm-quantum 2 \
  | tee "$tmp/gateway.log"

grep -o 'count=[0-9]*' "$tmp/legacy.log"  > "$tmp/legacy.counts"
grep -o 'count=[0-9]*' "$tmp/gateway.log" > "$tmp/gateway.counts"
if ! cmp -s "$tmp/legacy.counts" "$tmp/gateway.counts"; then
  echo "gateway_smoke FAILED: per-query counts differ between the" >&2
  echo "legacy path and the gateway path:" >&2
  diff "$tmp/legacy.counts" "$tmp/gateway.counts" >&2 || true
  exit 1
fi
echo "gateway_smoke OK: $(wc -l < "$tmp/legacy.counts") counts identical
across legacy and gateway paths"
