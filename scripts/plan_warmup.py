#!/usr/bin/env python
"""Offline plan-store warmup: precompile patterns × modes into a
persistent cache dir so serving replicas start warm (DESIGN.md §5).

    PYTHONPATH=src python scripts/plan_warmup.py --cache-dir /var/cache/plans
    PYTHONPATH=src python scripts/plan_warmup.py --cache-dir ./plans \
        --dataset small-rmat --patterns P1,P2 --modes graphpi --no-iep

For every (pattern, mode[, use_iep]) combination the tool runs the full
cold pipeline — configuration search → plan build → JIT warmup → AOT
export — through the same `PlanCache` code path serving uses, writing
each result behind into the store.  A replica started with
`launch/query_serve.py --cache-dir <dir> --warm-from-disk` (same graph,
executor config, and layout) then serves its first query with zero
configuration searches and zero fresh JIT traces.

Combinations already persisted are skipped (load-through hits), so the
tool is idempotent and cheap to re-run after adding patterns.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-dir", required=True,
                    help="plan store directory to populate")
    ap.add_argument("--dataset", default="tiny-er")
    ap.add_argument("--patterns", default="P1,P2,P3,P4,P5,P6",
                    help="comma-separated pattern names")
    ap.add_argument("--modes", default="graphpi,graphzero",
                    help="comma-separated subset of graphpi,graphzero,naive")
    ap.add_argument("--no-iep", action="store_true",
                    help="skip the use_iep=True variants")
    ap.add_argument("--capacity", type=int, default=1 << 15)
    ap.add_argument("--chunk", type=int, default=0,
                    help="outer-loop vertex chunk (0 = executor default); "
                         "must match the serving replica's --chunk")
    args = ap.parse_args(argv)

    from repro.configs.graphpi import get_dataset, get_pattern
    from repro.core.executor import ExecutorConfig
    from repro.query import PlanStore, QueryEngine, QueryRequest

    graph = get_dataset(args.dataset)
    store = PlanStore(args.cache_dir)
    engine = QueryEngine(
        graph, cfg=ExecutorConfig(capacity=args.capacity),
        chunk=args.chunk or None, store=store,
    )
    print(f"[warmup] graph={graph.name} (|V|={graph.n}, |E|={graph.m}); "
          f"store at {store.vdir} ({len(store)} entries)")

    combos = []
    for name in args.patterns.split(","):
        for mode in args.modes.split(","):
            iep_variants = [False] if (args.no_iep or mode == "naive") \
                else [False, True]
            for use_iep in iep_variants:
                combos.append((name.strip(), mode.strip(), use_iep))

    t0 = time.perf_counter()
    for name, mode, use_iep in combos:
        ticket = engine.enqueue(QueryRequest(
            get_pattern(name), mode=mode, use_iep=use_iep))
        engine.run_pending()
        res = ticket.result
        how = ("warm" if res.cache_hit else
               "persisted" if res.search_seconds == 0.0 else "compiled")
        print(f"[warmup] {name:<6} mode={mode:<10} iep={int(use_iep)} "
              f"{how:<9} count={res.count} "
              f"(search {res.search_seconds:.3f}s, "
              f"compile {res.compile_seconds:.3f}s)")
        if res.overflowed:
            print(f"[warmup] OVERFLOW on {name} — raise --capacity")
            return 1

    s = engine.cache.stats
    print(f"[warmup] done in {time.perf_counter() - t0:.1f}s: "
          f"{s.n_searches} searches, {s.n_compiles} compiles, "
          f"{s.persist_hits} already persisted, "
          f"{s.export_fails} export failures; "
          f"store now has {len(store)} entries")
    return 0


if __name__ == "__main__":
    sys.exit(main())
