#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): fast fail-fast suite.
#
# pytest.ini deselects @pytest.mark.slow tests by default so this
# finishes quickly; use `scripts/tier1.sh --all` (== pytest -m "")
# to run the full matrix including the slow executor/bucket tests.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--all" ]]; then
  shift
  exec python -m pytest -x -q -m "" "$@"
fi
exec python -m pytest -x -q "$@"
