#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): fast fail-fast suite + serve-path smoke.
#
# pytest.ini deselects @pytest.mark.slow tests by default so this
# finishes quickly; use `scripts/tier1.sh --all` (== pytest -m "")
# to run the full matrix including the slow executor/bucket tests.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--all" ]]; then
  shift
  python -m pytest -x -q -m "" "$@"
else
  python -m pytest -x -q "$@"
fi
scripts/query_smoke.sh
scripts/gateway_smoke.sh
scripts/docs_check.sh
scripts/static_check.sh
