#!/usr/bin/env python
"""Append one perf-trajectory record per CI run (ISSUE 9 satellite).

Scans a benchmark artifact directory (the `REPRO_BENCH_OUT` the
bench-smoke job writes) for the Row-list JSONs `benchmarks/_util.emit`
produces, distills them into a flat {metric: value} dict, and appends
ONE JSON line to `trajectory.jsonl`:

    {"sha": "<git sha>", "date": "<commit iso date>", "branch": "...",
     "metrics": {"query_throughput.warm_qps": 123.4, ...}}

CI keeps the file alive across runs by downloading the previous
`bench-trajectory` artifact before appending and re-uploading after
(.github/workflows/ci.yml), so the artifact IS the trajectory — every
line one commit, oldest first.  The delta summary against the previous
record is printed for humans and NEVER fails the job: shared-runner
numbers are noisy; the trajectory exists so regressions show up as a
trend, not so single samples gate merges.

The sha/date come from `git show -s` (the commit under test), not the
wall clock, so re-recording the same commit is reproducible.

Local runs additionally snapshot the recognized artifacts to repo-root
`BENCH_gateway.json` / `BENCH_questions.json` / `BENCH_live_churn.json`
(--no-snapshots to skip), and a run that finds NO artifacts exits 0 —
the first run of a fresh checkout has no previous artifact and must not
fail the job (--strict restores the old non-zero exit for CI stages
that require artifacts to exist).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# metric -> (source file, row predicate, value extractor).  A file that
# is missing or malformed simply contributes no metrics (partial bench
# runs still record what they measured).
WELL_KNOWN = {
    "query_throughput.cold_qps": ("query_throughput.json", "cold"),
    "query_throughput.warm_qps": ("query_throughput.json", "warm"),
    "query_throughput.speedup_x": ("query_throughput.json", "speedup"),
    "gateway_mix.interference_x": ("gateway_mix.json", "interference"),
    "gateway_mix.tenant_solo_p99_ms": ("gateway_mix.json", "tenant_solo"),
    "gateway_mix.tenant_adversarial_p99_ms": (
        "gateway_mix.json", "tenant_adversarial"),
    "gateway_mix.coalesced_executions": ("gateway_mix.json", "coalesce"),
    "live_churn.live_qps": ("live_churn.json", "live"),
    "live_churn.reload_qps": ("live_churn.json", "reload"),
    "live_churn.speedup_x": ("live_churn.json", "speedup"),
    "live_churn.incremental_x": ("live_churn.json", "incremental"),
}

# Local snapshot names: repo-root BENCH_<name>.json copies of the latest
# artifacts, so a developer run leaves an inspectable trajectory seed
# without the CI artifact plumbing.
SNAPSHOTS = {
    "BENCH_gateway.json": "gateway_mix.json",
    "BENCH_questions.json": "questions.json",
    "BENCH_live_churn.json": "live_churn.json",
}


def write_snapshots(bench_dir: str, root: str) -> list[str]:
    """Copy recognized artifacts to repo-root BENCH_*.json; returns the
    snapshot paths written.  Missing sources are skipped silently —
    partial bench runs snapshot what they measured."""
    written = []
    for out_name, src_name in SNAPSHOTS.items():
        rows = _rows(os.path.join(bench_dir, src_name))
        if not rows:
            continue
        path = os.path.join(root, out_name)
        with open(path, "w") as f:
            json.dump(rows, f, indent=1)
        written.append(path)
    return written


def _rows(path: str) -> list[dict]:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return []
    return [r for r in data if isinstance(r, dict) and "value" in r]


def collect(bench_dir: str) -> dict[str, float]:
    """Distill every recognized artifact under `bench_dir`."""
    metrics: dict[str, float] = {}
    by_file: dict[str, list[tuple[str, str]]] = {}
    for metric, (fname, phase) in WELL_KNOWN.items():
        by_file.setdefault(fname, []).append((metric, phase))
    for fname, wanted in by_file.items():
        rows = _rows(os.path.join(bench_dir, fname))
        phases = {r["keys"].get("phase"): r for r in rows
                  if isinstance(r.get("keys"), dict)}
        for metric, phase in wanted:
            if phase in phases:
                metrics[metric] = float(phases[phase]["value"])
    # question-benchmark accuracy rides under a stable name
    for fname in ("BENCH_questions.json", "questions.json"):
        rows = _rows(os.path.join(bench_dir, fname))
        if not rows:
            continue
        for r in rows:
            keys = r.get("keys", {})
            path = keys.get("path")
            if path and "category" not in keys and "phase" not in keys:
                metrics[f"questions.{path}_accuracy"] = float(r["value"])
                metrics["questions.n"] = float(keys.get("questions", 0))
        break
    return metrics


def git_meta() -> dict[str, str]:
    def show(fmt: str) -> str:
        try:
            return subprocess.run(
                ["git", "show", "-s", f"--format={fmt}"],
                capture_output=True, text=True, check=True,
            ).stdout.strip()
        except (OSError, subprocess.CalledProcessError):
            return "unknown"
    branch = os.environ.get("GITHUB_REF_NAME") or "unknown"
    return {"sha": os.environ.get("GITHUB_SHA") or show("%H"),
            "date": show("%cI"), "branch": branch}


def last_record(path: str) -> dict | None:
    try:
        with open(path) as f:
            lines = [ln for ln in f if ln.strip()]
        return json.loads(lines[-1]) if lines else None
    except (OSError, ValueError):
        return None


def delta_summary(prev: dict | None, record: dict) -> list[str]:
    """Human lines, one per metric; arrows against the previous record.
    Informational only — NEVER a gate (see module docstring)."""
    out = []
    prev_m = (prev or {}).get("metrics", {})
    for k in sorted(record["metrics"]):
        v = record["metrics"][k]
        if k in prev_m and prev_m[k]:
            pct = 100.0 * (v - prev_m[k]) / abs(prev_m[k])
            out.append(f"  {k}: {v:.6g}  ({pct:+.1f}% vs "
                       f"{str(prev.get('sha'))[:10]})")
        else:
            out.append(f"  {k}: {v:.6g}  (first record)")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench-dir", default=os.environ.get(
        "REPRO_BENCH_OUT", "artifacts/bench-smoke"))
    ap.add_argument("--out", default="artifacts/bench/trajectory.jsonl")
    ap.add_argument("--snapshot-root", default=".",
                    help="directory for local BENCH_*.json snapshots")
    ap.add_argument("--no-snapshots", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="fail (exit 1) when no artifacts are found; the "
                         "default is a clean exit so a first run with no "
                         "previous artifact never breaks the job")
    args = ap.parse_args(argv)

    metrics = collect(args.bench_dir)
    if not metrics:
        print(f"bench_record: no recognizable artifacts under "
              f"{args.bench_dir!r}; nothing recorded", file=sys.stderr)
        return 1 if args.strict else 0
    if not args.no_snapshots:
        for path in write_snapshots(args.bench_dir, args.snapshot_root):
            print(f"bench_record: snapshot {path}")
    record = {**git_meta(), "metrics": metrics}
    prev = last_record(args.out)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    with open(args.out) as f:
        n = sum(1 for ln in f if ln.strip())
    print(f"bench_record: appended {record['sha'][:10]} "
          f"({len(metrics)} metrics) -> {args.out} ({n} records)")
    for line in delta_summary(prev, record):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
