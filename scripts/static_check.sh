#!/usr/bin/env bash
# Static soundness gate (wired into scripts/tier1.sh and a blocking CI
# job): the `repro.analysis` verifier's three passes —
#   1. plan/restriction soundness over the P1-P6 pattern library
#      (+ every plan the planner builds for them),
#   2. kernel contracts for level_expand, including abstract tracing of
#      every executor call shape (--deep: eval_shape + jaxpr walk, no
#      compilation, no device),
#   3. repo-invariant AST lint over src/repro.
# Exits non-zero iff any ERROR finding is produced; extra flags are
# forwarded (e.g. `scripts/static_check.sh --lint` for the lint alone,
# or `--fsck DIR` to verify a plan-store directory).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m repro.analysis --deep "$@"
