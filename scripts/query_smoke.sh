#!/usr/bin/env bash
# Serve-path smoke (ISSUE 2 acceptance): a synthetic 2-pattern workload
# on tiny-er through launch/query_serve.py.  Each pattern is followed by
# an isomorphic relabeling of itself; --expect-min-hits asserts the
# re-queries were plan-cache hits (no second configuration search/JIT),
# and --verify checks every count against the pure-python oracle.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m repro.launch.query_serve \
  --dataset tiny-er --workload smoke --capacity 8192 \
  --single-device --verify --expect-min-hits 2 "$@"
