#!/usr/bin/env bash
# Benchmark smoke gate (wired into .github/workflows/ci.yml as a
# NON-BLOCKING job): run benchmarks/run.py smoke-sized — the quick-tier
# serve-path benchmark covers P1 (plus P2/P4) on tiny-er — and fail on
# overflowed/truncated counts or a missing/empty artifact.
#
# The benchmark itself asserts zero overflow per query (truncated counts
# are undercounts, never acceptable); the artifact gate below catches
# the silent-failure mode where the bench "passes" without measuring.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export REPRO_BENCH_OUT="${REPRO_BENCH_OUT:-artifacts/bench-smoke}"
# Tracing on: benchmarks emit <name>.trace.json + <name>.metrics.json
# next to their result artifacts (benchmarks/_util.emit), gated below.
export REPRO_TRACE=1

python -m benchmarks.run --only query

python - <<'EOF'
import json
import os
import sys

path = os.path.join(os.environ["REPRO_BENCH_OUT"], "query_throughput.json")
rows = json.load(open(path))
phases = {r["keys"]["phase"]: r for r in rows}
fail = []
for phase in ("cold", "warm", "speedup"):
    if phase not in phases:
        fail.append(f"missing {phase!r} row in {path}")
    elif not phases[phase]["value"] > 0:
        fail.append(f"{phase} throughput is {phases[phase]['value']}")
if fail:
    print("bench_smoke FAILED:", file=sys.stderr)
    for f in fail:
        print(f"  - {f}", file=sys.stderr)
    sys.exit(1)
print(f"bench_smoke OK: cold={phases['cold']['value']:.3g} q/s, "
      f"warm={phases['warm']['value']:.3g} q/s "
      f"({phases['speedup']['value']:.1f}x)")
EOF

# Observability gate: the trace artifact must exist, parse, and carry
# real spans (repro.obs summarize exits nonzero on empty/malformed),
# and the metrics snapshot must be non-empty JSON.
python -m repro.obs summarize "$REPRO_BENCH_OUT/query_throughput.trace.json"
python - <<'EOF'
import json
import os
import sys

path = os.path.join(os.environ["REPRO_BENCH_OUT"],
                    "query_throughput.metrics.json")
try:
    snap = json.load(open(path))
except (OSError, ValueError) as e:
    print(f"bench_smoke FAILED: metrics snapshot {path}: {e}",
          file=sys.stderr)
    sys.exit(1)
if not snap:
    print(f"bench_smoke FAILED: metrics snapshot {path} is empty",
          file=sys.stderr)
    sys.exit(1)
print(f"bench_smoke OK: metrics snapshot has {len(snap)} keys")
EOF

# Ground-truth question benchmark: the labeled inventory answered on
# both executor paths and scored against the brute-force oracle.  The
# benchmark itself raises on ANY oracle disagreement; the gate below
# additionally pins 100% accuracy in the persisted artifact and fails
# on a hollow inventory (fewer questions than the tier-1 floor).
python -m benchmarks.run --only questions

python - <<'EOF'
import json
import os
import shutil
import sys

path = os.path.join(os.environ["REPRO_BENCH_OUT"], "questions.json")
rows = json.load(open(path))
fail = []
acc = {r["keys"]["path"]: r for r in rows
       if "category" not in r["keys"] and "phase" not in r["keys"]}
for p in ("portable", "fused"):
    if p not in acc:
        fail.append(f"missing {p!r} accuracy row in {path}")
    elif acc[p]["value"] != 1.0:
        fail.append(f"{p} accuracy is {acc[p]['value']}, want 1.0: "
                    f"{acc[p]['extra'].get('wrong')}")
    elif acc[p]["keys"]["questions"] < 50:
        fail.append(f"only {acc[p]['keys']['questions']} questions "
                    f"(inventory floor is 50)")
if fail:
    print("bench_smoke FAILED:", file=sys.stderr)
    for f in fail:
        print(f"  - {f}", file=sys.stderr)
    sys.exit(1)
# persist the gated snapshot under its stable artifact name
dst = os.path.join(os.environ["REPRO_BENCH_OUT"], "BENCH_questions.json")
shutil.copyfile(path, dst)
n = acc["portable"]["keys"]["questions"]
print(f"bench_smoke OK: {n} questions, 100% oracle agreement on both "
      f"paths -> {dst}")
EOF

# Store hygiene ride-along: warm a plan store exactly the way a serving
# replica would, then fsck it — every record written this run must still
# verify (a non-empty quarantine fails the smoke).
python scripts/plan_warmup.py \
  --cache-dir "$REPRO_BENCH_OUT/plan-store" --patterns P1,P2 \
  --capacity 4096
scripts/static_check.sh --fsck "$REPRO_BENCH_OUT/plan-store"
