#!/usr/bin/env bash
# Docs honesty check (wired into scripts/tier1.sh):
#   1. every package/module directly under src/repro/ is mentioned in
#      README.md or DESIGN.md;
#   2. every relative markdown link in tracked *.md files resolves;
#   3. every path-looking token in README.md shell snippets names a
#      real file, and every `python -m pkg.mod` names a real module;
#   4. no stale references: every `repro.x.y` dotted module and every
#      `src/repro/...` path mentioned anywhere in the docs still exists;
#   5. no orphan packages: every directory package under src/repro (any
#      depth) is mentioned in README.md or DESIGN.md.
set -euo pipefail
cd "$(dirname "$0")/.."

python - <<'EOF'
import os
import re
import subprocess
import sys

fail = []

# --- 1. package coverage -------------------------------------------------
readme = open("README.md").read()
design = open("DESIGN.md").read()
docs = readme + design
for entry in sorted(os.listdir("src/repro")):
    if entry.startswith("__"):
        continue
    name = entry.removesuffix(".py")
    if not re.search(rf"\b{re.escape(name)}\b", docs):
        fail.append(f"package src/repro/{entry} is mentioned in neither "
                    f"README.md nor DESIGN.md")

# --- 2. relative markdown links ------------------------------------------
# PAPER/PAPERS/SNIPPETS are generated paper-extract dumps (figure links
# point into the original arxiv source) — not repo docs; skip them.
GENERATED = {"PAPER.md", "PAPERS.md", "SNIPPETS.md", "ISSUE.md"}
md_files = [
    f for f in subprocess.run(
        ["git", "ls-files", "*.md"], capture_output=True, text=True,
        check=True,
    ).stdout.split()
    if os.path.basename(f) not in GENERATED
]
link_re = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
for md in md_files:
    base = os.path.dirname(md)
    for target in link_re.findall(open(md).read()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = os.path.normpath(os.path.join(base, target.split("#")[0]))
        if not os.path.exists(path):
            fail.append(f"{md}: broken relative link -> {target}")

# --- 3. README shell snippets name real files/modules ---------------------
snippets = re.findall(r"```bash\n(.*?)```", readme, flags=re.S)
for block in snippets:
    for line in block.splitlines():
        line = line.split("#")[0]
        for mod in re.findall(r"-m\s+([\w.]+)", line):
            rel = mod.replace(".", "/")
            if not any(os.path.exists(p) for p in (
                    f"src/{rel}.py", f"src/{rel}/__init__.py",
                    f"{rel}.py", f"{rel}/__init__.py")):
                fail.append(f"README snippet names missing module: {mod}")
        for tok in re.findall(r"[\w./-]+\.(?:sh|py)\b", line):
            if "/" in tok and not os.path.exists(tok):
                fail.append(f"README snippet names missing file: {tok}")

# --- 4. stale module / path references ------------------------------------
doc_texts = {md: open(md).read() for md in md_files}
for md, text in doc_texts.items():
    for path in set(re.findall(r"src/repro[\w/.-]*", text)):
        path = path.rstrip(".")           # sentence-final period
        if not os.path.exists(path):
            fail.append(f"{md}: stale path reference -> {path}")
    for dotted in set(re.findall(r"\brepro\.[\w.]+\b", text)):
        # accept any prefix that is a real module — trailing components
        # may be attributes (repro.core.oracle.count_embeddings_oracle)
        parts = dotted.split(".")
        ok = False
        while len(parts) >= 2 and not ok:
            rel = "/".join(parts)
            ok = any(os.path.exists(p) for p in (f"src/{rel}.py",
                                                 f"src/{rel}"))
            parts = parts[:-1]
        if not ok:
            fail.append(f"{md}: stale module reference -> {dotted}")

# --- 5. orphan packages (any depth, not just top level) --------------------
for dirpath, dirnames, filenames in os.walk("src/repro"):
    dirnames[:] = [d for d in dirnames if not d.startswith("__")]
    for d in dirnames:
        if not any(f.endswith(".py")
                   for f in os.listdir(os.path.join(dirpath, d))):
            continue
        if not re.search(rf"\b{re.escape(d)}\b", docs):
            fail.append(f"orphan package {os.path.join(dirpath, d)}: "
                        f"mentioned in neither README.md nor DESIGN.md")

if fail:
    print("docs_check FAILED:", file=sys.stderr)
    for f in fail:
        print(f"  - {f}", file=sys.stderr)
    sys.exit(1)
print(f"docs_check OK ({len(md_files)} md files, "
      f"{len(snippets)} README snippets)")
EOF
