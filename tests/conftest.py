"""Shared test configuration.

Repo root goes on sys.path so tests can import the `benchmarks`
package — the labeled question inventory (benchmarks/questions.py) is
both a benchmark and the tier-1 ground-truth gate, so it must stay one
definition.

Hypothesis (optional dev dependency, requirements-dev.txt) gets a
derandomized profile so a property-test failure on CI reproduces
bit-for-bit on any machine instead of depending on a per-run entropy
seed.  Registered here rather than via a pytest.ini
`--hypothesis-profile` flag because the flag only exists when the
hypothesis plugin is installed — an unconditional addopts line would
break collection in environments without it (pytest.ini documents
this).  Set REPRO_REQUIRE_HYPOTHESIS=1 (as CI does) to turn the
missing-dependency skip in tests/test_property.py into a hard failure.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

try:
    from hypothesis import settings

    settings.register_profile("repro-ci", derandomize=True, deadline=None)
    settings.load_profile("repro-ci")
except ImportError:                  # optional dependency absent: tests
    pass                             # importorskip (or hard-fail under
                                     # REPRO_REQUIRE_HYPOTHESIS=1)
