"""End-to-end launcher smoke tests (the public CLI surface)."""
import pytest


def test_mine_end_to_end_graphpi_mode():
    from repro.launch.mine import main

    rc = main(["--pattern", "P1", "--dataset", "tiny-er", "--verify",
               "--capacity", str(1 << 14), "--single-device"])
    assert rc == 0


def test_mine_cache_dir_persists_across_invocations(tmp_path, capsys):
    from repro.launch.mine import main

    args = ["--pattern", "triangle", "--dataset", "tiny-er",
            "--capacity", str(1 << 13), "--single-device",
            "--cache-dir", str(tmp_path)]
    assert main(args) == 0
    cold = capsys.readouterr().out
    assert "cache miss" in cold
    # second process-equivalent invocation: plan + AOT executable come
    # from disk, no configuration search
    assert main(args) == 0
    warm = capsys.readouterr().out
    assert "persisted plan" in warm
    assert "search 0.000s" in warm


def test_mine_graphzero_and_naive_agree():
    from repro.launch.mine import main

    assert main(["--pattern", "P4", "--dataset", "tiny-er",
                 "--mode", "graphzero", "--verify", "--single-device"]) == 0
    assert main(["--pattern", "P4", "--dataset", "tiny-er",
                 "--mode", "naive", "--verify", "--single-device"]) == 0


@pytest.mark.slow  # tier1.sh already runs this workload via query_smoke.sh
def test_query_serve_launcher_smoke():
    from repro.launch.query_serve import main

    rc = main(["--dataset", "tiny-er", "--workload", "smoke",
               "--capacity", str(1 << 13), "--single-device", "--verify",
               "--expect-min-hits", "1"])
    assert rc == 0


def test_query_serve_request_file(tmp_path):
    import json

    from repro.launch.query_serve import main

    reqs = tmp_path / "reqs.jsonl"
    reqs.write_text("\n".join([
        json.dumps({"pattern": "P1", "verify": True}),
        json.dumps({"pattern": {"n": 3, "edges": [[2, 1], [0, 2], [1, 0]]},
                    "verify": True}),
        json.dumps({"pattern": "P1", "verify": True}),   # exact re-query: hit
    ]))
    rc = main(["--dataset", "tiny-er", "--requests", str(reqs),
               "--capacity", str(1 << 13), "--single-device",
               "--expect-min-hits", "1"])
    assert rc == 0


def test_serve_launcher_smoke():
    from repro.launch.serve import main

    rc = main(["--arch", "qwen3-1.7b", "--smoke", "--batch", "2",
               "--prompt-len", "16", "--gen", "4"])
    assert rc == 0


def test_train_launcher_smoke(tmp_path):
    from repro.launch.train import main

    rc = main(["--arch", "mamba2-370m", "--smoke", "--steps", "3",
               "--batch", "2", "--seq", "16",
               "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
               "--log-every", "1"])
    assert rc == 0
