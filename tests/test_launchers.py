"""End-to-end launcher smoke tests (the public CLI surface)."""
import pytest


def test_mine_end_to_end_graphpi_mode():
    from repro.launch.mine import main

    rc = main(["--pattern", "P1", "--dataset", "tiny-er", "--verify",
               "--capacity", str(1 << 14), "--single-device"])
    assert rc == 0


def test_mine_graphzero_and_naive_agree():
    from repro.launch.mine import main

    assert main(["--pattern", "P4", "--dataset", "tiny-er",
                 "--mode", "graphzero", "--verify", "--single-device"]) == 0
    assert main(["--pattern", "P4", "--dataset", "tiny-er",
                 "--mode", "naive", "--verify", "--single-device"]) == 0


def test_serve_launcher_smoke():
    from repro.launch.serve import main

    rc = main(["--arch", "qwen3-1.7b", "--smoke", "--batch", "2",
               "--prompt-len", "16", "--gen", "4"])
    assert rc == 0


def test_train_launcher_smoke(tmp_path):
    from repro.launch.train import main

    rc = main(["--arch", "mamba2-370m", "--smoke", "--steps", "3",
               "--batch", "2", "--seq", "16",
               "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
               "--log-every", "1"])
    assert rc == 0
