"""Gateway serving front door: deterministic scheduling, coalescing,
plan/enqueue parity with the deprecated submit path, LM session resume,
and the perf-model bucket layout."""
import numpy as np
import pytest

from repro.configs.graphpi import get_pattern
from repro.core.executor import ExecutorConfig, auto_buckets, compute_stats
from repro.core.perf_model import GraphStats, predicted_frontier_occupancy
from repro.graph.datasets import erdos_renyi, rmat
from repro.query import QueryEngine, QueryRequest, relabeled_variant
from repro.serve.gateway import (
    Gateway, GraphQueryWorkload, RoundScheduler, Share, StepReport,
)

CFG = ExecutorConfig(capacity=1 << 12)


# --------------------------------------------------------------- scheduler
class Scripted:
    """Workload fake: `items` units of work, fixed per-item seconds."""

    def __init__(self, name, items, seconds_per_item=0.0):
        self.name = name
        self.left = items
        self.spi = seconds_per_item
        self.warmed = False

    def warmup(self):
        self.warmed = True

    def ready(self):
        return self.left > 0

    def step(self, quantum):
        n = min(quantum, self.left)
        self.left -= n
        return StepReport(items=n, seconds=self.spi * n)

    def metrics(self):
        return {"left": self.left}


def test_scheduler_fairness_known_interleaving():
    """Two workloads with fixed shares → a fully determined trace."""
    a = Scripted("a", 4)
    b = Scripted("b", 4)
    sched = RoundScheduler({"a": Share(quantum=2, weight=1),
                            "b": Share(quantum=1, weight=2)})
    trace = sched.run([a, b])
    # round: a takes 1 turn of 2 items; b takes 2 turns of 1 item each
    assert trace.interleaving() == ["a", "b", "b", "a", "b", "b"]
    assert trace.items_of("a") == 4
    assert trace.items_of("b") == 4
    assert trace.rounds == 2


def test_scheduler_priority_orders_turns():
    a = Scripted("a", 2)
    b = Scripted("b", 2)
    sched = RoundScheduler({"b": Share(quantum=1, priority=1)},
                           default=Share(quantum=1))
    trace = sched.run([a, b])
    assert trace.interleaving() == ["b", "a", "b", "a"]


def test_scheduler_drains_unbalanced_workloads():
    """A workload going idle stops receiving turns; the other finishes."""
    a = Scripted("a", 1)
    b = Scripted("b", 5)
    trace = RoundScheduler(default=Share(quantum=2)).run([a, b])
    assert trace.items_of("a") == 1
    assert trace.items_of("b") == 5
    # a's only turn is contended, b's last turns are solo
    assert [t.contended for t in trace.turns if t.name == "a"] == [True]
    assert [t.contended for t in trace.turns if t.name == "b"][-1] is False


def test_scheduler_breaks_on_stalled_workload():
    """A workload claiming ready() but making no progress must not spin
    the gateway forever."""

    class Stalled(Scripted):
        def step(self, quantum):
            return StepReport(items=0, seconds=0.0)

    trace = RoundScheduler().run([Stalled("s", 3)])
    assert trace.rounds == 1


def test_gateway_report_splits_solo_and_contended():
    a = Scripted("a", 6, seconds_per_item=0.01)
    b = Scripted("b", 2, seconds_per_item=0.01)
    gw = Gateway(scheduler=RoundScheduler(default=Share(quantum=2)))
    gw.add(a)
    gw.add(b)
    gw.run()
    assert a.warmed and b.warmed
    rep = gw.report()["workloads"]["a"]
    assert rep["items"] == 6
    assert rep["turn_item_ms"]["contended"]["n"] >= 1
    assert rep["turn_item_ms"]["solo"]["n"] >= 1
    assert rep["interference_x"] == pytest.approx(1.0, rel=0.2)


def test_gateway_rejects_duplicate_names():
    gw = Gateway()
    gw.add(Scripted("a", 1))
    with pytest.raises(ValueError):
        gw.add(Scripted("a", 1))


# ------------------------------------------------------- engine round path
@pytest.fixture(scope="module")
def tiny_graph():
    return erdos_renyi(64, 256, seed=7, name="er64")


@pytest.fixture(scope="module")
def tiny_stats(tiny_graph):
    return compute_stats(tiny_graph, CFG)


@pytest.fixture()
def fresh_engine(tiny_graph, tiny_stats):
    return QueryEngine(tiny_graph, cfg=CFG, stats=tiny_stats)


def test_coalescing_one_execution_many_tickets(fresh_engine):
    """N iso-variant queries in one round → 1 cache entry, 1 execution,
    N tickets resolved with the same count."""
    p = get_pattern("P1")
    tickets = [fresh_engine.enqueue(QueryRequest(relabeled_variant(p, seed=s)))
               for s in range(4)]
    resolved = fresh_engine.run_pending()
    assert resolved == tickets
    assert all(t.done for t in tickets)
    assert len({t.result.count for t in tickets}) == 1
    assert len(fresh_engine.cache) == 1
    assert fresh_engine.executions == 1
    assert fresh_engine.coalesced == 3
    entry = fresh_engine.cache.entries()[0]
    assert entry.executions == 1
    # the lead ticket paid the miss; riders are accounted as hits
    assert [t.result.cache_hit for t in tickets] == [False, True, True, True]
    assert [t.result.coalesced for t in tickets] == [False, True, True, True]
    assert fresh_engine.cache.stats.hits == 3
    assert fresh_engine.cache.stats.n_searches == 1
    assert fresh_engine.cache.stats.n_compiles == 1


def test_gateway_graph_workload_round(fresh_engine):
    """Same property driven through the Gateway's scheduler."""
    p = get_pattern("triangle")
    reqs = [QueryRequest(relabeled_variant(p, seed=s)) for s in range(3)]
    gw = Gateway()
    wl = gw.add(GraphQueryWorkload(fresh_engine, reqs),
                Share(quantum=len(reqs)))
    gw.run()
    results = wl.results()
    assert len(results) == 3
    assert len({r.count for r in results}) == 1
    assert fresh_engine.executions == 1
    assert fresh_engine.pending() == 0
    assert wl.metrics()["coalesced"] == 2


def test_distinct_classes_micro_batch_in_one_round(fresh_engine):
    """Distinct classes in a round each execute once (no cross-class
    merging), in one scheduler turn."""
    reqs = [QueryRequest(get_pattern("triangle")),
            QueryRequest(get_pattern("rectangle")),
            QueryRequest(relabeled_variant(get_pattern("triangle"), 5))]
    for r in reqs:
        fresh_engine.enqueue(r)
    resolved = fresh_engine.run_pending()
    assert len(resolved) == 3
    assert fresh_engine.executions == 2
    assert fresh_engine.coalesced == 1
    assert len(fresh_engine.cache) == 2


def test_plan_never_executes(fresh_engine):
    planned = fresh_engine.plan(QueryRequest(get_pattern("triangle")))
    assert not planned.cache_hit
    assert fresh_engine.executions == 0
    assert planned.entry.executions == 0
    # planning again is a pure cache hit
    assert fresh_engine.plan(QueryRequest(get_pattern("triangle"))).cache_hit


def test_unresolved_ticket_raises(fresh_engine):
    t = fresh_engine.enqueue(QueryRequest(get_pattern("triangle")))
    assert not t.done
    with pytest.raises(RuntimeError):
        _ = t.result


# ------------------------------------------- submit parity + deprecation
@pytest.fixture(scope="module")
def parity_engine(tiny_graph, tiny_stats):
    return QueryEngine(tiny_graph, cfg=CFG, stats=tiny_stats)


@pytest.mark.parametrize("name", ["P1", "P2", "P3", "P4", "P5", "P6"])
def test_plan_enqueue_parity_with_submit(parity_engine, name):
    """The deprecated submit() and the new plan/enqueue rounds must
    produce identical counts for every paper pattern."""
    p = get_pattern(name)
    with pytest.deprecated_call():
        old = parity_engine.submit(QueryRequest(p))
    ticket = parity_engine.enqueue(
        QueryRequest(relabeled_variant(p, seed=11)))
    parity_engine.run_pending()
    new = ticket.result
    assert new.count == old.count
    assert new.canon_key == old.canon_key
    assert new.cache_hit          # submit's round planted the entry
    assert not old.overflowed and not new.overflowed


def test_serve_shim_deprecated_and_sequential(fresh_engine):
    p = get_pattern("triangle")
    with pytest.deprecated_call():
        results = fresh_engine.serve(
            [QueryRequest(p), QueryRequest(relabeled_variant(p, 3))])
    # one request per round: the re-query is a true cache hit, not a
    # coalesced rider (bit-identical legacy accounting)
    assert [r.cache_hit for r in results] == [False, True]
    assert [r.coalesced for r in results] == [False, False]
    assert fresh_engine.executions == 2


def test_submit_drains_fifo_tickets_ahead_of_it(fresh_engine):
    """submit() on an engine with older pending tickets resolves them
    first (FIFO) and still returns its own result."""
    early = fresh_engine.enqueue(QueryRequest(get_pattern("triangle")))
    with pytest.deprecated_call():
        res = fresh_engine.submit(QueryRequest(get_pattern("rectangle")))
    assert early.done
    assert res.pattern_name == "rectangle"
    assert fresh_engine.executions == 2


# ------------------------------------------------------- LM session resume
@pytest.mark.parametrize("arch", ["qwen3-1.7b"])
def test_lmsession_resume_matches_uninterrupted(tmp_path, arch):
    """Kill a session mid-generation; resuming from its checkpoint must
    reproduce the uninterrupted run's remaining tokens exactly."""
    from repro.serve.session import LMSession

    kw = dict(smoke=True, batch=2, prompt_len=8, gen=4, seed=0)
    full = LMSession(arch, **kw)
    full.start()
    while full.remaining:
        full.decode_steps(4)
    ref = full.tokens_out()            # [B, 5]: prefill tok + 4 steps

    interrupted = LMSession(arch, **kw, ckpt_dir=str(tmp_path),
                            ckpt_every=2)
    interrupted.start()
    interrupted.decode_steps(2)        # checkpoint lands at step 2
    # "preemption": a fresh session restores and finishes the generation
    resumed = LMSession(arch, **kw, ckpt_dir=str(tmp_path))
    assert resumed.start(resume=True) == 2
    assert resumed.remaining == 2
    while resumed.remaining:
        resumed.decode_steps(1)
    np.testing.assert_array_equal(resumed.tokens_out(), ref[:, 2:])
    assert resumed.metrics()["resumed_from"] == 2


def test_lmsession_resume_without_checkpoint_prefills(tmp_path):
    from repro.serve.session import LMSession

    s = LMSession("qwen3-1.7b", smoke=True, batch=2, prompt_len=8, gen=1,
                  ckpt_dir=str(tmp_path))
    assert s.start(resume=True) is None     # nothing to restore
    assert s.resumed_from is None
    assert s.remaining == 1


# ------------------------------------------------ continuous LM batching
def test_lmsession_continuous_batching_bit_exact():
    """Evict one sequence mid-decode and admit a fresh one into its
    slot: the evicted prefix and the UNDISTURBED row must both be
    bit-identical to an uninterrupted reference run — admission touches
    only the freed slot's cache rows."""
    from repro.obs.metrics import MetricsRegistry
    from repro.serve.session import LMSession

    kw = dict(smoke=True, batch=2, prompt_len=8, gen=4, seed=0)
    full = LMSession("qwen3-1.7b", **kw)
    full.start()
    while full.remaining:
        full.decode_steps(4)
    ref = full.tokens_out()                # [2, 5]: prefill tok + 4 steps

    reg = MetricsRegistry()
    s = LMSession("qwen3-1.7b", **kw, metrics=reg)
    s.start()
    assert s.metrics()["slots_active"] == 2
    s.decode_steps(2)
    gone = s.evict(1)
    np.testing.assert_array_equal(gone, ref[1, :3])   # prefill + 2 steps
    assert s.slots()[1]["active"] is False
    with pytest.raises(ValueError):
        s.evict(1)                         # double-evict refused
    slot = s.admit(seed=12345)             # joins mid-decode at pos S
    assert slot == 1
    assert s.slots()[1] == {"active": True, "pos": 8, "taken": 0,
                            "budget": 4}
    with pytest.raises(RuntimeError):
        s.admit()                          # batch full again
    while s.remaining:                     # newbie owes 4 more steps
        s.decode_steps(2)
    row0 = s.evict(0)
    np.testing.assert_array_equal(row0, ref[0])   # undisturbed row exact
    newbie = s.evict(1)
    assert newbie.shape == (5,)            # its own prefill + 4 steps
    assert not np.array_equal(newbie, ref[1])     # genuinely a new prompt
    m = s.metrics()
    assert (m["admitted"], m["evicted"], m["slots_active"]) == (1, 3, 0)
    snap = reg.snapshot()
    assert snap["lm.admitted"] == 1
    assert snap["lm.evicted"] == 3
    assert snap["lm.slots_active"] == 0


# --------------------------------------------------- model bucket layout
def test_predicted_frontier_occupancy_edge_weighted():
    deg = np.array([1, 1, 2, 4], dtype=np.int32)
    stats = GraphStats(4, 4, tri_cnt=0)     # p2=0 → amplification 1
    assert predicted_frontier_occupancy(stats, deg, 1) == pytest.approx(6 / 8)
    assert predicted_frontier_occupancy(stats, deg, 2) == pytest.approx(4 / 8)
    assert predicted_frontier_occupancy(stats, deg, 4) == 0.0
    # amplification is clamped to [1, 4] and never exceeds occupancy 1
    dense = GraphStats(4, 4, tri_cnt=10**9)
    assert predicted_frontier_occupancy(dense, deg, 1) <= 1.0
    assert (predicted_frontier_occupancy(dense, deg, 2)
            >= predicted_frontier_occupancy(stats, deg, 2))


def test_model_buckets_layout_and_exact_count():
    from repro.core.executor import Matcher
    from repro.core.oracle import count_embeddings_oracle
    from repro.core.pattern import clique
    from repro.core.plan import build_plan
    from repro.core.restrictions import generate_restriction_sets

    g = rmat(8, 6, seed=7, name="rmat8")
    stats = GraphStats(g.n, g.m, tri_cnt=max(g.m, 1))   # plan-time proxy
    # thresholds shrunk so the tiny CI graph exercises all three buckets
    legacy = auto_buckets(g, small=8, mid=32)
    model = auto_buckets(g, small=8, mid=32, stats=stats)
    widths = [w for w, _ in model]
    assert widths == sorted(widths)
    assert widths[-1] >= g.max_degree
    assert all(0 < f <= 1.0 for _, f in model)
    assert [w for w, _ in legacy] == widths     # same thresholds, new fracs
    # the layouts genuinely differ: occupancy is edge-weighted, not the
    # 4×-padded vertex share
    assert model != legacy

    tri = clique(3)
    plan = build_plan(tri, (0, 1, 2),
                      generate_restriction_sets(tri, max_sets=1)[0])
    expect = count_embeddings_oracle(g.n, g.edge_array(), tri)
    got = Matcher(g, plan, ExecutorConfig(capacity=1 << 12,
                                          degree_buckets=model)).count()
    assert got.count == expect
    assert not got.overflowed
    # the layout is part of the compiled-program fingerprint
    assert ExecutorConfig(degree_buckets=model).fingerprint() != \
        ExecutorConfig(degree_buckets=legacy).fingerprint()
