import numpy as np
import pytest

from repro.core.config_search import graphzero_configuration, search_configuration
from repro.core.executor import (
    CountResult, ExecutorConfig, compute_stats, count_embeddings,
)
from repro.core.oracle import count_embeddings_oracle
from repro.core.pattern import clique, cycle, house, rectangle, star, triangle
from repro.core.plan import best_iep_k, build_plan
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules
from repro.graph.datasets import complete_graph, erdos_renyi, rmat

CFG = ExecutorConfig(capacity=1 << 14)
PATTERNS = [triangle(), rectangle(), house(), clique(4), cycle(5), star(4)]


def _p(pat, slow=False):
    """Parametrize a pattern, optionally tagging the case slow (the deep
    rmat expansions dominate suite wall time; `pytest -m ""` runs all)."""
    return pytest.param(
        pat, id=pat.name, marks=[pytest.mark.slow] if slow else [])


# rmat cases compile/run the full 16k-capacity frontier per level; the
# 4+-deep patterns are the suite's slowest tests.
RMAT_PATTERNS = [_p(triangle()), _p(rectangle()), _p(house(), slow=True),
                 _p(clique(4)), _p(cycle(5), slow=True),
                 _p(star(4), slow=True)]


@pytest.fixture(scope="module")
def er_graph():
    return erdos_renyi(64, 420, seed=3)


@pytest.fixture(scope="module")
def rmat_graph():
    return rmat(8, 6, seed=11)


@pytest.mark.parametrize("pattern", PATTERNS, ids=lambda p: p.name)
def test_counts_match_oracle_er(er_graph, pattern):
    want = count_embeddings_oracle(er_graph.n, er_graph.edge_array(), pattern)
    order = generate_schedules(pattern)[0]
    rs = generate_restriction_sets(pattern, max_sets=1)[0]
    got = count_embeddings(er_graph, build_plan(pattern, order, rs), CFG)
    assert not got.overflowed
    assert got.count == want


@pytest.mark.parametrize("pattern", RMAT_PATTERNS)
def test_counts_match_oracle_rmat(rmat_graph, pattern):
    """Power-law graph exercises skewed windows + sentinel padding."""
    want = count_embeddings_oracle(rmat_graph.n, rmat_graph.edge_array(), pattern)
    order = generate_schedules(pattern)[0]
    rs = generate_restriction_sets(pattern, max_sets=1)[0]
    got = count_embeddings(rmat_graph, build_plan(pattern, order, rs), CFG)
    assert got.count == want


@pytest.mark.parametrize("pattern", PATTERNS, ids=lambda p: p.name)
def test_iep_counts_match_enumeration(er_graph, pattern):
    order = generate_schedules(pattern)[0]
    rs = generate_restriction_sets(pattern, max_sets=1)[0]
    k = best_iep_k(pattern, order, rs)
    if k < 1:
        pytest.skip("no sound IEP folding for this configuration")
    plan = build_plan(pattern, order, rs, iep_k=k)
    want = count_embeddings_oracle(er_graph.n, er_graph.edge_array(), pattern)
    got = count_embeddings(er_graph, plan, CFG)
    assert got.count == want


def test_complete_graph_closed_form():
    # K_10: #house = C(10,5) * 5!/|Aut| embeddings per 5-subset
    g = complete_graph(10)
    h = house()
    order = generate_schedules(h)[0]
    rs = generate_restriction_sets(h, max_sets=1)[0]
    got = count_embeddings(g, build_plan(h, order, rs), CFG)
    from math import comb, factorial
    want = comb(10, 5) * factorial(5) // h.aut_count()
    assert got.count == want


@pytest.mark.slow
def test_all_restriction_sets_agree(er_graph):
    p = clique(4)
    order = generate_schedules(p)[0]
    counts = set()
    for rs in generate_restriction_sets(p, max_sets=8):
        counts.add(count_embeddings(er_graph, build_plan(p, order, rs), CFG).count)
    assert len(counts) == 1


@pytest.mark.slow
def test_all_schedules_agree(er_graph):
    p = house()
    rs = generate_restriction_sets(p, max_sets=1)[0]
    counts = set()
    for order in generate_schedules(p)[:8]:
        counts.add(count_embeddings(er_graph, build_plan(p, order, rs), CFG).count)
    assert len(counts) == 1


def test_capacity_overflow_recovers_by_bisection(er_graph):
    """A tiny capacity must still give the right answer via host-side
    chunk bisection (straggler/elasticity mechanism)."""
    p = triangle()
    order = (0, 1, 2)
    rs = generate_restriction_sets(p, max_sets=1)[0]
    small = ExecutorConfig(capacity=256)
    got = count_embeddings(er_graph, build_plan(p, order, rs), small)
    want = count_embeddings_oracle(er_graph.n, er_graph.edge_array(), p)
    assert got.count == want


def test_static_base_matches_dynamic(er_graph):
    p = house()
    order = generate_schedules(p)[0]
    rs = generate_restriction_sets(p, max_sets=1)[0]
    plan = build_plan(p, order, rs)
    a = count_embeddings(er_graph, plan, ExecutorConfig(capacity=1 << 14, dynamic_base=True))
    b = count_embeddings(er_graph, plan, ExecutorConfig(capacity=1 << 14, dynamic_base=False))
    assert a.count == b.count


def test_compute_stats_triangle_count(er_graph):
    stats = compute_stats(er_graph)
    assert stats.tri_cnt == er_graph.triangle_count_numpy()
    assert stats.n_vertices == er_graph.n
    assert stats.n_edges == er_graph.m


def test_search_configuration_end_to_end(er_graph):
    stats = compute_stats(er_graph)
    res = search_configuration(house(), stats, use_iep=True)
    plan = res.plan(house())
    got = count_embeddings(er_graph, plan, CFG)
    want = count_embeddings_oracle(er_graph.n, er_graph.edge_array(), house())
    assert got.count == want
    # the chosen config must be the cheapest among all candidates ranked
    assert res.best.predicted_cost == min(
        c.predicted_cost for c in res.all_configs
    )
    # the GraphZero-style baseline still counts correctly
    gz = graphzero_configuration(house(), stats)
    gz_plan = build_plan(house(), gz.order, gz.res_set, iep_k=gz.iep_k)
    assert count_embeddings(er_graph, gz_plan, CFG).count == want
