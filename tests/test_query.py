"""Query subsystem: canonicalization, plan cache, serving engine."""
import numpy as np
import pytest

from repro.configs.graphpi import EXTRA_PATTERNS, PATTERNS, get_pattern
from repro.core.executor import ExecutorConfig, compute_stats
from repro.core.oracle import count_embeddings_oracle
from repro.core.pattern import Pattern, cycle, path, star
from repro.graph.datasets import erdos_renyi
from repro.query import (
    PlanCache, QueryEngine, QueryRequest, canonical_form, canonical_key,
    relabeled_variant,
)

CFG = ExecutorConfig(capacity=1 << 12)


@pytest.fixture(scope="module")
def tiny_graph():
    return erdos_renyi(64, 256, seed=7, name="er64")


@pytest.fixture(scope="module")
def engine(tiny_graph):
    return QueryEngine(tiny_graph, cfg=CFG)


# ------------------------------------------------------------- canonicalization
@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_isomorphic_variants_hash_identically(name):
    p = get_pattern(name)
    key = canonical_key(p)
    for seed in range(4):
        v = relabeled_variant(p, seed=seed)
        assert canonical_key(v) == key, (name, seed)


def test_canonical_form_is_isomorphic_and_idempotent():
    for name in sorted(PATTERNS) + sorted(EXTRA_PATTERNS):
        p = get_pattern(name)
        form = canonical_form(p)
        assert form.n == p.n and form.m == p.m
        assert sorted(form.degree(v) for v in range(form.n)) == \
            sorted(p.degree(v) for v in range(p.n))
        assert canonical_key(form) == canonical_key(p)
        assert canonical_form(form).edges == form.edges


def test_non_isomorphic_patterns_never_collide():
    pats = {name: get_pattern(name)
            for name in sorted(PATTERNS) + sorted(EXTRA_PATTERNS)}
    pats["path5"] = path(5)
    pats["star5"] = star(5)
    pats["cycle7"] = cycle(7)
    keys = {name: canonical_key(p) for name, p in pats.items()}
    names = sorted(pats)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            assert keys[a] != keys[b], (a, b)


def test_canonical_key_is_stable_across_processes():
    # regression pin: sha256 of the canonical form must never drift, or
    # persisted / cross-replica cache keys go stale silently
    assert canonical_key(get_pattern("triangle")) == canonical_key(
        Pattern(3, ((2, 1), (0, 2), (1, 0))))
    assert canonical_key(cycle(4)) == \
        "09936e89b622b79de515caad45084940c92ed6845cd3c709570a28e22cf7ac72"


# ----------------------------------------------------------------- plan cache
def test_cache_hit_on_isomorphic_requery(engine):
    r0 = engine.submit(QueryRequest(get_pattern("P1")))
    searches = engine.cache.stats.n_searches
    compiles = engine.cache.stats.n_compiles
    r1 = engine.submit(QueryRequest(relabeled_variant(get_pattern("P1"), 11)))
    assert r1.cache_hit
    assert r1.canon_key == r0.canon_key
    assert r1.count == r0.count
    # a hit never re-searches or re-compiles
    assert engine.cache.stats.n_searches == searches
    assert engine.cache.stats.n_compiles == compiles
    assert r1.search_seconds == 0.0 and r1.compile_seconds == 0.0


def test_cache_key_separates_options(tiny_graph):
    stats = compute_stats(tiny_graph, CFG)
    from repro.query.cache import graph_fingerprint

    fp = graph_fingerprint(tiny_graph, stats)
    p = get_pattern("P2")
    base = PlanCache.entry_key(p, fp, CFG)
    assert PlanCache.entry_key(relabeled_variant(p, 3), fp, CFG) == base
    assert PlanCache.entry_key(p, fp, CFG, use_iep=True) != base
    assert PlanCache.entry_key(p, fp, CFG, mode="naive") != base
    # naive ignores use_iep: the flag must not split the entry
    assert PlanCache.entry_key(p, fp, CFG, mode="naive", use_iep=True) == \
        PlanCache.entry_key(p, fp, CFG, mode="naive")
    from repro.query.cache import layout_fingerprint

    # chunk width is part of the compiled trace → part of the key; None
    # and the explicit default resolve to the SAME fingerprint
    assert layout_fingerprint(None, "data", None, CFG) == \
        layout_fingerprint(None, "data", CFG.capacity, CFG)
    assert PlanCache.entry_key(
        p, fp, CFG, layout_fp=layout_fingerprint(None, "data", 512, CFG)
    ) != base
    shard_a = ("sharded", "data", 64, (("data", 2),), ("cpu:0", "cpu:1"))
    shard_b = ("sharded", "data", 512, (("data", 2),), ("cpu:0", "cpu:1"))
    assert PlanCache.entry_key(p, fp, CFG, layout_fp=shard_a) != base
    # different stripe chunk = different compiled program = different entry
    assert PlanCache.entry_key(p, fp, CFG, layout_fp=shard_a) != \
        PlanCache.entry_key(p, fp, CFG, layout_fp=shard_b)
    assert PlanCache.entry_key(
        p, fp, ExecutorConfig(capacity=1 << 13)) != base
    other = erdos_renyi(64, 256, seed=8, name="er64b")
    assert PlanCache.entry_key(
        p, graph_fingerprint(other, stats), CFG) != base


def test_cache_lru_eviction(tiny_graph):
    stats = compute_stats(tiny_graph, CFG)
    cache = PlanCache(max_entries=2)
    for name in ("triangle", "rectangle", "clique4"):
        cache.get_or_build(get_pattern(name), tiny_graph, stats,
                           cfg=CFG, warm=False)
    assert len(cache) == 2
    assert cache.stats.evictions == 1
    # triangle was evicted → rebuilding it is a miss
    _, hit = cache.get_or_build(get_pattern("triangle"), tiny_graph, stats,
                                cfg=CFG, warm=False)
    assert not hit


# -------------------------------------------------------------------- engine
@pytest.mark.parametrize("name,use_iep", [
    ("P1", False), ("P2", True), ("triangle", False), ("rectangle", True),
])
def test_engine_counts_match_oracle(engine, tiny_graph, name, use_iep):
    res = engine.submit(QueryRequest(get_pattern(name), use_iep=use_iep,
                                     verify=True))
    assert res.verified, (res.count, res.expected)
    assert res.count == count_embeddings_oracle(
        tiny_graph.n, tiny_graph.edge_array(), get_pattern(name))
    assert not res.overflowed


def test_engine_modes_agree(engine):
    p = get_pattern("P4")
    counts = {mode: engine.submit(QueryRequest(p, mode=mode)).count
              for mode in ("graphpi", "graphzero", "naive")}
    assert len(set(counts.values())) == 1, counts


def test_engine_summary_reports_latencies(engine):
    engine.submit(QueryRequest(get_pattern("triangle")))
    s = engine.summary()
    assert s["latency"]["n"] >= 1
    assert s["latency"]["p99_ms"] >= s["latency"]["p50_ms"] >= 0.0
    assert s["cache"]["misses"] >= 1
    assert s["cache_entries"] == s["cache"]["misses"] - s["cache"]["evictions"]
