import numpy as np
import pytest

from repro.core.pattern import (
    Pattern, clique, cycle, house, path, perm_to_cycles, rectangle, star,
    triangle, two_cycles_of,
)


def test_aut_counts():
    assert triangle().aut_count() == 6
    assert rectangle().aut_count() == 8
    assert house().aut_count() == 2
    assert clique(4).aut_count() == 24
    assert clique(5).aut_count() == 120
    assert cycle(5).aut_count() == 10       # dihedral D5
    assert cycle(6).aut_count() == 12
    assert path(4).aut_count() == 2
    assert star(5).aut_count() == 24        # 4 leaves permute


def test_seven_clique_has_5040_automorphisms():
    # paper §II-B: "For a 7-clique pattern each embedding has 5,040
    # automorphisms"
    assert clique(7).aut_count() == 5040


def test_cycle_decomposition():
    p = (0, 3, 2, 1)           # (B,D) swap of the rectangle example
    cyc = perm_to_cycles(p)
    assert sorted(map(len, cyc)) == [1, 1, 2]
    assert two_cycles_of(p) == [(1, 3)]


def test_relabel_preserves_structure():
    h = house()
    r = h.relabel((4, 3, 2, 1, 0))
    assert r.m == h.m
    assert r.aut_count() == h.aut_count()


def test_max_independent_set():
    assert clique(5).max_independent_set_size() == 1
    assert rectangle().max_independent_set_size() == 2
    assert star(5).max_independent_set_size() == 4
    assert cycle(6).max_independent_set_size() == 3


def test_invalid_patterns_rejected():
    with pytest.raises(ValueError):
        Pattern(3, ((0, 0),))
    with pytest.raises(ValueError):
        Pattern(3, ((0, 1), (1, 0)))
    with pytest.raises(ValueError):
        Pattern(2, ((0, 5),))


def test_connectivity():
    assert house().is_connected()
    assert not Pattern(4, ((0, 1), (2, 3))).is_connected()
