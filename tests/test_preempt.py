"""Preemptive graph quanta + multi-tenant admission (ISSUE 9).

The load-bearing claims: a count preempted mid-isomorphism-class and
resumed across rounds is BIT-IDENTICAL to its uninterrupted twin; a
suspended class rotates behind other waiting classes (budget sharing);
weighted round-robin keeps an adversarially-huge tenant from starving a
small one; admission rejection is deterministic and counted; and the
scheduler keeps granting rounds to a workload that dispatches kernels
without resolving tickets (StepReport.progressed)."""
import pytest

from repro.configs.graphpi import get_pattern
from repro.core.executor import ExecutorConfig, compute_stats
from repro.graph.datasets import erdos_renyi
from repro.query import (
    AdmissionRejected, QueryEngine, QueryRequest, Rejection,
)
from repro.serve.gateway import (
    Gateway, GraphQueryWorkload, RoundScheduler, Share, StepReport,
)

CFG = ExecutorConfig(capacity=1 << 12)


@pytest.fixture(scope="module")
def graph():
    return erdos_renyi(64, 256, seed=7, name="er64")


@pytest.fixture(scope="module")
def stats(graph):
    return compute_stats(graph, CFG)


@pytest.fixture(scope="module")
def reference_counts(graph, stats):
    """Uninterrupted counts (chunk=8, no budget) for the patterns the
    preemption tests replay."""
    eng = QueryEngine(graph, cfg=CFG, stats=stats, chunk=8)
    out = {}
    for name in ("triangle", "P1", "P3"):
        t = eng.enqueue(QueryRequest(get_pattern(name)))
        eng.run_pending()
        out[name] = (t.result.count, eng.last_round_dispatches)
    return out


# ----------------------------------------------------- count bit-identity
def test_preempted_count_bit_identical(graph, stats, reference_counts):
    """max_dispatches=1: every round runs ONE kernel dispatch and
    checkpoints; the final count matches the uninterrupted run exactly,
    and intermediate rounds resolve nothing."""
    ref_count, ref_dispatches = reference_counts["P3"]
    eng = QueryEngine(graph, cfg=CFG, stats=stats, chunk=8,
                      preempt_dispatches=1)
    t = eng.enqueue(QueryRequest(get_pattern("P3")))
    rounds = 0
    while not t.done:
        resolved = eng.run_pending()
        rounds += 1
        assert rounds <= ref_dispatches
        if not t.done:
            assert resolved == []          # suspended: nothing resolves
            assert eng.inflight() == 1
    assert t.result.count == ref_count
    assert rounds == ref_dispatches        # 1 dispatch per round
    assert eng.preemptions == rounds - 1
    assert eng.executions == 1             # one completed class execution
    assert eng.inflight() == 0


def test_default_path_unaffected(graph, stats, reference_counts):
    """No budget → one round, identical count and dispatch total (the
    pre-preemption behaviour is the bit-exact default)."""
    ref_count, ref_dispatches = reference_counts["P1"]
    eng = QueryEngine(graph, cfg=CFG, stats=stats, chunk=8)
    t = eng.enqueue(QueryRequest(get_pattern("P1")))
    resolved = eng.run_pending()
    assert [x.seq for x in resolved] == [t.seq]
    assert t.result.count == ref_count
    assert eng.last_round_dispatches == ref_dispatches
    assert eng.preemptions == 0


def test_mouse_finishes_while_whale_suspended(graph, stats,
                                              reference_counts):
    """A suspended class rotates to the BACK of the in-flight queue, so
    a cheap query enqueued behind a whale completes while the whale is
    still mid-flight.  Whale = P3 in naive mode (111 dispatches on er64
    at chunk=8); mouse = triangle (8 dispatches).  Budget 8: rounds 1-2
    feed the whale, round 3 belongs to the mouse, which finishes with
    the whale ~95 dispatches from done."""
    ref_count, _ = reference_counts["P3"]
    tri_count, tri_dispatches = reference_counts["triangle"]
    assert tri_dispatches == 8             # layout guard for the schedule
    eng = QueryEngine(graph, cfg=CFG, stats=stats, chunk=8,
                      preempt_dispatches=8)
    whale = eng.enqueue(QueryRequest(get_pattern("P3"), mode="naive",
                                     tenant="whale"))
    assert eng.run_pending() == []         # whale: 8/111, suspended
    assert not whale.done
    mouse = eng.enqueue(QueryRequest(get_pattern("triangle"),
                                     tenant="mouse"))
    eng.run_pending()                      # whale resumes (front), 16/111;
    #                                        rotates behind the mouse
    r3 = eng.run_pending()                 # mouse's full-budget round
    assert mouse.done and not whale.done   # fairness: mouse didn't wait
    assert [t.seq for t in r3] == [mouse.seq]
    assert mouse.result.count == tri_count
    assert eng.inflight() == 1             # whale still checkpointed
    for _ in range(40):                    # drain the whale
        if whale.done:
            break
        eng.run_pending()
    assert whale.done
    assert whale.result.count == ref_count  # naive mode, same class count
    assert eng.preemptions >= 13           # whale suspended ~14 times
    assert eng.tenant_report()["mouse"]["resolved"] == 1


def test_wrr_keeps_small_tenant_ahead_of_flood(graph, stats):
    """An adversarial tenant floods 6 tickets; a small tenant's single
    later ticket is still taken in the first round (weighted round-robin
    across tenant queues, not global FIFO)."""
    eng = QueryEngine(graph, cfg=CFG, stats=stats)
    whale = [eng.enqueue(QueryRequest(get_pattern("triangle"),
                                      tenant="whale"))
             for _ in range(6)]
    mouse = eng.enqueue(QueryRequest(get_pattern("P1"), tenant="mouse"))
    resolved = eng.run_pending(limit=2)
    assert mouse.done                      # took 1 whale + 1 mouse
    assert whale[0].done
    assert sum(t.done for t in whale) == 1
    assert eng.pending("whale") == 5
    assert eng.pending("mouse") == 0
    assert {t.seq for t in resolved} == {whale[0].seq, mouse.seq}
    # shares shift the ratio: weight-3 whale drains 3 per cycle
    eng2 = QueryEngine(graph, cfg=CFG, stats=stats,
                       tenant_shares={"whale": 3})
    for _ in range(6):
        eng2.enqueue(QueryRequest(get_pattern("triangle"), tenant="whale"))
    m2 = eng2.enqueue(QueryRequest(get_pattern("P1"), tenant="mouse"))
    eng2.run_pending(limit=4)              # 3 whale + 1 mouse
    assert m2.done
    assert eng2.pending("whale") == 3


def test_admission_rejection_deterministic_and_counted(graph, stats):
    eng = QueryEngine(graph, cfg=CFG, stats=stats, tenant_depth=2)
    tri = get_pattern("triangle")
    assert not isinstance(eng.try_enqueue(QueryRequest(tri, tenant="A")),
                          Rejection)
    assert not isinstance(eng.try_enqueue(QueryRequest(tri, tenant="A")),
                          Rejection)
    r1 = eng.try_enqueue(QueryRequest(tri, tenant="A"))
    r2 = eng.try_enqueue(QueryRequest(tri, tenant="A"))
    assert r1 == Rejection(tenant="A", reason="queue depth bound",
                           depth=2, limit=2)
    assert r1 == r2                        # deterministic
    assert eng.rejections == {"A": 2}
    # other tenants are unaffected by A's full queue
    assert not isinstance(eng.try_enqueue(QueryRequest(tri, tenant="B")),
                          Rejection)
    with pytest.raises(AdmissionRejected) as ei:
        eng.enqueue(QueryRequest(tri, tenant="A"))
    assert ei.value.rejection.tenant == "A"
    assert eng.rejections == {"A": 3}
    snap = eng.metrics.snapshot()
    assert snap["engine.admission_rejected"] == 3
    assert snap["engine.admission_rejected{tenant=A}"] == 3
    # draining the queue reopens admission
    eng.run_pending()
    assert not isinstance(eng.try_enqueue(QueryRequest(tri, tenant="A")),
                          Rejection)
    rep = eng.tenant_report()
    assert rep["A"]["rejected"] == 3
    assert rep["A"]["resolved"] == 2
    assert rep["A"]["latency"]["n"] == 2
    assert rep["B"]["rejected"] == 0


def test_cancel_queued_ticket(graph, stats):
    eng = QueryEngine(graph, cfg=CFG, stats=stats)
    a = eng.enqueue(QueryRequest(get_pattern("triangle")))
    b = eng.enqueue(QueryRequest(get_pattern("triangle")))
    assert eng.cancel(a) is True
    assert a.cancelled and not a.done
    assert eng.cancel(a) is False          # idempotent
    resolved = eng.run_pending()
    assert [t.seq for t in resolved] == [b.seq]
    assert eng.cancel(b) is False          # already resolved


# ----------------------------------------------- scheduler progress flag
class _Spinner:
    """ready() forever; progress is scripted per step."""

    def __init__(self, name, flags):
        self.name = name
        self.flags = list(flags)
        self.steps = 0

    def warmup(self):
        pass

    def ready(self):
        return bool(self.flags)

    def step(self, quantum):
        self.steps += 1
        progressed = self.flags.pop(0)
        return StepReport(items=0, seconds=0.0, progressed=progressed)

    def metrics(self):
        return {}


def test_scheduler_respects_progress_flag():
    """items=0 with progressed=True must NOT trip the stall-break (a
    fully-preempted quantum is forward motion); the first
    progressed=False round still breaks."""
    w = _Spinner("w", [True, True, False, True])
    trace = RoundScheduler().run([w])
    assert w.steps == 3                    # 2 productive + the stalled one
    assert trace.rounds == 3


def test_gateway_drains_preempted_engine(graph, stats, reference_counts):
    """End-to-end: an engine with a 1-dispatch budget behind a Gateway
    resolves everything across many rounds — ready() covers inflight
    work and StepReport.progressed keeps the scheduler alive."""
    ref_count, ref_dispatches = reference_counts["triangle"]
    eng = QueryEngine(graph, cfg=CFG, stats=stats, chunk=8,
                      preempt_dispatches=1)
    gw = Gateway()
    wl = gw.add(GraphQueryWorkload(
        eng, [QueryRequest(get_pattern("triangle"))]),
        Share(quantum=4))
    trace = gw.run()
    assert trace.rounds >= ref_dispatches  # one dispatch per round
    (res,) = wl.results()
    assert res.count == ref_count
    assert eng.preemptions == ref_dispatches - 1
    rep = gw.report()["workloads"]["graph"]["metrics"]
    assert rep["preemptions"] == ref_dispatches - 1
    assert rep["inflight"] == 0
    assert rep["tenants"]["default"]["resolved"] == 1
