"""Tier-1 ground-truth gate: the labeled question inventory.

`benchmarks/questions.py` fixes a generated property graph and a
≥50-question inventory (typed multi-hop joins, labeled triangles and
cliques, star-with-role queries, wildcard mixes) whose answers the
brute-force oracle states independently of every plan-time and
executor-path decision.  This module is the hard gate: the full
pipeline (canonicalization → configuration search → label-aware plan →
executor) must agree with the oracle on EVERY question, on BOTH
executor paths — 100% accuracy, no tolerance, no sampling.

A disagreement on any single question localizes a soundness bug
(label-aware restriction generation, per-label candidate gather, root
masking, canonical keys) that throughput benchmarks would average away.
"""
import pytest

from benchmarks.questions import (
    DATASET, inventory, machine_answers, oracle_answers,
)
from repro.graph.datasets import named_dataset

MIN_QUESTIONS = 50


@pytest.fixture(scope="module")
def graph():
    return named_dataset(DATASET)


@pytest.fixture(scope="module")
def questions():
    return inventory()


@pytest.fixture(scope="module")
def truth(graph, questions):
    return oracle_answers(graph, questions)


def test_inventory_shape(questions):
    assert len(questions) >= MIN_QUESTIONS
    assert len({q.qid for q in questions}) == len(questions)
    assert len({q.category for q in questions}) >= 6
    for q in questions:
        assert q.pattern.is_labeled(), f"{q.qid} is not a labeled pattern"
        assert q.text, f"{q.qid} has no question text"


def test_inventory_has_mass(graph, questions, truth):
    """An inventory dominated by empty answer classes would 'pass' while
    validating nothing; demand real embedding mass behind the questions
    and at least one genuinely-empty class (the zero answer is also a
    ground truth the pipeline must reproduce, not special-case)."""
    nonzero = sum(1 for v in truth.values() if v > 0)
    assert nonzero >= len(questions) * 3 // 5
    assert any(v == 0 for v in truth.values())


@pytest.mark.parametrize("path,use_pallas",
                         [("portable", False), ("fused", True)])
def test_all_questions_answered_correctly(graph, questions, truth,
                                          path, use_pallas):
    answers, _ = machine_answers(graph, questions, use_pallas=use_pallas)
    wrong = {
        q.qid: {"question": q.text, "got": answers[q.qid],
                "want": truth[q.qid]}
        for q in questions if answers[q.qid] != truth[q.qid]
    }
    assert not wrong, (
        f"{path} path got {len(wrong)}/{len(questions)} questions "
        f"wrong: {wrong}")
