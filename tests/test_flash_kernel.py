"""Flash-attention Pallas kernel vs the pure-jnp oracle (ref.py).

Sweeps shapes (incl. GQA group sizes, multi-block grids, causal and
bidirectional) and dtypes, interpret mode on CPU."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ops import flash_attention
from repro.kernels.ref import flash_attention_ref

# (BH, BK, Sq, Sk, hd, block)
SHAPES = [
    (4, 4, 256, 256, 64, 128),      # MHA, multi-block
    (8, 2, 256, 256, 64, 128),      # GQA group 4
    (6, 6, 128, 128, 128, 128),     # single block, hd=128
    (2, 1, 512, 512, 32, 128),      # MQA
    (3, 3, 384, 384, 64, 128),      # non-power-of-two grid
]


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("causal", [True, False], ids=["causal", "bidir"])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32],
                         ids=["bf16", "f32"])
def test_flash_matches_ref(shape, causal, dtype):
    BH, BK, Sq, Sk, hd, block = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    q = jnp.asarray(rng.normal(size=(BH, Sq, hd)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(BK, Sk, hd)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(BK, Sk, hd)), dtype=dtype)
    got = flash_attention_pallas(q, k, v, causal=causal,
                                 block_q=block, block_k=block)
    want = flash_attention_ref(q, k, v, causal=causal)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol
    )


def test_block_shape_invariance():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 512, 64)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 512, 64)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 512, 64)), dtype=jnp.float32)
    a = flash_attention_pallas(q, k, v, causal=True, block_q=128, block_k=256)
    b = flash_attention_pallas(q, k, v, causal=True, block_q=512, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_model_layout_wrapper_gqa():
    """ops.flash_attention folds [B,S,H,hd] and maps GQA groups."""
    rng = np.random.default_rng(1)
    B, S, H, K, hd = 2, 256, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, hd)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, hd)), dtype=jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)

    # oracle via the model's own GQA sdpa
    from repro.models.layers import _sdpa

    want = _sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_softmax_stability_large_logits():
    """Online softmax must not overflow with large score magnitudes."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(30.0 * rng.normal(size=(1, 128, 64)), dtype=jnp.float32)
    k = jnp.asarray(30.0 * rng.normal(size=(1, 128, 64)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 64)), dtype=jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=False,
                                 block_q=64, block_k=64)
    assert np.isfinite(np.asarray(got)).all()
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
