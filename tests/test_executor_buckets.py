"""Degree-bucketed frontier expansion (§Perf, graphpi cell) vs oracle."""
import numpy as np
import pytest

from repro.core.executor import (
    ExecutorConfig, Matcher, auto_buckets, count_embeddings,
)
from repro.core.oracle import count_embeddings_oracle
from repro.core.pattern import clique, cycle, house, star
from repro.core.plan import best_iep_k, build_plan
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules
from repro.graph.datasets import erdos_renyi, rmat


@pytest.fixture(scope="module")
def graph():
    # small power-law graph: heavy-tailed degrees make bucketing matter
    return rmat(8, 6, seed=7, name="rmat8")


@pytest.fixture(scope="module")
def er():
    return erdos_renyi(128, 768, seed=3)


def _plan(pattern, iep=False):
    rs = generate_restriction_sets(pattern, max_sets=1)[0]
    order = generate_schedules(pattern)[0]
    k = best_iep_k(pattern, order, rs) if iep else 0
    return build_plan(pattern, order, rs, iep_k=k)


# house/star4 on the rmat graph dominate wall time → tagged slow
# (cycle4/clique3 keep bucketed-vs-oracle coverage in the default run)
PATTERNS = [pytest.param(house(), id="house", marks=pytest.mark.slow),
            pytest.param(cycle(4), id="cycle4", marks=pytest.mark.slow),
            pytest.param(clique(3), id="clique3"),
            pytest.param(star(4), id="star4", marks=pytest.mark.slow)]


@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("iep", [False, True], ids=["enum", "iep"])
def test_bucketed_matches_oracle(graph, pattern, iep):
    plan = _plan(pattern, iep=iep)
    expect = count_embeddings_oracle(graph.n, graph.edge_array(), pattern)
    cfg = ExecutorConfig(capacity=1 << 12,
                         degree_buckets=auto_buckets(graph))
    got = Matcher(graph, plan, cfg).count()
    assert not got.overflowed
    assert got.count == expect


@pytest.mark.parametrize("buckets", [
    ((8, 1.0), (10**9, 0.5)),
    ((4, 1.0), (16, 0.5), (10**9, 0.25)),
    ((2, 0.5), (10**9, 1.0)),
], ids=["two", "three", "tiny-first"])
@pytest.mark.slow
def test_bucket_layout_invariance(er, buckets):
    """Any bucket layout must give the same exact count."""
    plan = _plan(house())
    base = count_embeddings(er, plan, ExecutorConfig(capacity=1 << 12))
    got = Matcher(er, plan,
                  ExecutorConfig(capacity=1 << 12,
                                 degree_buckets=buckets)).count()
    assert got.count == base.count
    assert not got.overflowed


@pytest.mark.slow
def test_bucket_overflow_escalates(er):
    """Deliberately tiny bucket fractions force capacity escalation; the
    count must stay exact."""
    plan = _plan(house())
    expect = count_embeddings_oracle(er.n, er.edge_array(), house())
    cfg = ExecutorConfig(capacity=1 << 9,
                         degree_buckets=((8, 1 / 32), (10**9, 1 / 32)))
    got = Matcher(er, plan, cfg).count()
    assert got.count == expect
    assert not got.overflowed


def test_auto_buckets_shape(graph):
    b = auto_buckets(graph)
    if b is not None:
        widths = [w for w, _ in b]
        assert widths == sorted(widths)
        assert widths[-1] >= graph.max_degree
        assert all(0 < f <= 1.0 for _, f in b)
