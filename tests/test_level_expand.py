"""Fused Pallas level-expansion kernel: parity at every layer.

1. Kernel vs the pure-jnp oracle (kernels/ref.py) on random windows,
   mask and count modes, including ragged shapes and all three
   comparison kinds (restriction >, restriction <, injectivity !=).
2. Executor counts with the fused kernel (use_pallas=True — interpret
   lowering on CPU) vs the portable binary-search path vs the brute
   oracle, for every oracle pattern, enum and IEP modes, with and
   without degree buckets.  Counts must be bit-identical.
"""
import numpy as np
import pytest

from repro.core.executor import ExecutorConfig, count_embeddings
from repro.core.oracle import count_embeddings_oracle
from repro.core.pattern import clique, cycle, house, rectangle, star, triangle
from repro.core.plan import best_iep_k, build_plan
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules
from repro.graph.datasets import erdos_renyi, rmat
from repro.kernels import ops, ref

# house/cycle5 are the slowest executor-level parity cases → tagged slow;
# the remaining patterns keep fused-vs-portable coverage in the default run
PATTERNS = [pytest.param(p, id=p.name,
                         marks=pytest.mark.slow
                         if p.name in ("house", "cycle5") else [])
            for p in (triangle(), rectangle(), house(), clique(4), cycle(5),
                      star(4))]


# ------------------------------------------------------------- kernel ----
def _windows(seed, B=24, D=37, P=3, L=50, vmax=200):
    rng = np.random.default_rng(seed)
    nbrs = np.stack([
        np.stack([np.sort(rng.choice(vmax, size=L, replace=False))
                  for _ in range(B)])
        for _ in range(P)
    ]).astype(np.int32)
    cand = rng.integers(0, vmax, size=(B, D)).astype(np.int32)
    cand_valid = rng.random((B, D)) < 0.8
    nbr_lens = rng.integers(0, L + 1, size=(P, B)).astype(np.int32)
    extra = rng.integers(0, vmax, size=(B, 3)).astype(np.int32)
    return cand, nbrs, extra, cand_valid, nbr_lens


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("count", [False, True], ids=["mask", "count"])
def test_level_expand_matches_ref(seed, count):
    args = _windows(seed)
    dirs = (1, -1, 0)
    got = ops.level_expand(*args, dirs=dirs, count=count)
    want = ref.level_expand_ref(*args, dirs=dirs, count=count)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_level_expand_no_extras_single_pred():
    cand, nbrs, _, valid, lens = _windows(3, P=1)
    got = ops.level_expand(cand, nbrs, None, valid, lens)
    want = ref.level_expand_ref(cand, nbrs, None, valid, lens)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_level_expand_block_shape_invariance():
    """Block layout must not change results (grid/accumulator logic)."""
    args = _windows(4, B=16, D=40, P=2, L=70)
    dirs = (1, 0, 0)
    base = np.asarray(ref.level_expand_ref(*args, dirs=dirs))
    for bb, bd, bl in [(8, 128, 128), (4, 64, 32), (16, 256, 256)]:
        got = ops.level_expand(*args, dirs=dirs,
                               block_b=bb, block_d=bd, block_l=bl)
        np.testing.assert_array_equal(np.asarray(got), base)


# ----------------------------------------------------------- executor ----
def _plan(pattern, iep):
    order = generate_schedules(pattern)[0]
    rs = generate_restriction_sets(pattern, max_sets=1)[0]
    k = best_iep_k(pattern, order, rs) if iep else 0
    if iep and k < 1:
        return None
    return build_plan(pattern, order, rs, iep_k=k)


@pytest.fixture(scope="module")
def er():
    return erdos_renyi(48, 220, seed=5)


@pytest.fixture(scope="module")
def pl_graph():
    # power-law graph: skewed windows + sentinel padding + real buckets
    return rmat(7, 5, seed=9, name="rmat7")


@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("iep", [False, True], ids=["enum", "iep"])
def test_fused_matches_portable_and_oracle(er, pattern, iep):
    plan = _plan(pattern, iep)
    if plan is None:
        pytest.skip("no sound IEP folding for this configuration")
    want = count_embeddings_oracle(er.n, er.edge_array(), pattern)
    portable = count_embeddings(
        er, plan, ExecutorConfig(capacity=1 << 10, use_pallas=False))
    fused = count_embeddings(
        er, plan, ExecutorConfig(capacity=1 << 10, use_pallas=True))
    assert portable.count == want
    assert fused.count == want                 # bit-identical, not approx
    assert fused.overflowed == portable.overflowed


@pytest.mark.parametrize("pattern", [
    pytest.param(house(), id="house", marks=pytest.mark.slow),
    pytest.param(clique(4), id="clique4"),
])
def test_fused_bucketed_matches_oracle(pl_graph, pattern):
    plan = _plan(pattern, iep=False)
    want = count_embeddings_oracle(pl_graph.n, pl_graph.edge_array(), pattern)
    got = count_embeddings(
        pl_graph, plan,
        ExecutorConfig(capacity=1 << 10, use_pallas=True,
                       degree_buckets=((8, 1.0), (10**9, 0.5))))
    assert got.count == want
