"""Fused Pallas level-expansion kernel: parity at every layer.

1. Kernel vs the pure-jnp oracle (kernels/ref.py) on random CSR-layout
   windows — the kernel gathers every predecessor neighborhood from the
   flat array INSIDE the grid (scalar-prefetched offsets + per-row DMA),
   so these tests feed (flat, starts, lens), never a stacked [P, B, W]
   array.  Mask and count modes, ragged/empty rows, all three comparison
   kinds (restriction >, restriction <, injectivity !=), and the signed
   count (`neg_from`) that carries the IEP prefix corrections.
2. Executor counts with the fused kernel (use_pallas=True — interpret
   lowering on CPU) vs the portable binary-search path vs the brute
   oracle, for every oracle pattern and the paper's P1-P6, enum and IEP
   modes, with and without degree buckets, including capacity-overflow
   escalation and graphs with empty neighborhoods.  Counts must be
   bit-identical.
"""
import numpy as np
import pytest

from repro.configs.graphpi import get_pattern
from repro.core.executor import ExecutorConfig, count_embeddings
from repro.core.oracle import count_embeddings_oracle
from repro.core.pattern import clique, cycle, house, rectangle, star, triangle
from repro.core.plan import best_iep_k, build_plan
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules
from repro.graph.datasets import erdos_renyi, rmat
from repro.kernels import ops, ref

# house/cycle5 are the slowest executor-level parity cases → tagged slow;
# the remaining patterns keep fused-vs-portable coverage in the default run
PATTERNS = [pytest.param(p, id=p.name,
                         marks=pytest.mark.slow
                         if p.name in ("house", "cycle5") else [])
            for p in (triangle(), rectangle(), house(), clique(4), cycle(5),
                      star(4))]


# ------------------------------------------------------------- kernel ----
def _csr_windows(seed, B=24, D=37, P=3, L=50, vmax=200, empty_frac=0.1):
    """Random CSR-layout test data: a flat pool of strictly-increasing
    rows (one per (p, b), lengths 0..L — including empty neighborhoods)
    plus the (starts, lens) offset arrays the kernel prefetches."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, L + 1, size=(P, B)).astype(np.int32)
    lens[rng.random((P, B)) < empty_frac] = 0
    rows = []
    starts = np.zeros((P, B), np.int32)
    off = 0
    for p in range(P):
        for b in range(B):
            starts[p, b] = off
            row = np.sort(rng.choice(vmax, size=lens[p, b], replace=False))
            rows.append(row.astype(np.int32))
            off += lens[p, b]
    flat = np.concatenate(rows) if rows else np.zeros(0, np.int32)
    cand = rng.integers(0, vmax, size=(B, D)).astype(np.int32)
    cand_valid = rng.random((B, D)) < 0.8
    extra = rng.integers(0, vmax, size=(B, 3)).astype(np.int32)
    return cand, flat, starts, lens, extra, cand_valid


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("count", [False, True], ids=["mask", "count"])
def test_level_expand_matches_ref(seed, count):
    cand, flat, starts, lens, extra, valid = _csr_windows(seed)
    dirs = (1, -1, 0)
    got = ops.level_expand(cand, flat, starts, lens, extra, valid,
                           dirs=dirs, count=count, window=50)
    want = ref.level_expand_ref(cand, flat, starts, lens, extra, valid,
                                dirs=dirs, count=count, window=50)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("neg_from", [0, 20, 37])
def test_level_expand_signed_count_matches_ref(seed, neg_from):
    """The fused IEP tail: columns ≥ neg_from subtract (the prefix
    corrections ride along as negatively-weighted candidates)."""
    cand, flat, starts, lens, _, valid = _csr_windows(seed)
    got = ops.level_expand(cand, flat, starts, lens, None, valid,
                           count=True, neg_from=neg_from, window=50)
    want = ref.level_expand_ref(cand, flat, starts, lens, None, valid,
                                count=True, neg_from=neg_from, window=50)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_level_expand_no_extras_single_pred():
    cand, flat, starts, lens, _, valid = _csr_windows(3, P=1)
    got = ops.level_expand(cand, flat, starts, lens, None, valid, window=50)
    want = ref.level_expand_ref(cand, flat, starts, lens, None, valid,
                                window=50)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_level_expand_all_rows_empty():
    """Empty neighborhoods: no DMA is issued at all, nothing matches."""
    cand, flat, starts, lens, _, valid = _csr_windows(5, P=2)
    lens[:] = 0
    got = ops.level_expand(cand, flat, starts, lens, None, valid,
                           count=True, window=50)
    assert not np.asarray(got).any()
    got_m = ops.level_expand(cand, flat, starts, lens, None, valid,
                             window=50)
    assert not np.asarray(got_m).any()


def test_level_expand_block_shape_invariance():
    """Block layout must not change results (grid/accumulator/DMA-skip
    logic) — including block_l larger than most row lengths."""
    cand, flat, starts, lens, extra, valid = _csr_windows(
        4, B=16, D=40, P=2, L=70)
    dirs = (1, 0, 0)
    base = np.asarray(ref.level_expand_ref(
        cand, flat, starts, lens, extra, valid, dirs=dirs, window=70))
    for bb, bd, bl in [(8, 128, 128), (4, 64, 32), (16, 256, 256)]:
        got = ops.level_expand(cand, flat, starts, lens, extra, valid,
                               dirs=dirs, window=70,
                               block_b=bb, block_d=bd, block_l=bl)
        np.testing.assert_array_equal(np.asarray(got), base)


# ----------------------------------------------------------- executor ----
def _plan(pattern, iep):
    order = generate_schedules(pattern)[0]
    rs = generate_restriction_sets(pattern, max_sets=1)[0]
    k = best_iep_k(pattern, order, rs) if iep else 0
    if iep and k < 1:
        return None
    return build_plan(pattern, order, rs, iep_k=k)


@pytest.fixture(scope="module")
def er():
    return erdos_renyi(48, 220, seed=5)


@pytest.fixture(scope="module")
def pl_graph():
    # power-law graph: skewed windows + sentinel padding + real buckets
    return rmat(7, 5, seed=9, name="rmat7")


@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("iep", [False, True], ids=["enum", "iep"])
def test_fused_matches_portable_and_oracle(er, pattern, iep):
    plan = _plan(pattern, iep)
    if plan is None:
        pytest.skip("no sound IEP folding for this configuration")
    want = count_embeddings_oracle(er.n, er.edge_array(), pattern)
    portable = count_embeddings(
        er, plan, ExecutorConfig(capacity=1 << 10, use_pallas=False))
    fused = count_embeddings(
        er, plan, ExecutorConfig(capacity=1 << 10, use_pallas=True))
    assert portable.count == want
    assert fused.count == want                 # bit-identical, not approx
    assert fused.overflowed == portable.overflowed


# P3/P5/P6 are the big interpret-mode patterns → slow tier (tier1 --all)
@pytest.mark.parametrize("pname", [
    pytest.param(p, marks=pytest.mark.slow if p in ("P3", "P5", "P6")
                 else [])
    for p in ("P1", "P2", "P3", "P4", "P5", "P6")])
def test_fused_iep_tail_matches_portable_P1_P6(er, pname):
    """The satellite parity matrix: kernel-fused IEP cardinalities vs
    the portable (separate binary-search sweep) path on the paper's
    P1-P6, bit-identical counts.  Patterns without a sound foldable
    tail fall back to enum — still exercised for parity."""
    pattern = get_pattern(pname)
    plan = _plan(pattern, iep=True) or _plan(pattern, iep=False)
    want = count_embeddings_oracle(er.n, er.edge_array(), pattern)
    portable = count_embeddings(
        er, plan, ExecutorConfig(capacity=1 << 10, use_pallas=False))
    fused = count_embeddings(
        er, plan, ExecutorConfig(capacity=1 << 10, use_pallas=True))
    assert portable.count == want
    assert fused.count == want
    assert fused.overflowed == portable.overflowed
    assert fused.max_needed == portable.max_needed


def test_fused_iep_overflow_escalation_parity(er):
    """Truncation/overflow edge: a capacity too small for the frontier
    forces the bisection + escalation driver; the fused-IEP path must
    report the same exact count and overflow state as the portable
    path (counts stay exact through escalation)."""
    pattern = star(4)
    plan = _plan(pattern, iep=True)
    assert plan is not None
    portable = count_embeddings(
        er, plan, ExecutorConfig(capacity=128, use_pallas=False))
    fused = count_embeddings(
        er, plan, ExecutorConfig(capacity=128, use_pallas=True))
    want = count_embeddings_oracle(er.n, er.edge_array(), pattern)
    assert portable.count == fused.count == want
    assert portable.overflowed == fused.overflowed
    assert portable.max_needed == fused.max_needed


def test_fused_iep_empty_neighborhoods():
    """Graphs with isolated vertices: zero-length predecessor rows must
    contribute nothing (their window DMAs are skipped entirely)."""
    rng = np.random.default_rng(11)
    edges = rng.integers(0, 30, size=(60, 2))     # vertices 30..39 isolated
    from repro.graph.csr import GraphCSR

    g = GraphCSR.from_edges(40, edges, name="isolated")
    assert (g.degrees == 0).any()
    pattern = star(4)
    plan = _plan(pattern, iep=True)
    want = count_embeddings_oracle(g.n, g.edge_array(), pattern)
    portable = count_embeddings(
        g, plan, ExecutorConfig(capacity=1 << 10, use_pallas=False))
    fused = count_embeddings(
        g, plan, ExecutorConfig(capacity=1 << 10, use_pallas=True))
    assert portable.count == fused.count == want


@pytest.mark.parametrize("pattern", [
    pytest.param(house(), id="house", marks=pytest.mark.slow),
    pytest.param(clique(4), id="clique4"),
])
def test_fused_bucketed_matches_oracle(pl_graph, pattern):
    plan = _plan(pattern, iep=False)
    want = count_embeddings_oracle(pl_graph.n, pl_graph.edge_array(), pattern)
    got = count_embeddings(
        pl_graph, plan,
        ExecutorConfig(capacity=1 << 10, use_pallas=True,
                       degree_buckets=((8, 1.0), (10**9, 0.5))))
    assert got.count == want


@pytest.mark.slow
def test_fused_iep_bucketed_matches_portable(pl_graph):
    """Degree-bucketed + IEP + fused kernel: every (union, bucket)
    cardinality is one fused pass; counts must stay bit-identical."""
    pattern = star(4)
    plan = _plan(pattern, iep=True)
    assert plan is not None
    cfg = dict(capacity=1 << 10,
               degree_buckets=((8, 1.0), (10**9, 0.5)))
    portable = count_embeddings(
        pl_graph, plan, ExecutorConfig(use_pallas=False, **cfg))
    fused = count_embeddings(
        pl_graph, plan, ExecutorConfig(use_pallas=True, **cfg))
    want = count_embeddings_oracle(pl_graph.n, pl_graph.edge_array(), pattern)
    assert portable.count == fused.count == want
