import numpy as np
import pytest

from repro.core.pattern import clique, house, triangle
from repro.core.perf_model import (
    GraphStats, filter_probabilities, intersection_cardinality,
    loop_cardinalities, predict_cost,
)
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules

STATS = GraphStats(n_vertices=1000, n_edges=5000, tri_cnt=700)


def test_probabilities_match_paper_formulas():
    assert STATS.p1 == pytest.approx(2 * 5000 / 1000**2)
    assert STATS.p2 == pytest.approx(700 * 1000 / (2 * 5000) ** 2)
    assert STATS.avg_degree == pytest.approx(10.0)


def test_cardinality_estimates():
    # single neighborhood = average degree
    assert intersection_cardinality(STATS, 1) == pytest.approx(10.0)
    # m neighborhoods = |V| p1 p2^(m-1)
    assert intersection_cardinality(STATS, 2) == pytest.approx(
        1000 * STATS.p1 * STATS.p2
    )
    assert intersection_cardinality(STATS, 0) == 1000


def test_filter_probability_halves_for_single_restriction():
    # paper: a single id(A) > id(B) filters exactly half of all relative
    # orders at its checkable loop
    f = filter_probabilities(5, [(0, 1)], (0, 1, 2, 3, 4))
    assert f[1] == pytest.approx(0.5)
    assert all(x == 0 for i, x in enumerate(f) if i != 1)


def test_filter_probabilities_sequential():
    # two chained restrictions: second filters among survivors of first
    f = filter_probabilities(3, [(0, 1), (1, 2)], (0, 1, 2))
    # id0>id1 kills 1/2; among survivors, id1>id2 keeps only the fully
    # decreasing order: 1/3 survive
    assert f[1] == pytest.approx(0.5)
    assert f[2] == pytest.approx(2 / 3)


def test_cost_positive_and_restriction_sensitive():
    h = house()
    order = generate_schedules(h)[0]
    rs = generate_restriction_sets(h, max_sets=4)
    costs = [predict_cost(h, order, r, STATS) for r in rs]
    assert all(c > 0 for c in costs)
    unrestricted = predict_cost(h, order, (), STATS)
    assert all(c <= unrestricted for c in costs)


def test_cost_ranks_good_schedules_cheaper():
    """Dense-prefix schedules should beat sparse ones for triangle-rich
    stats: the model must give *different* costs across schedules."""
    h = house()
    rs = generate_restriction_sets(h, max_sets=1)[0]
    costs = {o: predict_cost(h, o, rs, STATS) for o in generate_schedules(h)}
    assert len(set(round(c, 3) for c in costs.values())) > 1


def test_iep_changes_cost():
    h = house()
    order = (0, 1, 2, 3, 4)
    rs = generate_restriction_sets(h, max_sets=1)[0]
    c0 = predict_cost(h, order, rs, STATS, iep_k=0)
    c2 = predict_cost(h, order, rs, STATS, iep_k=2)
    assert c0 != c2
