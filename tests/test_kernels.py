"""Per-kernel validation: sweep shapes/dtypes, assert against ref.py.

Kernels run in interpret=True on CPU (the container has no TPU); the
BlockSpec tiling and grid logic are identical to the hardware path.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.ops import intersect_count, sorted_membership
from repro.kernels.ref import (
    intersect_count_ref, membership_ref, membership_ref_searchsorted,
)


def _mk(rng, B, D, L, dtype, hi=2000):
    # strictly increasing rows (CSR contract): sample without replacement
    nbr = np.stack(
        [np.sort(rng.choice(hi, size=L, replace=False)) for _ in range(B)]
    ).astype(dtype)
    cand = rng.integers(0, hi, size=(B, D)).astype(dtype)
    return cand, nbr


SHAPES = [
    (1, 1, 1),
    (3, 5, 7),          # nothing aligned
    (8, 128, 128),      # exactly one block
    (16, 256, 384),     # multiple blocks each dim
    (9, 130, 200),      # ragged over block boundaries
    (2, 300, 64),       # D > L
    (32, 64, 512),      # L > D
]


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", [np.int32, np.int16], ids=["i32", "i16"])
def test_membership_matches_ref(shape, dtype):
    B, D, L = shape
    rng = np.random.default_rng(B * 1000 + D + L)
    cand, nbr = _mk(rng, B, D, L, dtype, hi=max(2048, L + 1))
    got = sorted_membership(jnp.asarray(cand), jnp.asarray(nbr))
    want = membership_ref(jnp.asarray(cand), jnp.asarray(nbr))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_intersect_count_matches_ref(shape):
    B, D, L = shape
    rng = np.random.default_rng(B + D * 31 + L * 7)
    cand, nbr = _mk(rng, B, D, L, np.int32, hi=max(4096, L + 1))
    got = intersect_count(jnp.asarray(cand), jnp.asarray(nbr))
    want = membership_ref(jnp.asarray(cand), jnp.asarray(nbr)).sum(axis=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ragged_masks():
    rng = np.random.default_rng(0)
    B, D, L = 6, 100, 150
    cand, nbr = _mk(rng, B, D, L, np.int32)
    nbr_len = rng.integers(0, L + 1, size=B).astype(np.int32)
    cand_valid = rng.random((B, D)) < 0.7
    got = sorted_membership(
        jnp.asarray(cand), jnp.asarray(nbr),
        jnp.asarray(cand_valid), jnp.asarray(nbr_len),
    )
    want = np.zeros((B, D), dtype=bool)
    for b in range(B):
        valid_nbrs = set(nbr[b, : nbr_len[b]].tolist())
        for d in range(D):
            want[b, d] = cand_valid[b, d] and cand[b, d] in valid_nbrs
    np.testing.assert_array_equal(np.asarray(got), want)
    cnt = intersect_count(
        jnp.asarray(cand), jnp.asarray(nbr),
        jnp.asarray(cand_valid), jnp.asarray(nbr_len),
    )
    np.testing.assert_array_equal(np.asarray(cnt), want.sum(axis=1))


def test_two_oracles_agree():
    rng = np.random.default_rng(3)
    cand, nbr = _mk(rng, 8, 64, 64, np.int32)
    a = membership_ref(jnp.asarray(cand), jnp.asarray(nbr))
    b = membership_ref_searchsorted(jnp.asarray(cand), jnp.asarray(nbr))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("blocks", [(8, 128, 128), (8, 128, 256), (16, 256, 128)])
def test_block_shape_invariance(blocks):
    """Different BlockSpec tilings must give identical results."""
    bb, bd, bl = blocks
    rng = np.random.default_rng(9)
    cand, nbr = _mk(rng, 12, 200, 300, np.int32)
    got = sorted_membership(
        jnp.asarray(cand), jnp.asarray(nbr),
        block_b=bb, block_d=bd, block_l=bl,
    )
    want = membership_ref(jnp.asarray(cand), jnp.asarray(nbr))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_duplicate_candidates_counted_separately():
    cand = jnp.asarray([[5, 5, 5, 7]], dtype=jnp.int32)
    nbr = jnp.asarray([[1, 5, 9, 2**31 - 1]], dtype=jnp.int32)
    assert int(intersect_count(cand, nbr)[0]) == 3
