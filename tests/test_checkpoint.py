"""Fault-tolerance substrate: atomic checkpoints, resume, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "p": {"w": jax.random.normal(k, (8, 16)),
              "b": jnp.arange(16, dtype=jnp.float32)},
        "o": {"m": jnp.zeros((8, 16)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    like = jax.eval_shape(lambda: t)
    got, step = ckpt.restore(str(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_points_to_newest(tmp_path):
    ckpt.save(str(tmp_path), 5, _tree(1))
    ckpt.save(str(tmp_path), 10, _tree(2))
    assert ckpt.latest_step(str(tmp_path)) == 10
    got, step = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: _tree()))
    assert step == 10


def test_restore_specific_step(tmp_path):
    ckpt.save(str(tmp_path), 5, _tree(1))
    ckpt.save(str(tmp_path), 10, _tree(2))
    _, step = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: _tree()),
                           step=5)
    assert step == 5


def test_atomic_no_partial_on_failure(tmp_path):
    """A crashed save must not corrupt LATEST (tmp dir cleaned/ignored)."""
    ckpt.save(str(tmp_path), 1, _tree(1))
    # simulate a torn write: leave a stale tmp dir around
    os.makedirs(os.path.join(str(tmp_path), ".tmp_dead"), exist_ok=True)
    assert ckpt.latest_step(str(tmp_path)) == 1
    got, step = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: _tree()))
    assert step == 1


def test_elastic_resharding(tmp_path):
    """Checkpoint written under one sharding restores under another
    (different device count is simulated by a different PartitionSpec)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    sh = jax.tree.map(
        lambda v: NamedSharding(mesh, P()), t,
    )
    got, step = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: t),
                             shardings=sh)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_train_resume_continues_stream(tmp_path):
    """End-to-end: train 4 steps, kill, resume → identical params to an
    uninterrupted 8-step run (checkpoint + deterministic data pipeline)."""
    from repro.launch.train import main as train_main

    common = ["--arch", "qwen3-1.7b", "--smoke", "--batch", "2",
              "--seq", "16", "--log-every", "100"]
    d1 = str(tmp_path / "interrupted")
    train_main(common + ["--steps", "4", "--ckpt-dir", d1,
                         "--ckpt-every", "4"])
    train_main(common + ["--steps", "8", "--ckpt-dir", d1,
                         "--ckpt-every", "4"])
    d2 = str(tmp_path / "straight")
    train_main(common + ["--steps", "8", "--ckpt-dir", d2,
                         "--ckpt-every", "8"])
    a, sa = ckpt.restore(d1, None) if False else (None, None)
    # compare the saved params directly
    import json

    def leaves(d):
        man = json.load(open(os.path.join(d, "step_8", "manifest.json")))
        return {
            m["path"]: np.load(os.path.join(d, "step_8", m["file"]))
            for m in man["leaves"]
        }

    l1, l2 = leaves(d1), leaves(d2)
    assert l1.keys() == l2.keys()
    for k in l1:
        np.testing.assert_allclose(l1[k], l2[k], rtol=2e-5, atol=2e-6,
                                   err_msg=k)
