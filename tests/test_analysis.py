"""Static analysis layer: soundness proofs over the pattern library,
seeded violations of every rule class, the repo-invariant lint on the
live tree, kernel contract checks, and the PlanStore fsck/verify
integration (ISSUE 6 acceptance).

The hypothesis property tests skip cleanly when hypothesis is absent
(optional dev dependency); `test_random_patterns_fallback` is the
`slow`-marked deterministic stand-in that covers the same invariant.
"""
import dataclasses
import json
import os
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (
    ERROR, Finding, error_count, format_findings, has_errors,
    verify_configuration, verify_plan, verify_restriction_set,
    verify_schedule,
)
from repro.analysis.kernel_contracts import (
    LevelExpandSpec, abstract_eval_spec, check_graph_contract, check_spec,
)
from repro.analysis.lint import lint_source, lint_tree
from repro.configs.graphpi import PATTERNS, get_pattern
from repro.core.executor import ExecutorConfig, compute_stats
from repro.core.pattern import Pattern
from repro.core.plan import best_iep_k, build_plan
from repro.core.restrictions import generate_restriction_sets
from repro.core.schedule import generate_schedules
from repro.graph.datasets import erdos_renyi
from repro.query import PlanStore, QueryEngine, QueryRequest
from repro.query.store import SCHEMA_VERSION

CFG = ExecutorConfig(capacity=1 << 12)
REPO_ROOT = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def tiny_graph():
    return erdos_renyi(64, 256, seed=7, name="er64")


@pytest.fixture(scope="module")
def tiny_stats(tiny_graph):
    return compute_stats(tiny_graph, CFG)


# ------------------------------------------------------------- soundness
@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_generated_sets_verify_clean(name):
    """Every restriction set the planner can emit for P1-P6 proves sound."""
    pat = get_pattern(name)
    for rs in generate_restriction_sets(pat):
        assert not verify_restriction_set(pat, rs), (name, rs)


@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_built_plans_verify_clean(name):
    pat = get_pattern(name)
    rs = generate_restriction_sets(pat)[0]
    order = generate_schedules(pat)[0]
    for k in (0, best_iep_k(pat, order, rs)):
        plan = build_plan(pat, order, rs, iep_k=k)
        findings = verify_plan(plan)
        assert not has_errors(findings), format_findings(findings)


def test_incomplete_set_flagged():
    tri = get_pattern("triangle")
    findings = verify_restriction_set(tri, ((0, 1),))
    rules = {f.rule for f in findings}
    # all three independent proofs fail for a half-complete set
    assert {"restriction-survivors", "restriction-order-count",
            "restriction-partition"} <= rules


def test_malformed_and_contradictory_pairs_flagged():
    tri = get_pattern("triangle")
    assert has_errors(verify_restriction_set(tri, ((0, 7),)))
    assert has_errors(verify_restriction_set(tri, ((1, 1),)))
    f = verify_restriction_set(tri, ((0, 1), (1, 0)))
    assert any(x.rule == "restriction-range" for x in f)


def test_disconnected_schedule_flagged():
    path3 = Pattern(3, ((0, 1), (1, 2)), name="path3")
    f = verify_schedule(path3, (0, 2, 1))   # vertex 2 has no earlier nbr
    assert any(x.rule == "schedule-connected" for x in f)
    f = verify_schedule(path3, (0, 0, 1))
    assert any(x.rule == "schedule-permutation" for x in f)


def test_naive_mode_empty_set_is_clean():
    """Naive records carry no restrictions (count divided by |Aut| at
    execution); the verifier must not demand completeness of them."""
    pat = get_pattern("P1")
    order = generate_schedules(pat)[0]
    plan = build_plan(pat, order, ())
    assert not has_errors(verify_plan(plan, mode="naive"))
    assert has_errors(verify_plan(plan, mode="graphpi"))


def _flip(rs, i):
    return tuple((b, a) if j == i else (a, b)
                 for j, (a, b) in enumerate(rs))


@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_flipped_restriction_in_plan_always_flagged(name):
    """A flipped pair inside a PERSISTED plan always drifts from the
    rebuild (the positional dir sign changes), even when the flipped set
    happens to be a valid complete set in its own right."""
    pat = get_pattern(name)
    rs = generate_restriction_sets(pat)[0]
    order = generate_schedules(pat)[0]
    plan = build_plan(pat, order, rs)
    for i in range(len(rs)):
        mutated = dataclasses.replace(plan, res_set=_flip(rs, i))
        assert has_errors(verify_plan(mutated)), (name, i)


def _iep_case():
    """First (pattern, order, res_set, k>=1) the planner yields."""
    for name in ("rectangle", "P1", "P2", "P3"):
        pat = get_pattern(name)
        rs = generate_restriction_sets(pat)[0]
        for order in generate_schedules(pat):
            k = best_iep_k(pat, order, rs)
            if k >= 1:
                return pat, order, rs, k
    raise AssertionError("no IEP-foldable configuration found")


def test_divisor_and_positional_tampering_flagged():
    pat, order, rs, k = _iep_case()
    plan = build_plan(pat, order, rs, iep_k=k)
    assert not has_errors(verify_plan(plan))

    wrong_div = dataclasses.replace(plan, iep_divisor=plan.iep_divisor * 2)
    assert any(f.rule == "iep-multiplicity"
               for f in verify_plan(wrong_div))

    # a positional restriction pointing at a LATER position can never be
    # checked where it is scheduled
    restr = list(plan.restr)
    restr[1] = ((2, +1),)
    bad_pos = dataclasses.replace(plan, restr=tuple(restr))
    assert any(f.rule in ("restriction-checkable", "plan-derived-drift")
               for f in verify_plan(bad_pos))


# ------------------------------------------- property test (+ fallback)
def _random_pattern(rng) -> Pattern:
    n = int(rng.integers(4, 7))
    edges = set()
    for i in range(1, n):
        edges.add((int(rng.integers(0, i)), i))
    for _ in range(int(rng.integers(0, 5))):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return Pattern(n, tuple(sorted(edges)), name=f"rand{n}")


def _assert_pattern_invariants(pat):
    sets = generate_restriction_sets(pat, max_sets=4)
    assert sets
    order = generate_schedules(pat)[0]
    for rs in sets:
        assert not verify_restriction_set(pat, rs), (pat, rs)
        plan = build_plan(pat, order, rs)
        assert not has_errors(verify_plan(plan))
        for i in range(len(rs)):
            mutated = dataclasses.replace(plan, res_set=_flip(rs, i))
            assert has_errors(verify_plan(mutated)), (pat, rs, i)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @st.composite
    def _hyp_patterns(draw):
        n = draw(st.integers(min_value=4, max_value=6))
        edges = set()
        for i in range(1, n):
            edges.add((draw(st.integers(0, i - 1)), i))
        for (u, v) in draw(st.lists(
                st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
                max_size=4)):
            if u != v:
                edges.add((min(u, v), max(u, v)))
        return Pattern(n, tuple(sorted(edges)), name=f"rand{n}")

    @settings(max_examples=20, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(_hyp_patterns())
    def test_random_patterns_property(pattern):
        _assert_pattern_invariants(pattern)

except ImportError:
    @pytest.mark.slow
    def test_random_patterns_fallback():
        """Deterministic stand-in for the hypothesis property test."""
        rng = np.random.default_rng(0)
        for _ in range(25):
            _assert_pattern_invariants(_random_pattern(rng))


# ------------------------------------------------------------------ lint
def test_lint_clean_on_live_tree():
    findings = lint_tree(REPO_ROOT)
    assert not has_errors(findings), format_findings(findings)


def test_lint_scheduler_rules():
    src = ("import time\nimport jax\nimport random\n"
           "def pick():\n"
           "    return jax.numpy.zeros(1), time.time(), random.random()\n")
    rules = {f.rule for f in lint_source(src, "serve/scheduler.py")}
    assert {"scheduler-no-jax", "scheduler-determinism"} <= rules
    # the same module is fine anywhere else on the no-jax front
    rules_elsewhere = {f.rule for f in lint_source(src, "query/engine.py")}
    assert "scheduler-no-jax" not in rules_elsewhere


def test_lint_no_raw_timing():
    src = "import time\ndef t():\n    return time.perf_counter()\n"
    # serve/ and query/ must route timing through repro.obs ...
    for rel in ("serve/scheduler.py", "query/engine.py",
                "src/repro/serve/gateway.py"):
        f = lint_source(src, rel)
        assert {x.rule for x in f} == {"no-raw-timing"}, rel
    # ... in every spelling
    f = lint_source("from time import monotonic, sleep\n",
                    "serve/gateway.py")
    assert [x.rule for x in f] == ["no-raw-timing"]   # sleep not flagged
    # obs/ is the sanctioned home; other layers keep their own clocks
    assert not lint_source(src, "src/repro/obs/trace.py")
    assert not lint_source(src, "core/config_search.py")


def test_lint_compat_only_drift():
    src = ("import jax\nfrom jax.experimental import shard_map\n"
           "from jax.experimental import pallas\n"
           "def f():\n    return jax.sharding.set_mesh\n")
    f = lint_source(src, "models/layers.py")
    assert {x.rule for x in f} == {"compat-only-drift"}
    assert len(f) == 2                      # pallas import stays allowed
    assert not lint_source(src, "repro/compat.py")   # shim home is exempt


def test_lint_no_stale_fingerprint():
    src = ("class Engine:\n"
           "    def __init__(self, graph, stats):\n"
           "        self.fp = graph.fingerprint\n"          # attr store
           "    def rekey(self, graph, stats):\n"
           "        self._key = graph_fingerprint(graph, stats)\n")
    for rel in ("serve/gateway.py", "query/engine.py",
                "src/repro/query/cache.py"):
        f = [x for x in lint_source(src, rel)
             if x.rule == "no-stale-fingerprint"]
        assert len(f) == 2, rel
    # locals don't outlive a round — reading fingerprints at the use
    # site is exactly what the rule steers toward
    ok = ("def f(graph, stats):\n"
          "    fp = graph.fingerprint\n"
          "    return graph_fingerprint(graph, stats), fp\n")
    assert not lint_source(ok, "serve/gateway.py")
    # epoch objects are the sanctioned long-lived identity
    epoch = ("class Engine:\n"
             "    def bump(self, live, stats):\n"
             "        self._epoch = EpochStamp.for_live(live, stats)\n")
    assert not lint_source(epoch, "query/engine.py")
    # outside serve/query the engine's lifecycle rules don't apply
    assert not lint_source(src, "core/executor.py")


def test_lint_tracer_concretize():
    src = ("import jax\nfrom functools import partial\n"
           "@partial(jax.jit, static_argnames=('n',))\n"
           "def f(x, n):\n"
           "    k = int(x.shape[0])\n"        # static shape read: allowed
           "    return int(x[0]) + x.sum().item() + k\n")
    f = lint_source(src, "kernels/ops.py")
    assert len([x for x in f if x.rule == "no-tracer-concretize"]) == 2
    # kernel bodies are traced even without a jit decorator
    f = lint_source("def _f_body(r, o):\n    o[0] = float(r[0])\n",
                    "kernels/intersect.py")
    assert has_errors(f)
    # the same calls outside any traced body are not flagged
    assert not lint_source("def f(x):\n    return int(x)\n", "core/misc.py")


# ------------------------------------------------------- kernel contracts
def test_kernel_spec_clean_and_violations():
    ok = LevelExpandSpec(B=64, D=16, P=2, E=2, window=16, flat_len=512)
    assert not check_spec(ok)
    dma = dataclasses.replace(ok, block_l=1024)
    assert any(f.rule == "kernel-dma-window" for f in check_spec(dma))
    blk = dataclasses.replace(ok, block_d=100)
    assert any(f.rule == "kernel-block-shape" for f in check_spec(blk))
    of = dataclasses.replace(ok, flat_len=2**31 - 10)
    assert any(f.rule == "kernel-int32-offset" for f in check_spec(of))


def test_kernel_abstract_eval_clean():
    for spec in (
        LevelExpandSpec(B=64, D=16, P=2, E=2, window=16, flat_len=512),
        LevelExpandSpec(B=64, D=16, P=2, E=1, window=16, flat_len=512,
                        count=True),
        LevelExpandSpec(B=64, D=20, P=2, window=16, flat_len=512,
                        count=True, neg_from=16),
    ):
        findings = abstract_eval_spec(spec)
        assert not has_errors(findings), format_findings(findings)


def test_kernel_graph_contract(tiny_graph):
    assert not has_errors(check_graph_contract(tiny_graph, CFG, deep=True))
    # shape-only probe: a graph too big for int32 CSR offsets is refused
    f = check_graph_contract((10**10, 2 * 10**9, 1000))
    assert any(x.rule == "kernel-int32-offset" for x in f)


# ------------------------------------------------- store verify + fsck
def workload():
    return [
        QueryRequest(get_pattern("P1")),
        QueryRequest(get_pattern("triangle")),
        QueryRequest(get_pattern("rectangle"), use_iep=True),
    ]


@pytest.fixture()
def warm_store(tmp_path, tiny_graph, tiny_stats):
    root = str(tmp_path / "plan-store")
    engine = QueryEngine(tiny_graph, cfg=CFG, store=PlanStore(root),
                         stats=tiny_stats)
    results = engine.serve(workload())
    return root, [r.count for r in results]


def _flip_record_pair(vdir):
    """Flip one restriction pair inside some persisted plan record;
    returns the tampered digest."""
    for fname in sorted(os.listdir(vdir)):
        if not fname.endswith(".json") or fname.startswith("stats-"):
            continue
        path = os.path.join(vdir, fname)
        with open(path) as f:
            rec = json.load(f)
        rs = rec["plan"]["res_set"]
        if rs:
            rs[0] = [rs[0][1], rs[0][0]]
            with open(path, "w") as f:
                json.dump(rec, f)
            return fname[: -len(".json")], rec
    raise AssertionError("no record with restrictions")


def test_load_rejects_unsound_record(warm_store):
    root, _ = warm_store
    store = PlanStore(root)
    digest, rec = _flip_record_pair(store.vdir)
    assert store._load_digest(digest) is None
    assert store.stats.verify_fails == 1
    assert store.stats.rejects.get("verify") == 1


def test_fsck_quarantines_and_untouched_replay(warm_store, tiny_graph,
                                               tiny_stats):
    root, counts = warm_store
    store = PlanStore(root)
    digest, _ = _flip_record_pair(store.vdir)

    report = store.fsck()
    assert report["checked"] == 3
    assert report["quarantined"] == 1
    assert digest in report["findings"]
    assert has_errors(report["findings"][digest])
    qjson = os.path.join(store.vdir, "quarantine", digest + ".json")
    assert os.path.exists(qjson)
    assert not os.path.exists(os.path.join(store.vdir, digest + ".json"))

    # a second fsck over the now-clean store finds nothing new
    again = PlanStore(root).fsck()
    assert again["quarantined"] == 0 and again["checked"] == 2

    # the workload still replays correctly: the two untouched records
    # come from disk, only the quarantined one re-searches (and its
    # write-behind heals the store)
    engine = QueryEngine(tiny_graph, cfg=CFG, store=PlanStore(root),
                         stats=tiny_stats)
    results = engine.serve(workload())
    assert [r.count for r in results] == counts      # counts unchanged
    assert engine.cache.stats.n_searches == 1        # only the quarantined

    # after healing, a fresh replica replays the whole workload cold-free
    healed = QueryEngine(tiny_graph, cfg=CFG, store=PlanStore(root),
                         stats=tiny_stats)
    results = healed.serve(workload())
    assert [r.count for r in results] == counts
    assert healed.cache.stats.n_searches == 0


def test_graph_stats_persist_and_reload(tmp_path, tiny_graph):
    root = str(tmp_path / "stats-store")
    e1 = QueryEngine(tiny_graph, cfg=CFG, store=PlanStore(root))
    spath = os.path.join(root, f"v{SCHEMA_VERSION}",
                         f"stats-{tiny_graph.fingerprint}.json")
    assert os.path.exists(spath)

    store2 = PlanStore(root)
    e2 = QueryEngine(tiny_graph, cfg=CFG, store=store2)
    assert e2.stats == e1.stats
    assert store2.stats.loads >= 1           # no recount happened

    # corrupt stats record: engine degrades to recompute, never raises
    with open(spath, "w") as f:
        f.write("{not json")
    store3 = PlanStore(root)
    e3 = QueryEngine(tiny_graph, cfg=CFG, store=store3)
    assert e3.stats == e1.stats
    assert store3.stats.rejects.get("stats_corrupt") == 1


def test_fsck_validates_stats_record(tmp_path, tiny_graph):
    root = str(tmp_path / "stats-fsck")
    store = PlanStore(root)
    stats = compute_stats(tiny_graph, CFG)
    assert store.save_graph_stats(tiny_graph.fingerprint, stats)
    clean = PlanStore(root).fsck()
    assert clean["stats_checked"] == 1 and clean["quarantined"] == 0

    spath = store._stats_path(tiny_graph.fingerprint)
    with open(spath) as f:
        rec = json.load(f)
    rec["graph_fingerprint"] = "deadbeef"
    with open(spath, "w") as f:
        json.dump(rec, f)
    report = PlanStore(root).fsck()
    assert report["stats_checked"] == 1 and report["quarantined"] == 1


# ------------------------------------------------------------------- CLI
def test_cli_lint_clean_tree_exits_zero():
    from repro.analysis.__main__ import main

    assert main(["--lint", "--root", str(REPO_ROOT)]) == 0


def test_cli_fsck_flags_tampered_store(warm_store, capsys):
    from repro.analysis.__main__ import main

    root, _ = warm_store
    _flip_record_pair(os.path.join(root, f"v{SCHEMA_VERSION}"))
    assert main(["--fsck", root]) == 1
    out = capsys.readouterr().out
    assert "quarantined" in out


def test_finding_severity_validated():
    with pytest.raises(ValueError):
        Finding("fatal", "rule", "loc", "msg")
    fs = [Finding(ERROR, "r", "l", "m")]
    assert has_errors(fs) and error_count(fs) == 1

# --------------------------------------- labeled patterns (ISSUE 8)
def _random_labeled_pattern(rng) -> Pattern:
    """Random connected 4-6-vertex pattern with a random label
    assignment (3 classes + occasional wildcard slots)."""
    base = _random_pattern(rng)
    labels = tuple(
        int(rng.integers(0, 3)) if rng.random() < 0.8 else None
        for _ in range(base.n)
    )
    return base.with_labels(labels)


def test_labeled_restrictions_kill_exactly_label_subgroup():
    """Randomized (fixed-seed) labeled patterns: symmetry breaking must
    operate on EXACTLY the label-preserving automorphism subgroup — the
    generated sets keep n!/|Aut_label| orders and eliminate every
    non-identity label-preserving automorphism, and the built plans
    re-prove sound end to end.  Label-aware plans must also never emit
    MORE restrictions than their unlabeled skeletons (a smaller group
    needs fewer-or-equal breakers)."""
    import math

    from repro.core.pattern import identity_perm
    from repro.core.restrictions import (
        count_orders_satisfying, surviving_perms,
    )

    rng = np.random.default_rng(42)
    symmetry_broken = 0
    for _ in range(25):
        pat = _random_labeled_pattern(rng)
        auts = pat.automorphisms()
        skel = pat.skeleton()
        assert set(auts) <= set(skel.automorphisms())
        sets = generate_restriction_sets(pat, max_sets=4)
        skel_sets = generate_restriction_sets(skel, max_sets=4)
        assert sets
        assert min(len(rs) for rs in sets) <= \
            min(len(rs) for rs in skel_sets), (pat, sets, skel_sets)
        order = generate_schedules(pat)[0]
        for rs in sets:
            assert surviving_perms(auts, rs) == [identity_perm(pat.n)]
            assert count_orders_satisfying(pat.n, rs) == \
                math.factorial(pat.n) // len(auts)
            assert not verify_restriction_set(pat, rs), (pat, rs)
            plan = build_plan(pat, order, rs)
            assert plan.vlabels is not None
            assert not has_errors(verify_plan(plan))
        if len(auts) < len(skel.automorphisms()):
            symmetry_broken += 1
    # the sweep must actually exercise label-broken symmetry, not just
    # patterns whose labels happen to preserve the full group
    assert symmetry_broken >= 8


def test_labels_killing_all_symmetry_yield_empty_restriction_set():
    """A path typed L0-L1-L2 has trivial Aut_label: the only sound
    restriction set is the empty one (every ordering kept)."""
    pat = Pattern(3, ((0, 1), (1, 2)), labels=(0, 1, 2))
    assert len(pat.automorphisms()) == 1
    sets = generate_restriction_sets(pat)
    assert sets == [()]
    assert not verify_restriction_set(pat, ())
    # the SKELETON still has the reversal symmetry and needs a breaker
    assert all(len(rs) >= 1
               for rs in generate_restriction_sets(pat.skeleton()))


def _save_labeled_record(store, stats, pattern):
    """Search + persist one labeled pattern the way the cache would;
    returns (key, digest)."""
    from repro.core.config_search import search_configuration
    from repro.query.cache import PlanCache
    from repro.query.canon import canonical_form

    canon = canonical_form(pattern)
    best = search_configuration(canon, stats).best
    plan = build_plan(canon, best.order, best.res_set, iep_k=best.iep_k)
    key = PlanCache.entry_key(canon, ("gfp", 64, 256, 1), CFG)
    digest = store.save(key, pattern=canon, config=best, plan=plan)
    assert digest is not None
    return key, digest


@pytest.mark.parametrize("labels,flip", [
    ((0, 1, 1), {0: 1, 1: 0}),          # triangle: swap both classes
    ((2, 0, 2), {0: 2, 2: 0}),          # triangle: structure-preserving
    ((0, 1, 2), {0: 3}),                # all-distinct: retype one role
    # NOTE: a flip that merely PERMUTES distinct label values on a fully
    # symmetric skeleton — e.g. (0,1,2) -> (0,2,1) on a triangle — is
    # label-ISOMORPHIC to the original (same canonical class, same
    # count) and is correctly accepted, so it is not a case here.
])
def test_flipped_label_tamper_always_flagged_by_fsck(tmp_path, tiny_stats,
                                                     labels, flip):
    """Satellite: flipping labels inside a persisted record — even a
    CONSISTENT flip across pattern, embedded plan pattern, and vlabels,
    which keeps every internal invariant green — must be rejected by the
    loader and flagged by fsck: the record's canonical key no longer
    matches the slot it is filed under."""
    store = PlanStore(str(tmp_path / "store"))
    pat = get_pattern("triangle").with_labels(labels)
    key, digest = _save_labeled_record(store, tiny_stats, pat)

    path = os.path.join(store.vdir, digest + ".json")
    with open(path) as f:
        rec = json.load(f)
    sub = lambda x: flip.get(x, x)                     # noqa: E731
    rec["pattern"]["labels"] = [sub(x) for x in rec["pattern"]["labels"]]
    rec["plan"]["pattern"]["labels"] = [
        sub(x) for x in rec["plan"]["pattern"]["labels"]]
    rec["plan"]["vlabels"] = [sub(x) for x in rec["plan"]["vlabels"]]
    with open(path, "w") as f:
        json.dump(rec, f)

    fresh = PlanStore(store.root)
    assert fresh.load(key) is None
    assert fresh.stats.rejects.get("key-pattern-mismatch") == 1

    report = PlanStore(store.root).fsck()
    assert digest in report["findings"]
    assert any(f.rule == "key-pattern-mismatch"
               for f in report["findings"][digest])
    assert report["quarantined"] == 1
    assert not os.path.exists(path)


def test_inconsistent_label_tamper_flagged(tmp_path, tiny_stats):
    """Flipping ONLY the plan's vlabels (pattern left alone) is internal
    drift — verify_plan's vlabels rebuild check catches it even before
    the key comparison."""
    store = PlanStore(str(tmp_path / "store"))
    pat = get_pattern("triangle").with_labels((0, 1, 2))
    key, digest = _save_labeled_record(store, tiny_stats, pat)

    path = os.path.join(store.vdir, digest + ".json")
    with open(path) as f:
        rec = json.load(f)
    rec["plan"]["vlabels"] = [rec["plan"]["vlabels"][i]
                              for i in (1, 0, 2)]
    with open(path, "w") as f:
        json.dump(rec, f)
    assert PlanStore(store.root).load(key) is None
    report = PlanStore(store.root).fsck()
    assert digest in report["findings"]
    assert has_errors(report["findings"][digest])


def test_lint_label_coverage():
    """Dropping the labels reference from an identity surface — or the
    surface itself — is a lint ERROR; the live tree stays clean (covered
    by test_lint_clean_on_live_tree)."""
    src = ("def canonical_key(p):\n"
           "    return str(p.n)\n"
           "def _wl_cells(p):\n"
           "    return [p.labels]\n")
    f = lint_source(src, "src/repro/query/canon.py")
    assert any(x.rule == "label-coverage" and "canonical_key" in x.message
               for x in f)
    assert not any("_wl_cells" in x.message for x in f)

    # surface renamed/removed entirely -> also flagged
    f2 = lint_source("x = 1\n", "src/repro/core/plan.py")
    assert any(x.rule == "label-coverage" and "plan_to_dict" in x.message
               for x in f2)

    # unrelated modules are exempt
    assert not any(x.rule == "label-coverage"
                   for x in lint_source("x = 1\n", "src/repro/obs/core.py"))
