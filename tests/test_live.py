"""Live-graph subsystem (ISSUE 10): delta overlays, epoch fingerprints,
incremental count maintenance.

The load-bearing claims: counts over base ⊕ delta are ORACLE-EXACT under
randomized churn (portable and fused paths, before and after
compaction); after a mutation the plan cache replays with zero searches
and zero compiles while stale count memos are provably invalidated;
compaction preserves the content-derived edge key (memos survive it);
mutations land only at round boundaries, so a preempted whale or a
submit racing a mutate never yields a mixed-epoch count; and the
overlay record round-trips through the PlanStore (fsck understands and
quarantines damaged ones)."""
import numpy as np
import pytest

from repro.configs.graphpi import get_pattern
from repro.core.executor import ExecutorConfig, compute_stats
from repro.core.oracle import count_embeddings_oracle
from repro.graph.csr import GraphCSR
from repro.graph.datasets import erdos_renyi, rmat
from repro.live import (
    DeltaOverlay, EpochStamp, MUTATION_VERBS, edge_delta_digest,
)
from repro.query import QueryEngine, QueryRequest
from repro.query.store import PlanStore

CFG = ExecutorConfig(capacity=1 << 12)


@pytest.fixture(scope="module")
def graph():
    return erdos_renyi(64, 256, seed=7, name="er64")


def _churn_batches(graph, seed, rounds, n_ins=8, n_del=4):
    """Deterministic (insert_batch, delete_batch) pairs; deletes always
    target edges present at that point in the replayed sequence."""
    rng = np.random.default_rng(seed)
    edges = set(map(tuple, graph.edge_array().tolist()))
    out = []
    for _ in range(rounds):
        ins = []
        while len(ins) < n_ins:
            u, v = sorted(int(x) for x in rng.integers(0, graph.n, 2))
            if u != v and (u, v) not in edges and (u, v) not in ins:
                ins.append((u, v))
        edges |= set(ins)
        pool = sorted(edges)
        dels = [pool[i] for i in
                rng.choice(len(pool), size=n_del, replace=False)]
        edges -= set(dels)
        out.append((ins, dels))
    return out, edges


def _absent_edge(graph, k=0):
    """k-th lexicographic vertex pair NOT in the graph (u < v)."""
    seen = 0
    for u in range(graph.n):
        for v in range(u + 1, graph.n):
            if not graph.has_edge(u, v):
                if seen == k:
                    return (u, v)
                seen += 1
    raise AssertionError("graph is complete")


def _drain(engine, request):
    t = engine.enqueue(request)
    while not t.done:
        engine.run_pending()
    return t.result.count


# -------------------------------------------------------- overlay (unit)
def test_view_matches_rebuilt_csr_per_vertex(graph):
    live = DeltaOverlay(graph)
    batches, final_edges = _churn_batches(graph, seed=3, rounds=3)
    for ins, dels in batches:
        live.apply("insert_edges", ins)
        live.apply("delete_edges", dels)
    ref = GraphCSR.from_edges(graph.n, sorted(final_edges))
    view = live.view
    assert view.m == ref.m
    for v in range(graph.n):
        assert view.neighbors(v).tolist() == ref.neighbors(v).tolist(), v
    # compaction relays the same content; the view is again pure-base
    live.compact()
    assert live.overlay_edges() == 0
    for v in range(graph.n):
        assert live.view.neighbors(v).tolist() == \
            ref.neighbors(v).tolist(), v


def test_noop_mutations_do_not_bump_epoch(graph):
    live = DeltaOverlay(graph)
    e0 = live.edge_epoch
    present = tuple(int(x) for x in graph.edge_array()[0])
    absent = _absent_edge(graph)
    assert live.apply("insert_edges", [present]) == 0    # already there
    assert live.apply("delete_edges", [absent]) == 0     # never there
    assert live.edge_epoch == e0
    assert live.apply("insert_edges", [absent]) == 1
    assert live.edge_epoch == e0 + 1


def test_edge_key_is_content_derived(graph):
    """Same cumulative delta ⇒ same key regardless of mutation order;
    reverting a mutation restores the ORIGINAL key (memos revalidate);
    compaction never changes it."""
    key0 = DeltaOverlay(graph).edge_key
    live = DeltaOverlay(graph)
    victim = tuple(int(x) for x in graph.edge_array()[3])
    fresh = _absent_edge(graph)
    live.apply("insert_edges", [fresh])
    live.apply("delete_edges", [victim])
    k1 = live.edge_key
    assert k1 != key0
    other = DeltaOverlay(graph)
    other.apply("delete_edges", [victim])
    other.apply("insert_edges", [fresh])
    assert other.edge_key == k1                  # order-independent
    live.compact()
    assert live.edge_key == k1                   # content unchanged
    live.apply("delete_edges", [fresh])
    live.apply("insert_edges", [victim])
    assert live.edge_key == key0                 # full revert
    assert edge_delta_digest(live.base0_fingerprint, set(), set()) == key0


def test_edge_key_memoized_per_epoch(graph):
    live = DeltaOverlay(graph)
    n0 = live._edge_key_computes
    for _ in range(10):
        live.edge_key
    assert live._edge_key_computes == n0 + 1     # O(1) per-round checks
    live.apply("insert_edges", [_absent_edge(graph)])
    live.edge_key
    live.edge_key
    assert live._edge_key_computes == n0 + 2     # one recompute per epoch


def test_overflow_auto_compacts(graph):
    live = DeltaOverlay(graph, patch_capacity=graph.max_degree + 9)
    batches, final_edges = _churn_batches(graph, seed=11, rounds=4,
                                          n_ins=12, n_del=2)
    for ins, dels in batches:
        live.apply("insert_edges", ins)
        live.apply("delete_edges", dels)
    assert live.compactions >= 1                 # patch region overflowed
    ref = GraphCSR.from_edges(graph.n, sorted(final_edges))
    for v in range(graph.n):
        assert live.view.neighbors(v).tolist() == \
            ref.neighbors(v).tolist(), v


def test_epoch_stamp_levels(graph):
    live = DeltaOverlay(graph)
    stats = compute_stats(live.view, CFG)
    s0 = EpochStamp.for_live(live, stats)
    live.apply("insert_edges", [_absent_edge(graph)])
    s1 = EpochStamp.for_live(live, stats)
    assert s1.plan_key == s0.plan_key            # plans/AOT survive edits
    assert s1.edge_key != s0.edge_key            # count memos do not
    live.stats_epoch += 1
    s2 = EpochStamp.for_live(live, stats)
    assert s2.plan_key != s1.plan_key            # stats refresh re-plans


# ------------------------------------------------ oracle-exact churn
@pytest.mark.parametrize("use_pallas", [False, True])
def test_churn_counts_oracle_exact(graph, use_pallas):
    """Randomized insert/delete batches with queries between each, on
    both executor paths, with a compaction in the middle: every count
    equals the backtracking oracle on the rebuilt graph."""
    cfg = ExecutorConfig(capacity=1 << 12, use_pallas=use_pallas)
    eng = QueryEngine(graph, cfg=cfg, live=True)
    patterns = [get_pattern("triangle"), get_pattern("P1")]
    batches, _ = _churn_batches(graph, seed=5, rounds=4)
    for i, (ins, dels) in enumerate(batches):
        eng.request_mutation("insert_edges", ins)
        eng.request_mutation("delete_edges", dels)
        if i == 2:
            eng.request_mutation("compact")
        for p in patterns:
            got = _drain(eng, QueryRequest(p))
            cur = eng.live.materialize_edges()
            want = count_embeddings_oracle(graph.n, cur, p)
            assert got == want, (i, p.name, got, want)
    assert eng.live.compactions >= 1


@pytest.mark.slow
def test_churn_oracle_exact_small_rmat():
    g = rmat(10, 8, seed=5, name="small-rmat")
    eng = QueryEngine(g, cfg=CFG, live=True)
    tri = get_pattern("triangle")
    batches, _ = _churn_batches(g, seed=9, rounds=2, n_ins=16, n_del=8)
    for ins, dels in batches:
        eng.request_mutation("insert_edges", ins)
        eng.request_mutation("delete_edges", dels)
        got = _drain(eng, QueryRequest(tri))
        want = count_embeddings_oracle(g.n, eng.live.materialize_edges(),
                                       tri)
        assert got == want


# ------------------------------------- epoch keys: replay vs invalidate
def test_mutation_replays_plans_invalidates_memos(graph):
    """After a mutation: zero plan searches, zero recompiles (the plan
    key rides the stats epoch; the resident matchers rebind in place) —
    while the memoized count is invalidated and the new count is
    correct."""
    eng = QueryEngine(graph, cfg=CFG, live=True)
    tri = get_pattern("triangle")
    c0 = _drain(eng, QueryRequest(tri))
    searches = eng.cache.stats.n_searches
    compiles = eng.cache.stats.n_compiles
    eng.request_mutation("insert_edges",
                         [_absent_edge(graph, k) for k in range(4)])
    c1 = _drain(eng, QueryRequest(tri))
    assert eng.cache.stats.n_searches == searches    # no re-search
    assert eng.cache.stats.n_compiles == compiles    # no re-compile
    s = eng.summary()["live"]
    assert s["matcher_rebinds"] >= 1 and s["matcher_rebuilds"] == 0
    assert s["memo_invalidations"] >= 1              # stale memo dropped
    want = count_embeddings_oracle(graph.n, eng.live.materialize_edges(),
                                   tri)
    assert c1 == want and c1 != c0


def test_memo_hit_same_epoch_and_across_compaction(graph):
    eng = QueryEngine(graph, cfg=CFG, live=True)
    tri = get_pattern("triangle")
    c0 = _drain(eng, QueryRequest(tri))
    c1 = _drain(eng, QueryRequest(tri))              # same epoch: memo
    assert eng.summary()["live"]["memo_hits"] == 1
    assert eng.last_round_dispatches == 0            # zero kernel work
    eng.request_mutation("compact")
    c2 = _drain(eng, QueryRequest(tri))              # edge_key unchanged
    assert eng.summary()["live"]["memo_hits"] == 2
    assert c0 == c1 == c2


# --------------------------------------------- incremental maintenance
def test_incremental_recount_reuses_clean_spans():
    """Ring-lattice graph (all adjacency index-local) + one edge insert:
    only the spans owning the dirty neighborhood re-expand; every other
    span's total is carried over, and the result is still oracle-exact."""
    n = 512
    edges = [(i, (i + 1) % n) for i in range(n)] + \
            [(i, (i + 2) % n) for i in range(n)]
    edges = sorted({(min(u, v), max(u, v)) for u, v in edges})
    g = GraphCSR.from_edges(n, edges, name="ring512")
    eng = QueryEngine(g, cfg=CFG, live=True, chunk=64)   # 8 spans
    tri = get_pattern("triangle")
    _drain(eng, QueryRequest(tri))                   # memoize full count
    full_dispatches = eng.last_round_dispatches
    eng.request_mutation("insert_edges", [(100, 103)])
    got = _drain(eng, QueryRequest(tri))
    s = eng.summary()["live"]
    assert s["incremental_hits"] == 1 and s["full_recounts"] == 0
    assert s["spans_reused"] >= 6                    # ≥6 of 8 untouched
    assert eng.last_round_dispatches < full_dispatches
    want = count_embeddings_oracle(n, eng.live.materialize_edges(), tri)
    assert got == want


def test_global_churn_falls_back_to_full_recount(graph):
    """Edits touching most spans must NOT go incremental (break-even)."""
    eng = QueryEngine(graph, cfg=CFG, live=True, chunk=8)
    tri = get_pattern("triangle")
    _drain(eng, QueryRequest(tri))
    ins = [(u, v) for u in range(0, 64, 8) for v in (u + 3,)
           if not graph.has_edge(u, v)]
    eng.request_mutation("insert_edges", ins)        # every span dirtied
    got = _drain(eng, QueryRequest(tri))
    s = eng.summary()["live"]
    assert s["full_recounts"] >= 1
    want = count_embeddings_oracle(graph.n, eng.live.materialize_edges(),
                                   tri)
    assert got == want


# ------------------------------------ round-boundary mutation semantics
def test_preempted_whale_across_mutation(graph):
    """A class suspended mid-count when a mutation lands is re-enqueued
    and recounted on the new epoch — never a mixed-epoch count."""
    eng = QueryEngine(graph, cfg=CFG, live=True, chunk=8,
                      preempt_dispatches=1)
    p3 = get_pattern("P3")
    t = eng.enqueue(QueryRequest(p3))
    eng.run_pending()                                # starts, suspends
    assert not t.done and eng.inflight() == 1
    eng.request_mutation("insert_edges",
                         [_absent_edge(graph, k) for k in range(4)])
    while not t.done:
        eng.run_pending()
    assert eng.preemptions >= 1
    want = count_embeddings_oracle(graph.n, eng.live.materialize_edges(),
                                   p3)
    assert t.result.count == want


def test_submit_racing_mutate_is_round_deterministic(graph):
    """Tickets enqueued before AND after a mutation request resolve in
    the same round — and both see the post-mutation graph, because
    mutations apply at the round boundary before tickets are taken."""
    eng = QueryEngine(graph, cfg=CFG, live=True)
    tri = get_pattern("triangle")
    t_before = eng.enqueue(QueryRequest(tri))
    eng.request_mutation("insert_edges",
                         [_absent_edge(graph, k) for k in range(4)])
    t_after = eng.enqueue(QueryRequest(tri))
    eng.run_pending()
    assert t_before.done and t_after.done
    want = count_embeddings_oracle(graph.n, eng.live.materialize_edges(),
                                   tri)
    assert t_before.result.count == t_after.result.count == want


def test_request_mutation_validates(graph):
    eng = QueryEngine(graph, cfg=CFG, live=True)
    with pytest.raises(ValueError):
        eng.request_mutation("explode", [(0, 1)])
    frozen = QueryEngine(graph, cfg=CFG)
    with pytest.raises(RuntimeError):
        frozen.request_mutation("insert_edges", [(0, 1)])
    assert frozen.mutations_pending() == 0
    ack = eng.request_mutation("insert_edges", [(0, 63)])
    assert set(ack) == {"verb", "queued_edges", "pending_batches",
                        "edge_epoch"}
    assert eng.mutations_pending() == 1
    assert "compact" in MUTATION_VERBS


# --------------------------------------------------- overlay persistence
def test_overlay_record_roundtrip_and_fsck(graph, tmp_path):
    store = PlanStore(str(tmp_path / "plans"))
    eng = QueryEngine(graph, cfg=CFG, live=True, store=store)
    tri = get_pattern("triangle")
    eng.request_mutation("insert_edges", [(0, 63), (1, 62)])
    eng.request_mutation("delete_edges",
                         [tuple(int(x) for x in graph.edge_array()[0])])
    c = _drain(eng, QueryRequest(tri))
    rec = store.load_overlay(eng.live.base0_fingerprint)
    assert rec is not None                   # write-behind at the round
    resumed = DeltaOverlay.from_record(graph, rec)
    assert resumed.edge_key == eng.live.edge_key
    eng2 = QueryEngine(graph, cfg=CFG, live=resumed)
    assert _drain(eng2, QueryRequest(tri)) == c
    report = store.fsck()
    assert report["overlays_checked"] == 1 and report["quarantined"] == 0
    # damage it: unnormalized pair → fsck quarantines, load rejects
    import json
    path = store._overlay_path(eng.live.base0_fingerprint)
    bad = dict(rec, inserts=[[63, 0]])
    with open(path, "w") as f:
        json.dump(bad, f)
    report = store.fsck()
    assert report["quarantined"] == 1
    assert store.load_overlay(eng.live.base0_fingerprint) is None


def test_save_overlay_rejects_malformed(tmp_path, graph):
    store = PlanStore(str(tmp_path / "plans"))
    live = DeltaOverlay(graph)
    rec = live.to_record()
    assert store.save_overlay(rec)
    assert not store.save_overlay(dict(rec, edge_epoch=-1))
    assert not store.save_overlay(dict(rec, inserts=[[2, 2]]))
    assert not store.save_overlay(dict(rec, inserts=[[0, 5]],
                                       deletes=[[0, 5]]))
    assert len(store) == 0                   # never counted as a plan
