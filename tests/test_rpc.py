"""Ticket RPC front door (serve/rpc.py): framing, wire shapes, and a
real socket round-trip whose counts must be bit-identical to the
in-process engine path (ISSUE 9 acceptance)."""
import asyncio
import threading

import pytest

from repro.configs.graphpi import get_pattern
from repro.core.executor import ExecutorConfig, compute_stats
from repro.graph.datasets import erdos_renyi
from repro.query import QueryEngine, QueryRequest
from repro.serve.gateway import Gateway, GraphQueryWorkload, Share
from repro.serve.rpc import (
    MAX_FRAME, GatewayRPCServer, RPCClient, RPCError, encode_frame,
    read_frame, request_from_spec, result_to_wire,
)

CFG = ExecutorConfig(capacity=1 << 12)


@pytest.fixture(scope="module")
def graph():
    return erdos_renyi(64, 256, seed=7, name="er64")


@pytest.fixture(scope="module")
def stats(graph):
    return compute_stats(graph, CFG)


# -------------------------------------------------------------- framing
def _read_bytes(data: bytes):
    async def go():
        reader = asyncio.StreamReader()
        reader.feed_data(data)
        reader.feed_eof()
        return await read_frame(reader)
    return asyncio.run(go())


def test_frame_roundtrip():
    msg = {"op": "submit", "pattern": {"n": 3, "edges": [[0, 1], [1, 2]]},
           "tenant": "t0"}
    assert _read_bytes(encode_frame(msg)) == msg


def test_frame_eof_and_oversize():
    assert _read_bytes(b"") is None            # clean EOF -> None
    assert _read_bytes(b"\x00\x00") is None    # torn header -> None
    import struct
    huge = struct.pack(">I", MAX_FRAME + 1)
    with pytest.raises(ValueError):
        _read_bytes(huge)
    with pytest.raises(ValueError):
        encode_frame({"pad": "x" * (MAX_FRAME + 16)})


def test_request_from_spec_matches_trace_format():
    req = request_from_spec({"pattern": "triangle", "tenant": "acme"})
    assert req.pattern == get_pattern("triangle")
    assert req.tenant == "acme"
    assert req.use_iep is False and req.mode == "graphpi"
    inline = request_from_spec(
        {"pattern": {"n": 3, "edges": [[2, 1], [0, 2], [1, 0]]}})
    assert inline.pattern.n == 3 and inline.pattern.name == "inline"
    assert inline.tenant == "default"


# ----------------------------------------------------------- socket path
TRACE = [
    {"pattern": "triangle"},
    {"pattern": "P1"},
    {"pattern": {"n": 3, "edges": [[2, 1], [0, 2], [1, 0]]}},
    {"pattern": "triangle"},          # duplicate: must coalesce server-side
]


def _start_server(engine):
    """GatewayRPCServer on an ephemeral port, event loop in a daemon
    thread; returns (server, thread, port)."""
    gw = Gateway()
    wl = gw.add(GraphQueryWorkload(engine), Share(quantum=4))
    server = GatewayRPCServer(gw, wl)
    ready = threading.Event()
    box = {}

    def on_ready(host, port):
        box["port"] = port
        ready.set()

    th = threading.Thread(target=server.serve_forever,
                          kwargs={"on_ready": on_ready}, daemon=True)
    th.start()
    assert ready.wait(timeout=60), "RPC server never came up"
    return server, th, box["port"]


def test_socket_counts_bit_identical(graph, stats):
    """The acceptance counter-assert: every count fetched over the
    socket equals the count the in-process engine computes for the same
    trace."""
    ref_engine = QueryEngine(graph, cfg=CFG, stats=stats)
    ref = []
    for spec in TRACE:
        t = ref_engine.enqueue(request_from_spec(spec))
        ref_engine.run_pending()
        ref.append(t.result.count)

    engine = QueryEngine(graph, cfg=CFG, stats=stats, chunk=8,
                         preempt_dispatches=4)
    server, th, port = _start_server(engine)
    client = RPCClient("127.0.0.1", port, timeout=120.0)
    try:
        tickets = [client.submit(spec) for spec in TRACE]
        results = [client.result(tk) for tk in tickets]
        assert [r["count"] for r in results] == ref
        for r in results:
            assert "count=" in r["line"]      # what the smoke diff greps
        # the duplicate triangle never re-plans: depending on how the
        # drive loop interleaves with the submits it either coalesces
        # into the in-flight group or hits the plan cache — both count
        # as hits, and both must cover the repeated class
        stats_resp = client.stats()
        assert stats_resp["stats"]["requests_resolved"] == len(TRACE)
        s = stats_resp["stats"]
        assert s["cache"]["hits"] + s["coalesced"] >= 1
        assert s["cache"]["misses"] == 2      # triangle class + P1 class
        assert stats_resp["rounds"] >= 1
        # resolved tickets: poll reports done, cancel refuses
        p = client.poll(tickets[0])
        assert p == {"ok": True, "done": True, "cancelled": False}
        assert client.cancel(tickets[0]) is False
        assert client.poll(999).get("ok") is False       # unknown ticket
        with pytest.raises(RPCError):
            client.result(999)
    finally:
        client.shutdown()
        client.close()
        th.join(timeout=30)
    assert not th.is_alive()
    assert engine.preemptions >= 1            # budget was actually active


def test_socket_admission_rejection(graph, stats):
    """tenant_depth=0 rejects every submit: the wire carries the full
    Rejection payload and the client surfaces it as RPCError."""
    engine = QueryEngine(graph, cfg=CFG, stats=stats, tenant_depth=0)
    server, th, port = _start_server(engine)
    client = RPCClient("127.0.0.1", port, tenant="acme", timeout=60.0)
    try:
        with pytest.raises(RPCError) as ei:
            client.submit({"pattern": "triangle"})
        resp = ei.value.resp
        assert resp["error"] == "rejected"
        assert resp["rejection"] == {"tenant": "acme",
                                     "reason": "queue depth bound",
                                     "depth": 0, "limit": 0}
        assert engine.rejections == {"acme": 1}
        assert client.call({"op": "bogus"})["ok"] is False
    finally:
        client.shutdown()
        client.close()
        th.join(timeout=30)
    assert not th.is_alive()


def test_socket_mutate_then_replay_bit_identical(graph):
    """Live serving over the wire: queries, then insert/delete mutations,
    then the same queries again — every post-mutation count equals a
    fresh engine built from scratch on the mutated edge set, and the
    mutation is acked with the epoch it queued against."""
    from repro.graph.csr import GraphCSR

    engine = QueryEngine(graph, cfg=CFG, live=True)
    ins = [[0, 63], [1, 62], [2, 61]]
    ins = [e for e in ins if not graph.has_edge(*e)]
    dels = [[int(u), int(v)] for u, v in graph.edge_array()[:2]]
    server, th, port = _start_server(engine)
    client = RPCClient("127.0.0.1", port, timeout=120.0)
    try:
        before = [client.result(client.submit({"pattern": n}))["count"]
                  for n in ("triangle", "P1")]
        ack = client.mutate("insert_edges", ins)
        assert ack["ok"] and ack["verb"] == "insert_edges"
        assert ack["queued_edges"] == len(ins)
        client.mutate("delete_edges", dels)
        after = [client.result(client.submit({"pattern": n}))["count"]
                 for n in ("triangle", "P1")]
        client.mutate("compact")
        compacted = [client.result(client.submit({"pattern": n}))["count"]
                     for n in ("triangle", "P1")]
    finally:
        client.shutdown()
        client.close()
        th.join(timeout=30)
    assert not th.is_alive()
    edges = set(map(tuple, graph.edge_array().tolist()))
    edges |= {tuple(e) for e in ins}
    edges -= {tuple(e) for e in dels}
    rebuilt = GraphCSR.from_edges(graph.n, sorted(edges), name="rebuilt")
    ref_engine = QueryEngine(rebuilt, cfg=CFG)
    ref = []
    for n in ("triangle", "P1"):
        t = ref_engine.enqueue(request_from_spec({"pattern": n}))
        ref_engine.run_pending()
        ref.append(t.result.count)
    assert after == ref and compacted == ref
    assert after != before                  # the mutation actually bit
    assert engine.summary()["live"]["mutations_applied"] >= len(ins)
