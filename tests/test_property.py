"""Hypothesis property tests over the system's core invariants.

`hypothesis` is an optional dev dependency (requirements-dev.txt); the
whole module skips cleanly when it is not installed so `pytest -x`
never dies at collection.  CI sets REPRO_REQUIRE_HYPOTHESIS=1 to turn
that skip into a hard failure — the suite must actually EXECUTE there,
not silently vanish when a cache miss drops the dependency.  Profile:
tests/conftest.py pins a derandomized hypothesis profile so any failure
here reproduces bit-for-bit."""
import os

import numpy as np
import pytest

if os.environ.get("REPRO_REQUIRE_HYPOTHESIS") == "1":
    import hypothesis            # ImportError = loud collection failure
else:
    hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core.executor import ExecutorConfig, count_embeddings
from repro.core.oracle import count_embeddings_oracle, count_injective_maps
from repro.core.pattern import Pattern, clique, cycle, house, rectangle, triangle
from repro.core.plan import best_iep_k, build_plan
from repro.core.restrictions import (
    generate_restriction_sets, surviving_perms, validate,
)
from repro.core.schedule import generate_schedules
from repro.graph.csr import GraphCSR

CFG = ExecutorConfig(capacity=1 << 13)
SLOW = settings(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def random_graphs(draw, max_n=24, max_m=80):
    n = draw(st.integers(min_value=4, max_value=max_n))
    m = draw(st.integers(min_value=0, max_value=max_m))
    edges = draw(
        st.lists(
            st.tuples(
                st.integers(0, n - 1), st.integers(0, n - 1)
            ),
            min_size=0,
            max_size=m,
        )
    )
    return n, np.asarray(edges, dtype=np.int64).reshape(-1, 2)


@st.composite
def random_patterns(draw):
    """Small connected patterns."""
    n = draw(st.integers(min_value=3, max_value=5))
    # random spanning tree + extra edges => connected
    extra = draw(
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            max_size=4,
        )
    )
    edges = set()
    for i in range(1, n):
        parent = draw(st.integers(0, i - 1))
        edges.add((parent, i))
    for (u, v) in extra:
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return Pattern(n, tuple(sorted(edges)), name=f"rand{n}")


@SLOW
@given(random_patterns())
def test_restriction_sets_always_complete(pattern):
    """Invariant: every generated set leaves exactly the identity."""
    auts = pattern.automorphisms()
    ident = tuple(range(pattern.n))
    sets = generate_restriction_sets(pattern, max_sets=8)
    assert sets
    for rs in sets:
        assert surviving_perms(auts, rs) == [ident]
        assert validate(pattern, rs)


@SLOW
@given(random_graphs(), random_patterns())
def test_executor_count_matches_oracle(graph, pattern):
    """Invariant: JAX count == oracle count on any graph, any pattern."""
    n, edges = graph
    g = GraphCSR.from_edges(n, edges)
    if g.m == 0:
        return
    want = count_embeddings_oracle(n, g.edge_array(), pattern)
    order = generate_schedules(pattern)[0]
    rs = generate_restriction_sets(pattern, max_sets=1)[0]
    got = count_embeddings(g, build_plan(pattern, order, rs), CFG)
    assert got.count == want


@SLOW
@given(random_graphs(max_n=16, max_m=60), random_patterns())
def test_iep_equals_enumeration(graph, pattern):
    """Invariant: IEP-folded counting == plain enumeration."""
    n, edges = graph
    g = GraphCSR.from_edges(n, edges)
    if g.m == 0:
        return
    order = generate_schedules(pattern)[0]
    rs = generate_restriction_sets(pattern, max_sets=1)[0]
    k = best_iep_k(pattern, order, rs)
    if k < 1:
        return
    enum = count_embeddings(g, build_plan(pattern, order, rs), CFG)
    iep = count_embeddings(g, build_plan(pattern, order, rs, iep_k=k), CFG)
    assert iep.count == enum.count


@SLOW
@given(random_graphs(max_n=14, max_m=40), random_patterns())
def test_injective_maps_are_aut_multiples(graph, pattern):
    """Invariant: #injective maps ≡ 0 (mod |Aut|)."""
    n, edges = graph
    maps = count_injective_maps(n, edges, pattern)
    assert maps % pattern.aut_count() == 0
