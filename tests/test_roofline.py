"""Roofline extraction: HLO parsing, trip counts, slice-aware bytes."""
import numpy as np
import pytest

from repro.roofline.hlo_cost import HloCost, parse_module, type_bytes

HLO = """
HloModule test

%fused_gather (param_0.1: f32[1000,64], param_1.2: s32[8]) -> f32[8,64] {
  %param_0.1 = f32[1000,64]{1,0} parameter(0)
  %param_1.2 = s32[8]{0} parameter(1)
  ROOT %gather.1 = f32[8,64]{1,0} gather(%param_0.1, %param_1.2), offset_dims={1}
}

%fused_dus (param_0.3: f32[1000,64], param_1.4: f32[1,64], param_2.5: s32[]) -> f32[1000,64] {
  %param_0.3 = f32[1000,64]{1,0} parameter(0)
  %param_1.4 = f32[1,64]{1,0} parameter(1)
  %param_2.5 = s32[] parameter(2)
  %constant.1 = s32[] constant(0)
  ROOT %dynamic-update-slice.1 = f32[1000,64]{1,0} dynamic-update-slice(%param_0.3, %param_1.4, %param_2.5, %constant.1)
}

%body (param.1: (s32[], f32[128,256], f32[256,128])) -> (s32[], f32[128,256], f32[256,128]) {
  %param.1 = (s32[], f32[128,256], f32[256,128]) parameter(0)
  %get-tuple-element.1 = s32[] get-tuple-element(%param.1), index=0
  %get-tuple-element.2 = f32[128,256]{1,0} get-tuple-element(%param.1), index=1
  %get-tuple-element.3 = f32[256,128]{1,0} get-tuple-element(%param.1), index=2
  %dot.1 = f32[128,128]{1,0} dot(%get-tuple-element.2, %get-tuple-element.3), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %all-reduce.1 = f32[128,128]{1,0} all-reduce(%dot.1), replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %tuple.1 = (s32[], f32[128,256], f32[256,128]) tuple(%get-tuple-element.1, %get-tuple-element.2, %get-tuple-element.3)
}

%cond (param.2: (s32[], f32[128,256], f32[256,128])) -> pred[] {
  %param.2 = (s32[], f32[128,256], f32[256,128]) parameter(0)
  %get-tuple-element.4 = s32[] get-tuple-element(%param.2), index=0
  %constant.2 = s32[] constant(10)
  ROOT %compare.1 = pred[] compare(%get-tuple-element.4, %constant.2), direction=LT
}

ENTRY %main (p0: f32[1000,64], p1: s32[8], p2: f32[1,64], p3: (s32[], f32[128,256], f32[256,128])) -> f32[1000,64] {
  %p0 = f32[1000,64]{1,0} parameter(0)
  %p1 = s32[8]{0} parameter(1)
  %p2 = f32[1,64]{1,0} parameter(2)
  %p3 = (s32[], f32[128,256], f32[256,128]) parameter(3)
  %fusion.1 = f32[8,64]{1,0} fusion(%p0, %p1), kind=kLoop, calls=%fused_gather
  %while.1 = (s32[], f32[128,256], f32[256,128]) while(%p3), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %constant.3 = s32[] constant(5)
  ROOT %fusion.2 = f32[1000,64]{1,0} fusion(%p0, %p2, %constant.3), kind=kLoop, calls=%fused_dus
}
"""


@pytest.fixture(scope="module")
def hc():
    return HloCost(HLO)


def test_parse_finds_computations(hc):
    assert "%main" in hc.comps
    assert hc.entry == "%main"
    assert "%fused_gather" in hc.comps


def test_type_bytes():
    assert type_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert type_bytes("bf16[8]") == 16
    assert type_bytes("(f32[2,2], s32[3])") == 16 + 12


def test_while_trip_count_multiplies_flops(hc):
    # one dot of 2*128*128*256 flops, 10 trips
    assert hc.flops() == pytest.approx(10 * 2 * 128 * 128 * 256)


def test_collectives_trip_multiplied_and_ring_modeled(hc):
    colls = hc.collective_bytes()
    # all-reduce: result 128*128*4 bytes × ring factor 2 × 10 trips
    assert colls["all-reduce"] == pytest.approx(128 * 128 * 4 * 2 * 10)


def test_gather_fusion_bills_window_not_table(hc):
    # fusion.1 reads: gathered window (8×64×4) + indices (8×4), writes 8×64×4;
    # it must NOT bill the 1000×64×4 table.
    comp = hc.comps["%main"]
    op = next(o for o in comp.ops if o.name == "%fusion.1")
    reads = hc._operand_read_bytes(comp, op)
    assert reads == pytest.approx(8 * 64 * 4 + 8 * 4)
    assert hc._result_write_bytes(comp, op) == 8 * 64 * 4


def test_dus_fusion_bills_update_not_buffer(hc):
    comp = hc.comps["%main"]
    op = next(o for o in comp.ops if o.name == "%fusion.2")
    # write = the 1×64 update, not the 1000×64 buffer
    assert hc._result_write_bytes(comp, op) == 64 * 4
    # reads: aliased buffer not billed; update operand + s32 index billed
    reads = hc._operand_read_bytes(comp, op)
    assert reads == pytest.approx(64 * 4 + 4)


def test_total_bytes_slice_aware(hc):
    total = hc.hbm_bytes()
    fusion1 = (8 * 64 * 4 + 8 * 4) + 8 * 64 * 4
    fusion2 = 64 * 4 + 64 * 4
    body_once = (128 * 256 * 4 + 256 * 128 * 4) + 128 * 128 * 4 \
        + 128 * 128 * 4 * 2   # dot r+w… allreduce r+w
    # while body bytes × 10 trips plus the two fusions (± small tuple ops)
    assert total >= fusion1 + fusion2
    assert total == pytest.approx(fusion1 + fusion2 + 10 * (
        128 * 256 * 4 + 256 * 128 * 4    # dot reads
        + 128 * 128 * 4                  # dot write
        + 128 * 128 * 4 * 2              # all-reduce read+write
    ), rel=0.05)


def test_explain_runs(hc):
    from repro.roofline.explain import explain

    txt = explain(HLO, top=5)
    assert "total bytes" in txt
    assert "dot" in txt or "fusion" in txt
