"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/loss + prefill + decode step on CPU; asserts shapes + finite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config, input_specs
from repro.configs.base import ShapeConfig
from repro.models import transformer as T

B, S = 2, 32

# jamba-52b's smoke config is by far the largest (hybrid attn+mamba+moe
# stack) and dominates this module's wall time → tagged slow
SMOKE_ARCHS = [
    pytest.param(a, marks=pytest.mark.slow) if a == "jamba-v0.1-52b" else a
    for a in ARCHS
]


def _batch(cfg, key, *, train=True):
    ks = jax.random.split(key, 4)
    batch = {}
    if cfg.stub_frontend and cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.bfloat16)
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (B, 3, S)
        )
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(ks[2], (B, S, cfg.d_model),
                                                jnp.bfloat16)
    if train:
        batch["labels"] = jax.random.randint(ks[3], (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(T.loss_fn(cfg))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # one grad step must also be finite
    grads = jax.jit(jax.grad(lambda p, b: T.loss_fn(cfg)(p, b)[0]))(params, batch)
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves), (
        f"{arch}: non-finite grads"
    )


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    params = T.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1), train=False)
    logits, cache = jax.jit(T.prefill_fn(cfg))(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: prefill NaN"

    # decode continues from a fresh cache (prefill cache layout differs for
    # encdec cross-attn, exercised above)
    full = T.init_cache(cfg, B, S, dtype=jnp.float32)
    if cfg.family == "encdec":
        full["cross_kv"] = jnp.zeros_like(full["cross_kv"]) + cache["cross_kv"].astype(full["cross_kv"].dtype)
    step = jax.jit(T.decode_fn(cfg))
    tokens = jnp.zeros((B, 1), jnp.int32)
    for pos in range(2):
        logits2, full = step(params, tokens, full, jnp.asarray(pos, jnp.int32))
        assert logits2.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits2)).all(), f"{arch}: decode NaN"
        tokens = jnp.argmax(logits2, axis=-1)[:, None].astype(jnp.int32)


def test_remat_matches_no_remat():
    cfg = get_smoke_config("qwen3-1.7b")
    params = T.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l0, _ = jax.jit(T.loss_fn(cfg, remat=False))(params, batch)
    l1, _ = jax.jit(T.loss_fn(cfg, remat=True))(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_chunked_attention_matches_full():
    cfg = get_smoke_config("qwen3-1.7b")
    params = T.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l0, _ = jax.jit(T.loss_fn(cfg, q_chunk=0))(params, batch)
    l1, _ = jax.jit(T.loss_fn(cfg, q_chunk=8))(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=2e-3, atol=2e-3)


def test_moe_sorted_matches_dense_reference():
    from repro.models.moe import init_moe, moe_dense, moe_sorted

    cfg = get_smoke_config("granite-moe-1b-a400m").scaled(capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    a, _ = jax.jit(lambda p, x: moe_dense(p, x, cfg, jnp.float32))(p, x)
    b, _ = jax.jit(lambda p, x: moe_sorted(p, x, cfg, jnp.float32))(p, x)
    # generous capacity → no drops → exact same routing math
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)


def test_mamba_chunked_matches_stepwise():
    """SSD chunked scan == token-by-token recurrence (decode oracle)."""
    from repro.models.mamba2 import (
        init_mamba, init_mamba_state, mamba_block, mamba_decode_step,
    )

    cfg = get_smoke_config("mamba2-370m").scaled(ssm_chunk=8)
    p = init_mamba(jax.random.PRNGKey(0), cfg)
    Sl = 16
    x = jax.random.normal(jax.random.PRNGKey(1), (1, Sl, cfg.d_model),
                          jnp.float32) * 0.5
    y_chunk, final = jax.jit(
        lambda p, x: mamba_block(p, x, cfg, jnp.float32)
    )(p, x)

    state = init_mamba_state(cfg, 1)
    outs = []
    step = jax.jit(lambda p, xt, st: mamba_decode_step(p, xt, st, cfg,
                                                       jnp.float32))
    for t in range(Sl):
        o, state = step(p, x[:, t : t + 1], state)
        outs.append(o)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_chunk), np.asarray(y_step), rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(final["h"]), np.asarray(state["h"]), rtol=2e-3, atol=2e-3
    )
