import itertools

import numpy as np
import pytest

from repro.core.pattern import clique, cycle, house, rectangle, star, triangle
from repro.core.restrictions import (
    count_orders_satisfying, first_restriction_set, generate_restriction_sets,
    no_conflict, surviving_perms, validate,
)

PATTERNS = [triangle(), rectangle(), house(), clique(4), cycle(5), star(4)]


@pytest.mark.parametrize("pattern", PATTERNS, ids=lambda p: p.name)
def test_every_generated_set_is_complete(pattern):
    """Each set must kill every non-identity automorphism (paper Alg. 1)."""
    sets = generate_restriction_sets(pattern)
    assert sets, "at least one restriction set must exist"
    auts = pattern.automorphisms()
    ident = tuple(range(pattern.n))
    for rs in sets:
        assert surviving_perms(auts, rs) == [ident]
        assert validate(pattern, rs)


@pytest.mark.parametrize("pattern", PATTERNS, ids=lambda p: p.name)
def test_kn_count_equals_orbit_count(pattern):
    """On K_n: #embeddings == n!/|Aut| for every restriction set."""
    n = pattern.n
    n_fact = 1
    for i in range(2, n + 1):
        n_fact *= i
    for rs in generate_restriction_sets(pattern, max_sets=16):
        assert count_orders_satisfying(n, rs) * pattern.aut_count() == n_fact


def test_multiple_distinct_sets_generated():
    """The paper's key claim vs GraphZero: MULTIPLE sets per pattern."""
    for pattern, lo in [(rectangle(), 2), (clique(4), 2), (cycle(5), 2)]:
        sets = generate_restriction_sets(pattern)
        assert len(set(map(frozenset, sets))) >= lo


def test_no_conflict_example_from_paper():
    """Fig. 4(d): after id(B)>id(D) and id(A)>id(C), the rotation
    permutation (2) = (A,B,C,D) is eliminated."""
    rot = (1, 2, 3, 0)  # A->B->C->D->A
    rs = [(1, 3), (0, 2)]  # id(B) > id(D), id(A) > id(C)
    assert not no_conflict(rot, rs)


def test_identity_never_eliminated():
    for pattern in PATTERNS:
        ident = tuple(range(pattern.n))
        for rs in generate_restriction_sets(pattern, max_sets=8):
            assert no_conflict(ident, rs)


def test_first_set_deterministic():
    assert first_restriction_set(house()) == first_restriction_set(house())
