"""Persistent plan store: serialization round-trips, header
invalidation, and the disk-warm restart guarantee (zero configuration
searches, zero fresh JIT traces — ISSUE 4 acceptance)."""
import json
import os

import pytest

from repro.configs.graphpi import get_pattern
from repro.core.config_search import (
    config_from_dict, config_to_dict, search_configuration,
)
from repro.core.executor import ExecutorConfig, compute_stats
from repro.core.plan import build_plan, plan_from_dict, plan_to_dict
from repro.graph.datasets import erdos_renyi
from repro.query import (
    PlanCache, PlanStore, QueryEngine, QueryRequest, relabeled_variant,
)
from repro.query.store import SCHEMA_VERSION, key_digest, repro_fingerprint

CFG = ExecutorConfig(capacity=1 << 12)
ROUND_TRIP_PATTERNS = ["triangle", "rectangle", "P1", "P2"]


@pytest.fixture(scope="module")
def tiny_graph():
    return erdos_renyi(64, 256, seed=7, name="er64")


@pytest.fixture(scope="module")
def tiny_stats(tiny_graph):
    return compute_stats(tiny_graph, CFG)


# The workload one "replica process" serves; the restart tests replay it
# byte-for-byte against a fresh engine over the same store.
def workload():
    return [
        QueryRequest(get_pattern("P1")),
        QueryRequest(get_pattern("triangle")),
        QueryRequest(get_pattern("rectangle"), use_iep=True),
    ]


@pytest.fixture(scope="module")
def warm_store(tmp_path_factory, tiny_graph, tiny_stats):
    """A store populated by one cold serving pass (write-behind)."""
    root = str(tmp_path_factory.mktemp("plan-store"))
    engine = QueryEngine(tiny_graph, cfg=CFG, store=PlanStore(root),
                         stats=tiny_stats)
    results = engine.serve(workload())
    assert engine.cache.stats.n_searches == len(workload())
    assert engine.cache.stats.export_fails == 0
    return root, [r.count for r in results]


# ------------------------------------------------------- dict round-trips
@pytest.mark.parametrize("name", ROUND_TRIP_PATTERNS)
@pytest.mark.parametrize("use_iep", [False, True])
def test_config_round_trip_exact(tiny_stats, name, use_iep):
    config = search_configuration(
        get_pattern(name), tiny_stats, use_iep=use_iep).best
    thawed = config_from_dict(json.loads(json.dumps(config_to_dict(config))))
    assert thawed == config        # dataclass equality, tuples and all


@pytest.mark.parametrize("name", ROUND_TRIP_PATTERNS)
@pytest.mark.parametrize("use_iep", [False, True])
def test_plan_round_trip_exact(tiny_stats, name, use_iep):
    pattern = get_pattern(name)
    config = search_configuration(pattern, tiny_stats, use_iep=use_iep).best
    plan = build_plan(pattern, config.order, config.res_set,
                      iep_k=config.iep_k)
    thawed = plan_from_dict(json.loads(json.dumps(plan_to_dict(plan))))
    assert thawed == plan
    if use_iep and config.iep_k > 0:
        assert thawed.iep is not None and thawed.iep.k == config.iep_k


def test_executor_fingerprint_string_stable():
    assert CFG.fingerprint() == CFG.fingerprint()
    assert ExecutorConfig(capacity=1 << 13).fingerprint() != CFG.fingerprint()
    assert ExecutorConfig(
        capacity=CFG.capacity, degree_buckets=((64, 1.0),),
    ).fingerprint() != CFG.fingerprint()
    # the resolved (not declared) pallas path is what the program bakes
    # in: auto must alias whichever explicit setting it resolves to
    assert ExecutorConfig(use_pallas=None).fingerprint() in (
        ExecutorConfig(use_pallas=False).fingerprint(),
        ExecutorConfig(use_pallas=True).fingerprint(),
    )


# ------------------------------------------------------------- store I/O
def test_store_save_load_round_trip(tmp_path, tiny_graph, tiny_stats):
    from repro.query.cache import graph_fingerprint

    store = PlanStore(str(tmp_path))
    pattern = get_pattern("P4")
    config = search_configuration(pattern, tiny_stats).best
    plan = build_plan(pattern, config.order, config.res_set)
    key = PlanCache.entry_key(
        pattern, graph_fingerprint(tiny_graph, tiny_stats), CFG)
    digest = store.save(key, pattern=pattern, config=config, plan=plan,
                        exec_bytes=b"not-a-real-executable",
                        search_seconds=0.25)
    assert digest == key_digest(key)
    assert len(store) == 1

    rec = store.load(key)
    assert rec is not None
    assert rec.config == config and rec.plan == plan
    assert rec.pattern == pattern
    assert rec.exec_bytes == b"not-a-real-executable"
    assert rec.mode == "graphpi" and rec.use_iep is False
    assert rec.search_seconds == 0.25
    # absent key is a miss, not an error
    other = PlanCache.entry_key(
        get_pattern("P2"), graph_fingerprint(tiny_graph, tiny_stats), CFG)
    assert store.load(other) is None
    assert store.stats.misses == 1


def _tamper(store, digest, **patch):
    path = os.path.join(store.vdir, digest + ".json")
    rec = json.load(open(path))
    rec.update(patch)
    with open(path, "w") as f:
        json.dump(rec, f)


def test_store_rejects_mismatched_headers(tmp_path, tiny_graph, tiny_stats):
    from repro.query.cache import graph_fingerprint

    store = PlanStore(str(tmp_path))
    pattern = get_pattern("triangle")
    config = search_configuration(pattern, tiny_stats).best
    plan = build_plan(pattern, config.order, config.res_set)
    key = PlanCache.entry_key(
        pattern, graph_fingerprint(tiny_graph, tiny_stats), CFG)
    digest = store.save(key, pattern=pattern, config=config, plan=plan)

    _tamper(store, digest, schema_version=SCHEMA_VERSION + 1)
    assert store.load(key) is None
    assert store.stats.rejects.get("schema_version") == 1

    _tamper(store, digest, schema_version=SCHEMA_VERSION, jax="0.0.1")
    assert store.load(key) is None
    assert store.stats.rejects.get("jax_version") == 1

    _tamper(store, digest, jax=__import__("jax").__version__,
            repro_fingerprint="stale-code-fingerprint")
    assert store.load(key) is None
    assert store.stats.rejects.get("repro_fingerprint") == 1

    # a truncated/corrupt record degrades to a cold start, never raises
    with open(os.path.join(store.vdir, digest + ".json"), "w") as f:
        f.write("{not json")
    assert store.load(key) is None
    assert store.stats.rejects.get("corrupt") == 1


def test_store_backend_mismatch_drops_executable_keeps_plan(
        tmp_path, tiny_graph, tiny_stats):
    from repro.query.cache import graph_fingerprint

    store = PlanStore(str(tmp_path))
    pattern = get_pattern("triangle")
    config = search_configuration(pattern, tiny_stats).best
    plan = build_plan(pattern, config.order, config.res_set)
    key = PlanCache.entry_key(
        pattern, graph_fingerprint(tiny_graph, tiny_stats), CFG)
    digest = store.save(key, pattern=pattern, config=config, plan=plan,
                        exec_bytes=b"cpu-compiled-blob")
    _tamper(store, digest, backend="tpu")
    rec = store.load(key)
    assert rec is not None                  # plans are device-independent
    assert rec.exec_bytes is None           # the executable is not
    assert store.stats.exec_drops == 1


def test_repro_fingerprint_is_stable():
    assert repro_fingerprint() == repro_fingerprint()
    assert len(repro_fingerprint()) == 64


# ------------------------------------------- disk-warm restart guarantee
def test_fresh_engine_replays_with_zero_searches_and_zero_compiles(
        warm_store, tiny_graph, tiny_stats):
    """ISSUE 4 acceptance: a restarted store-backed replica replays the
    prior workload with n_searches == 0 and n_compiles == 0."""
    root, cold_counts = warm_store
    engine = QueryEngine(tiny_graph, cfg=CFG, store=PlanStore(root),
                         stats=tiny_stats)
    results = engine.serve(workload())
    stats = engine.cache.stats
    assert [r.count for r in results] == cold_counts
    assert stats.n_searches == 0, stats.as_dict()
    assert stats.n_compiles == 0, stats.as_dict()
    assert stats.persist_hits == len(workload())
    assert stats.aot_loads == len(workload())
    assert all(r.search_seconds == 0.0 for r in results)


def test_warm_from_disk_preloads_then_serves_pure_hits(
        warm_store, tiny_graph, tiny_stats):
    root, cold_counts = warm_store
    engine = QueryEngine(tiny_graph, cfg=CFG, store=PlanStore(root),
                         stats=tiny_stats)
    assert engine.warm_from_disk() == len(workload())
    # replay + an isomorphic relabeling: every request is an in-memory hit
    reqs = workload() + [
        QueryRequest(relabeled_variant(get_pattern("P1"), seed=5))]
    results = engine.serve(reqs)
    stats = engine.cache.stats
    assert [r.count for r in results[:3]] == cold_counts
    assert results[3].count == cold_counts[0]
    assert stats.hits == len(reqs) and stats.misses == 0
    assert stats.n_searches == 0 and stats.n_compiles == 0
    # preloads are counted apart from load-through persist hits: no
    # request was served from disk here, every request was an in-memory
    # hit on a preloaded entry
    assert stats.preloads == len(workload()) and stats.persist_hits == 0


def test_preload_skips_incompatible_layouts(warm_store, tiny_graph,
                                            tiny_stats):
    root, _ = warm_store
    # a different executor capacity compiles different programs: nothing
    # in the store may preload into this engine
    engine = QueryEngine(tiny_graph, cfg=ExecutorConfig(capacity=1 << 11),
                         store=PlanStore(root), stats=tiny_stats)
    assert engine.warm_from_disk() == 0


def test_store_does_not_leak_across_graphs(warm_store, tiny_stats):
    root, _ = warm_store
    other = erdos_renyi(64, 256, seed=8, name="er64b")
    engine = QueryEngine(other, cfg=CFG, store=PlanStore(root))
    assert engine.warm_from_disk() == 0
    res = engine.submit(QueryRequest(get_pattern("P1")))
    assert not res.cache_hit
    assert engine.cache.stats.persist_hits == 0
    assert engine.cache.stats.n_searches == 1


# ------------------------------------------------------- eviction release
def test_lru_eviction_releases_matcher_memory(tiny_graph, tiny_stats):
    cache = PlanCache(max_entries=1)
    e1, _ = cache.get_or_build(get_pattern("triangle"), tiny_graph,
                               tiny_stats, cfg=CFG, warm=False)
    assert e1.matcher._arrays is not None
    cache.get_or_build(get_pattern("rectangle"), tiny_graph, tiny_stats,
                       cfg=CFG, warm=False)
    assert cache.stats.evictions == 1
    # the evicted matcher dropped its executables + device-array refs
    assert e1.matcher._arrays is None
    assert not e1.matcher._fns
    with pytest.raises(RuntimeError, match="released"):
        e1.matcher.count()


def test_zero_capacity_cache_keeps_returned_entry_usable(tiny_graph,
                                                         tiny_stats):
    # max_entries=0 immediately pops every entry, but the entry handed
    # back to the caller must stay live (eviction-release must not
    # apply to the entry being returned)
    cache = PlanCache(max_entries=0)
    entry, hit = cache.get_or_build(get_pattern("triangle"), tiny_graph,
                                    tiny_stats, cfg=CFG, warm=False)
    assert not hit and len(cache) == 0
    assert entry.count().count >= 0        # still executable


# ----------------------------------- schema v2: labels + v1 migration
def _searched(pattern, stats):
    """(canonical pattern, config, plan) the way the cache persists them."""
    from repro.query.canon import canonical_form

    canon = canonical_form(pattern)
    config = search_configuration(canon, stats).best
    plan = build_plan(canon, config.order, config.res_set,
                      iep_k=config.iep_k)
    return canon, config, plan


@pytest.fixture(scope="module")
def labeled_graph():
    from repro.graph.datasets import named_dataset

    return named_dataset("tiny-labeled")


@pytest.fixture(scope="module")
def labeled_stats(labeled_graph):
    return compute_stats(labeled_graph, CFG)


def test_labeled_and_skeleton_never_share_entry_or_record(
        tmp_path, labeled_graph, labeled_stats):
    """Key-separation satellite: a labeled pattern, a second label
    assignment of the same skeleton, and the bare skeleton are three
    distinct cache entries AND three distinct store records — on the
    SAME graph, executor config, and layout."""
    from repro.query.cache import graph_fingerprint

    gfp = graph_fingerprint(labeled_graph, labeled_stats)
    tri = get_pattern("triangle")
    variants = [tri, tri.with_labels((0, 1, 1)), tri.with_labels((0, 0, 1))]
    keys = [PlanCache.entry_key(p, gfp, CFG) for p in variants]
    assert len({k[0] for k in keys}) == 3          # canonical keys split
    assert len({key_digest(k) for k in keys}) == 3  # store slots split

    store = PlanStore(str(tmp_path / "store"))
    cache = PlanCache(store=store)
    for p in variants:
        cache.get_or_build(p, labeled_graph, labeled_stats, cfg=CFG,
                           warm=False)
    assert len(cache) == 3 and len(store) == 3
    assert cache.stats.n_searches == 3
    # re-querying any variant hits its own entry, never a sibling's
    for p in variants:
        _, hit = cache.get_or_build(p, labeled_graph, labeled_stats,
                                    cfg=CFG, warm=False)
        assert hit
    assert cache.stats.n_searches == 3


def test_labeled_record_round_trips_with_vlabels(tmp_path, labeled_graph,
                                                 labeled_stats):
    from repro.query.cache import graph_fingerprint

    store = PlanStore(str(tmp_path))
    canon, config, plan = _searched(
        get_pattern("rectangle").with_labels((0, 1, 0, None)),
        labeled_stats)
    assert plan.vlabels is not None
    key = PlanCache.entry_key(
        canon, graph_fingerprint(labeled_graph, labeled_stats), CFG)
    store.save(key, pattern=canon, config=config, plan=plan)
    rec = PlanStore(store.root).load(key)
    assert rec is not None
    assert rec.pattern == canon and rec.pattern.labels == canon.labels
    assert rec.plan == plan and rec.plan.vlabels == plan.vlabels


def test_v1_unlabeled_records_still_load(tmp_path, labeled_stats):
    """A v2 store opened over a v1 tree warm-loads unlabeled records in
    place (same digests), and a v2 rewrite of the same key shadows the
    legacy copy."""
    store = PlanStore(str(tmp_path))
    canon, config, plan = _searched(get_pattern("triangle"), labeled_stats)
    key = PlanCache.entry_key(canon, ("gfp", 64, 256, 1), CFG)
    digest = store.save(key, pattern=canon, config=config, plan=plan,
                        schema_version=1)
    assert os.path.exists(os.path.join(store.root, "v1", digest + ".json"))
    assert len(store) == 1

    fresh = PlanStore(store.root)
    rec = fresh.load(key)
    assert rec is not None and rec.header["schema_version"] == 1

    # re-saving at the current version shadows the v1 copy on load
    store.save(key, pattern=canon, config=config, plan=plan)
    rec2 = PlanStore(store.root).load(key)
    assert rec2 is not None
    assert rec2.header["schema_version"] == SCHEMA_VERSION
    # records() must not yield the same digest twice across versions
    digs = [r.digest for r in PlanStore(store.root).records()]
    assert digs.count(digest) == 1


def test_labeled_patterns_refuse_v1_downgrade(tmp_path, labeled_stats):
    store = PlanStore(str(tmp_path))
    canon, config, plan = _searched(
        get_pattern("triangle").with_labels((0, 1, 1)), labeled_stats)
    key = PlanCache.entry_key(canon, ("gfp", 64, 256, 1), CFG)
    with pytest.raises(ValueError, match="labels are a v2 field"):
        store.save(key, pattern=canon, config=config, plan=plan,
                   schema_version=1)


def test_forged_v1_labeled_record_rejected(tmp_path, labeled_stats):
    """A v1 record claiming label fields could not have been written by
    any v1 writer: the loader rejects it and fsck flags it."""
    store = PlanStore(str(tmp_path))
    canon, config, plan = _searched(get_pattern("triangle"), labeled_stats)
    key = PlanCache.entry_key(canon, ("gfp", 64, 256, 1), CFG)
    digest = store.save(key, pattern=canon, config=config, plan=plan,
                        schema_version=1)
    path = os.path.join(store.root, "v1", digest + ".json")
    rec = json.load(open(path))
    rec["pattern"]["labels"] = [0, 1, 1]
    with open(path, "w") as f:
        json.dump(rec, f)

    fresh = PlanStore(store.root)
    assert fresh.load(key) is None
    assert fresh.stats.rejects.get("v1-labeled") == 1
    report = PlanStore(store.root).fsck()
    assert any(f.rule == "record-version-labeled"
               for f in report["findings"][digest])
    assert report["quarantined"] == 1
    assert os.path.exists(
        os.path.join(store.root, "v1", "quarantine", digest + ".json"))


def test_labeled_engine_round_trip_through_store(tmp_path, labeled_graph,
                                                 labeled_stats):
    """End-to-end: a labeled query served, persisted, and replayed by a
    restarted replica with zero searches — and verified against the
    oracle through the engine's own verify path."""
    tri = get_pattern("triangle").with_labels((0, 1, 1))
    root = str(tmp_path / "plan-store")
    e1 = QueryEngine(labeled_graph, cfg=CFG, store=PlanStore(root),
                     stats=labeled_stats)
    r1 = e1.submit(QueryRequest(tri, verify=True))
    assert r1.verified and not r1.cache_hit

    e2 = QueryEngine(labeled_graph, cfg=CFG, store=PlanStore(root),
                     stats=labeled_stats)
    r2 = e2.submit(QueryRequest(tri))
    assert r2.count == r1.count
    assert e2.cache.stats.n_searches == 0
    assert e2.cache.stats.persist_hits == 1
