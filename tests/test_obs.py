"""Observability layer (repro.obs): tracer span nesting (including
across threads), near-zero disabled cost, Chrome/JSONL export schema,
deterministic histograms, the unified latency dict, registry snapshots,
the summarize CLI gates, and span nesting through a real gateway replay."""
import json
import threading
import time

import numpy as np
import pytest

from repro.core.executor import ExecutorConfig, compute_stats
from repro.graph.datasets import erdos_renyi
from repro.obs import (
    Histogram, MetricsRegistry, Tracer, get_tracer, latency_summary,
    set_tracer, timer,
)
from repro.obs.metrics import _key, percentile
from repro.obs.summarize import main as summarize_main, summarize

CFG = ExecutorConfig(capacity=1 << 12)


@pytest.fixture()
def tracer():
    """Enabled tracer installed as the process tracer for one test."""
    old = get_tracer()
    tr = set_tracer(Tracer(enabled=True))
    yield tr
    set_tracer(old)


# ----------------------------------------------------------------- tracer
def test_span_nesting_parent_child(tracer):
    with tracer.span("a.root", k=1) as root:
        with tracer.span("a.child") as c1:
            pass
        with tracer.span("a.child") as c2:
            with tracer.span("a.grand") as g:
                pass
    spans = {s["id"]: s for s in tracer.spans()}
    assert spans[root.span_id]["parent"] is None
    assert spans[c1.span_id]["parent"] == root.span_id
    assert spans[c2.span_id]["parent"] == root.span_id
    assert spans[g.span_id]["parent"] == c2.span_id
    assert spans[root.span_id]["attrs"] == {"k": 1}
    # children close before parents, so durations nest too
    assert spans[g.span_id]["dur_ns"] <= spans[c2.span_id]["dur_ns"]


def test_span_set_attaches_mid_span_attrs(tracer):
    with tracer.span("x.y", a=1) as sp:
        sp.set(b=2, a=3)
    (rec,) = tracer.spans()
    assert rec["attrs"] == {"a": 3, "b": 2}


def test_spans_never_parent_across_threads(tracer):
    """Each thread gets its own parent chain: a span opened on a worker
    thread while the main thread holds an open span must be a root."""
    results = {}

    def worker(name):
        with tracer.span(f"w.{name}") as outer:
            with tracer.span(f"w.{name}.inner") as inner:
                pass
        results[name] = (outer.span_id, outer.parent_id,
                         inner.span_id, inner.parent_id)

    with tracer.span("main.root"):
        threads = [threading.Thread(target=worker, args=(f"t{i}",))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    for _, (oid, oparent, _iid, iparent) in results.items():
        assert oparent is None          # not parented under main.root
        assert iparent == oid           # nested within its own thread
    # main's span records main's thread id; workers record their own
    # (idents can be reused across short-lived threads, so >= 2 not 5)
    tids = {s["tid"] for s in tracer.spans()}
    main_tid = threading.get_ident()
    assert main_tid in tids and len(tids) >= 2


def test_disabled_tracer_is_shared_noop_and_cheap():
    tr = Tracer(enabled=False)
    assert tr.span("a.b", k=1) is tr.span("c.d")    # no allocation
    assert len(tr) == 0
    n = 100_000
    best = float("inf")
    for _ in range(3):
        with timer() as t:
            for _ in range(n):
                with tr.span("hot.loop", i=0):
                    pass
        best = min(best, t.seconds)
    # ~0.4us/span measured; generous 2us bound for loaded CI machines
    assert best / n < 2e-6, f"{best / n * 1e9:.0f}ns per disabled span"


def test_chrome_export_round_trips(tracer, tmp_path):
    with tracer.span("engine.round", tickets=3):
        with tracer.span("executor.count", depth=4):
            pass
    path = tmp_path / "trace.json"
    assert tracer.export_chrome(str(path)) == 2
    doc = json.load(open(path))                     # must parse
    assert doc["displayTimeUnit"] == "ms"
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    outer, inner = by_name["engine.round"], by_name["executor.count"]
    for e in (outer, inner):
        assert e["ph"] == "X"
        assert e["cat"] == e["name"].split(".")[0]  # perfetto category
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
    assert inner["args"]["parent"] == outer["args"]["id"]
    assert outer["args"]["tickets"] == 3
    assert inner["args"]["depth"] == 4
    # ... and the summarizer accepts its own exporter's output
    summ = summarize(doc)
    assert summ["events"] == 2
    assert summ["rows"][0]["count"] == 1


def test_jsonl_export(tracer, tmp_path):
    with tracer.span("a.b"):
        pass
    path = tmp_path / "spans.jsonl"
    assert tracer.export_jsonl(str(path)) == 1
    (rec,) = [json.loads(line) for line in open(path)]
    assert rec["name"] == "a.b" and rec["parent"] is None


def test_max_spans_bound_counts_drops(tmp_path):
    tr = Tracer(enabled=True, max_spans=2)
    for i in range(4):
        with tr.span("s.n", i=i):
            pass
    assert len(tr) == 2 and tr.dropped == 2
    path = tmp_path / "t.json"
    tr.export_chrome(str(path))
    assert json.load(open(path))["otherData"]["dropped_spans"] == 2
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_timer_measures():
    with timer() as t:
        time.sleep(0.01)
    assert t.seconds >= 0.005


# ---------------------------------------------------------------- metrics
def test_percentile_matches_numpy():
    rng = np.random.default_rng(3)
    vals = sorted(rng.exponential(10.0, size=257).tolist())
    for q in (0, 12.5, 50, 95, 99, 100):
        assert percentile(vals, q) == pytest.approx(
            float(np.percentile(vals, q)), rel=1e-12)
    assert percentile([7.0], 99) == 7.0
    with pytest.raises(ValueError):
        percentile([], 50)


def test_histogram_exact_counts_and_deterministic_decimation():
    h1, h2 = Histogram(max_samples=16), Histogram(max_samples=16)
    vals = [float((7 * i) % 101) for i in range(1000)]
    for v in vals:
        h1.observe(v)
        h2.observe(v)
    # count/total exact even after the reservoir thinned
    assert h1.count == 1000 and h1.total == pytest.approx(sum(vals))
    assert len(h1._samples) < 1000
    # no RNG: identical sequences give identical reservoirs + summaries
    assert h1._samples == h2._samples
    assert h1.summary() == h2.summary()
    s = h1.summary()
    assert s["n"] == 1000 and 0 <= s["p50"] <= 100


def test_latency_summary_unified_keys():
    h = Histogram()
    keys = {"n", "p50_ms", "p95_ms", "p99_ms", "mean_ms"}
    empty = latency_summary(h)
    assert set(empty) == keys and empty["n"] == 0
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    s = latency_summary(h)
    assert set(s) == keys
    assert s["p50_ms"] == 2.0 and s["mean_ms"] == 2.0


def test_registry_keys_snapshot_and_reset_window():
    reg = MetricsRegistry()
    # labels sort into one canonical key, order-independent
    assert _key("s.m", {"b": 2, "a": 1}) == "s.m{a=1,b=2}"
    c = reg.counter("engine.executions")
    assert reg.counter("engine.executions") is c    # get-or-create
    c.inc(3)
    reg.gauge("engine.pending").set(7)
    reg.histogram("scheduler.turn_item_ms", workload="graph",
                  phase="solo").observe(4.0)
    reg.register_collector(lambda: {"cache.hits": 9})
    snap = reg.snapshot()
    assert snap["engine.executions"] == 3
    assert snap["engine.pending"] == 7
    assert snap["scheduler.turn_item_ms{phase=solo,workload=graph}"]["n"] == 1
    assert snap["cache.hits"] == 9
    reg.reset_window()
    snap = reg.snapshot()
    assert snap["engine.executions"] == 0           # counters zeroed
    assert snap["scheduler.turn_item_ms{phase=solo,workload=graph}"]["n"] == 0
    assert snap["engine.pending"] == 7              # gauges keep state
    assert snap["cache.hits"] == 9                  # collectors unaffected


# -------------------------------------------------------------- summarize
def _doc(events):
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def _ev(name, sid, parent, dur, ts=0.0):
    return {"name": name, "cat": name.split(".")[0], "ph": "X", "ts": ts,
            "dur": dur, "pid": 1, "tid": 1,
            "args": {"id": sid, "parent": parent}}


def test_summarize_self_time_and_coverage():
    doc = _doc([_ev("a.root", 1, None, 100.0),
                _ev("a.leaf", 2, 1, 60.0, ts=10.0)])
    s = summarize(doc)
    assert s["wall_us"] == 100.0 and s["leaf_us"] == 60.0
    assert s["leaf_coverage"] == pytest.approx(0.6)
    rows = {r["name"]: r for r in s["rows"]}
    assert rows["a.root"]["self_us"] == 40.0 and not rows["a.root"]["leaf"]
    assert rows["a.leaf"]["self_us"] == 60.0 and rows["a.leaf"]["leaf"]


def test_summarize_rejects_malformed():
    with pytest.raises(ValueError):
        summarize({"notATrace": []})
    with pytest.raises(ValueError):
        summarize(_doc([]))                         # no complete events
    with pytest.raises(ValueError):
        summarize(_doc([{"ph": "X", "name": "x", "args": {}}]))  # no dur


def test_summarize_cli_exit_codes(tmp_path, capsys):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_doc([_ev("a.root", 1, None, 100.0),
                                     _ev("a.leaf", 2, 1, 60.0)])))
    assert summarize_main([str(good)]) == 0
    assert "leaf_coverage=60.0%" in capsys.readouterr().out
    # coverage gate: 60% < 95% -> exit 2 (the bench/CI acceptance knob)
    assert summarize_main([str(good), "--min-coverage", "0.95"]) == 2
    assert summarize_main([str(good), "--min-coverage", "0.5"]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert summarize_main([str(bad)]) == 1
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps(_doc([])))
    assert summarize_main([str(empty)]) == 1
    assert summarize_main([str(tmp_path / "missing.json")]) == 1


# ---------------------------------------------- instrumented serving path
@pytest.fixture(scope="module")
def obs_graph():
    return erdos_renyi(64, 256, seed=7, name="er64")


@pytest.fixture(scope="module")
def obs_stats(obs_graph):
    return compute_stats(obs_graph, CFG)


def _replay(graph, stats, metrics):
    """One gateway_smoke-shaped run: 2 classes x (original + iso dup)."""
    from repro.configs.graphpi import get_pattern
    from repro.query import QueryEngine, QueryRequest, relabeled_variant
    from repro.serve.gateway import Gateway, GraphQueryWorkload, Share

    engine = QueryEngine(graph, cfg=CFG, stats=stats, metrics=metrics)
    reqs = []
    for i, name in enumerate(("triangle", "P1")):
        p = get_pattern(name)
        reqs.append(QueryRequest(p))
        reqs.append(QueryRequest(relabeled_variant(p, seed=i)))
    gw = Gateway(metrics=metrics)
    wl = gw.add(GraphQueryWorkload(engine, reqs), Share(quantum=2))
    gw.run()
    return engine, gw, wl.results()


def test_gateway_replay_span_nesting(tracer, obs_graph, obs_stats):
    """The acceptance-criteria trace shape: scheduler rounds nest engine
    plan/execute spans which nest executor dispatch spans."""
    engine, _gw, results = _replay(obs_graph, obs_stats, MetricsRegistry())
    assert len(results) == 4
    spans = tracer.spans()
    by_id = {s["id"]: s for s in spans}

    def parent_name(s):
        return by_id[s["parent"]]["name"] if s["parent"] else None

    names = {s["name"] for s in spans}
    assert {"gateway.run", "scheduler.round", "scheduler.turn",
            "engine.round", "engine.plan", "engine.execute",
            "executor.count", "executor.dispatch",
            "cache.search", "cache.compile"} <= names
    for s in spans:
        if s["name"] == "scheduler.round":
            assert parent_name(s) == "gateway.run"
        elif s["name"] == "scheduler.turn":
            assert parent_name(s) == "scheduler.round"
        elif s["name"] == "engine.round":
            assert parent_name(s) == "scheduler.turn"
        elif s["name"] in ("engine.plan", "engine.execute"):
            assert parent_name(s) == "engine.round"
        elif s["name"] == "executor.dispatch":
            assert parent_name(s) == "executor.count"
    # coalescing evidence rides on the round + execute spans: each
    # iso duplicate becomes a rider on its class lead, never a second
    # execution
    rounds = [s for s in spans if s["name"] == "engine.round"]
    assert sum(s["attrs"]["tickets"] for s in rounds) == 4
    assert sum(s["attrs"]["coalesced"] for s in rounds) == 2
    execs = [s for s in spans if s["name"] == "engine.execute"]
    assert len(execs) == 2
    assert sum(s["attrs"]["riders"] for s in execs) == 2
    # the trace localizes time: leaf spans cover >=95% of the wall
    doc = {"traceEvents": tracer.chrome_events()}
    assert summarize(doc)["leaf_coverage"] >= 0.95


def test_registry_snapshot_stable_across_replays(obs_graph, obs_stats):
    """Two identical replays on fresh engines expose the same snapshot
    key set with the same integer counters (latency values differ)."""
    snaps = []
    for _ in range(2):
        metrics = MetricsRegistry()
        engine, gw, _ = _replay(obs_graph, obs_stats, metrics)
        assert engine.latency_percentiles().keys() == {
            "n", "p50_ms", "p95_ms", "p99_ms", "mean_ms"}
        rep = gw.report()["workloads"]["graph"]
        assert set(rep["turn_item_ms"]["solo"]) == {
            "n", "p50_ms", "p95_ms", "p99_ms", "mean_ms"}
        snaps.append(metrics.snapshot())
    a, b = snaps
    assert a.keys() == b.keys()
    for k in ("engine.requests_resolved", "engine.executions",
              "engine.coalesced", "cache.hits", "cache.misses"):
        assert a[k] == b[k], k
    assert a["engine.query_latency_ms"]["n"] == 4
    assert b["engine.query_latency_ms"]["n"] == 4
