"""Serving integration: decode-with-cache must match full-sequence
forward (teacher forcing) — the strongest correctness property of the
prefill/decode path, per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T

B, S = 2, 12


def _prompts(cfg, key, S):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab,
                                          jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, S, cfg.d_model), jnp.bfloat16
        )
    if cfg.stub_frontend and cfg.family == "vlm":
        batch = {
            "embeds": jax.random.normal(
                jax.random.fold_in(key, 2), (B, S, cfg.d_model), jnp.bfloat16
            ),
            "positions3": jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (B, 3, S)
            ),
        }
    return batch


@pytest.mark.parametrize("arch", [
    "qwen3-1.7b", "granite-moe-1b-a400m", "mamba2-370m",
    pytest.param("jamba-v0.1-52b", marks=pytest.mark.slow),
])
def test_decode_matches_prefill_logits(arch):
    """Prefill the first S-1 tokens, decode token S-1; its logits must
    match the full-sequence forward's last-position logits.

    MoE configs are pinned DROPLESS (capacity_factor = E) for this
    comparison: capacity-drop sets differ between an S-token and an
    (S-1)-token prefill by design, which is routing semantics rather
    than a cache bug — the property under test here."""
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        cfg = cfg.scaled(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    batch = _prompts(cfg, jax.random.fold_in(key, 7), S)

    # full forward over S tokens → logits at the last position
    prefill_full = T.prefill_fn(cfg)
    logits_full, _ = prefill_full(params, batch)

    # prefill S-1, then decode the last token with the cache
    if "tokens" in batch:
        head = {**batch, "tokens": batch["tokens"][:, : S - 1]}
        tail_tok = batch["tokens"][:, S - 1:]
    else:
        head = {**batch, "embeds": batch["embeds"][:, : S - 1],
                "positions3": batch["positions3"][..., : S - 1]}
        tail_tok = None
    if "enc_embeds" in head:
        head["enc_embeds"] = batch["enc_embeds"]

    if tail_tok is None:
        pytest.skip("vlm stub frontend has no token decode input")

    _, pcache = T.prefill_fn(cfg)(params, head)
    cache = T.init_cache(cfg, B, S)
    cache = _seed(cache, pcache, S - 1)
    decode = T.decode_fn(cfg)
    logits_dec, _ = decode(params, tail_tok, cache, jnp.asarray(S - 1))

    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full),
        rtol=5e-2, atol=5e-2,     # bf16 compute
    )


def _seed(cache, pcache, S):
    def put(dst, src):
        if src.shape == dst.shape:
            return src.astype(dst.dtype)
        ax = next(i for i in range(dst.ndim) if src.shape[i] != dst.shape[i])
        idx = [slice(None)] * dst.ndim
        idx[ax] = slice(0, src.shape[ax])
        return dst.at[tuple(idx)].set(src.astype(dst.dtype))

    out = dict(cache)
    if "blocks" in pcache:
        out["blocks"] = jax.tree.map(put, cache["blocks"], pcache["blocks"])
    if "cross_kv" in pcache:
        out["cross_kv"] = put(cache["cross_kv"], pcache["cross_kv"])
    return out
