import itertools

import pytest

from repro.core.pattern import clique, cycle, house, rectangle, star
from repro.core.schedule import (
    generate_schedules, is_prefix_connected, last_k_independent, predecessors,
)


def test_phase1_prefix_connected():
    for p in [house(), clique(4), cycle(5)]:
        for o in generate_schedules(p):
            assert is_prefix_connected(p, o)


def test_phase2_tail_independent_house():
    h = house()
    k = h.max_independent_set_size()
    assert k == 2
    for o in generate_schedules(h):
        assert last_k_independent(h, o, 2)


def test_phase2_relaxes_when_conflicting_with_phase1():
    # 4-cycle: no prefix-connected order ends in the diagonal pair, so
    # phase 2 must relax to k=1 rather than return nothing.
    scheds = generate_schedules(rectangle())
    assert len(scheds) > 0
    for o in scheds:
        assert is_prefix_connected(rectangle(), o)


def test_schedules_subset_of_all_orders():
    p = house()
    scheds = set(generate_schedules(p))
    assert len(scheds) < 120  # strictly prunes 5! orders
    assert all(sorted(o) == [0, 1, 2, 3, 4] for o in scheds)


def test_clique_keeps_all_connected_orders():
    # every order of a clique is prefix-connected; k=1 means phase 2 is
    # vacuous
    assert len(generate_schedules(clique(4))) == 24


def test_predecessors():
    h = house()
    preds = predecessors(h, (0, 1, 2, 3, 4))
    assert preds[0] == []
    # vertex 1 adjacent to 0
    assert preds[1] == [0]
    # roof vertex 4 adjacent to 0 and 1
    assert preds[4] == [0, 1]
