"""Data-pipeline determinism + gradient-compression correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import shard_map
from repro.configs import get_smoke_config
from repro.train.compress import compressed_psum, dequantize_int8, quantize_int8
from repro.train.data import DataConfig, SyntheticLM


def test_batch_pure_function_of_step():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=3)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    for step in (0, 5, 1000):
        b1, b2 = d1.batch(step), d2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_batches_differ_across_steps_and_seeds():
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=4, seed=0)
    d = SyntheticLM(cfg)
    assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])
    d2 = SyntheticLM(DataConfig(vocab=512, seq_len=32, global_batch=4, seed=1))
    assert not np.array_equal(d.batch(0)["tokens"], d2.batch(0)["tokens"])


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=2, seed=0)
    b = SyntheticLM(cfg).batch(0)
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1])
    )


def test_family_specific_batches():
    enc = get_smoke_config("whisper-base")
    b = SyntheticLM(DataConfig(vocab=enc.vocab, seq_len=8, global_batch=2),
                    enc).batch(0)
    assert b["enc_embeds"].shape == (2, 8, enc.d_model)
    vlm = get_smoke_config("qwen2-vl-72b")
    b = SyntheticLM(DataConfig(vocab=vlm.vocab, seq_len=8, global_batch=2),
                    vlm).batch(0)
    assert b["embeds"].shape == (2, 8, vlm.d_model)
    assert b["positions3"].shape == (2, 3, 8)


# ----------------------------------------------------------- compression ---
def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_compressed_psum_single_device_identity_with_error_feedback():
    mesh = jax.make_mesh((1,), ("dp",))
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(64,))
                          .astype(np.float32))}

    def f(t):
        out, err = compressed_psum(t, "dp")
        return out, err

    out, err = jax.jit(
        shard_map(f, mesh=mesh,
                  in_specs=(jax.sharding.PartitionSpec(),),
                  out_specs=(jax.sharding.PartitionSpec(),) * 2)
    )(g)
    # single device: reduced value == dequantized value; error = residual
    np.testing.assert_allclose(
        np.asarray(out["w"] + err["w"]), np.asarray(g["w"]),
        rtol=0, atol=1e-6,
    )


def test_error_feedback_accumulates_to_true_sum():
    """Simulated repeated reductions: error feedback makes the MEAN of
    compressed reductions converge to the true gradient."""
    mesh = jax.make_mesh((1,), ("dp",))
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(128,)).astype(np.float32)) * 1e-3

    def f(t, e):
        out, err = compressed_psum(t, "dp", error_state=e)
        return out, err

    fn = jax.jit(shard_map(
        f, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(),) * 2,
        out_specs=(jax.sharding.PartitionSpec(),) * 2,
    ))
    err = {"g": jnp.zeros_like(g)}
    total = np.zeros_like(np.asarray(g))
    N = 32
    for _ in range(N):
        out, err = fn({"g": g}, err)
        total += np.asarray(out["g"])
    np.testing.assert_allclose(total / N, np.asarray(g), atol=5e-6)
